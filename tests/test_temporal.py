"""repro.temporal: v4 delta containers, VersionedStore round-trips,
versioned serving (single service and fleet, bit-identical), cache
accounting across shared base tiles, and the versioned checkpointer."""
import os
import struct

import numpy as np
import pytest

from repro.codecs import container, load_bytes
from repro.fleet import FleetFrontend, SocketTransport
from repro.serve.codec_service import CodecService
from repro.stream.writer import ChunkedWriter
from repro.temporal import DeltaFitter, VersionedStore, drifting_versions

SHAPE = (12, 10, 8)
N_VERSIONS = 5
KF_INTERVAL = 4  # versions 0 and 4 are keyframes, 1-3 are deltas


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    """(path, input versions, per-append stats) for a shared ttd store."""
    path = str(tmp_path_factory.mktemp("temporal") / "t.tcdc")
    data = drifting_versions(SHAPE, N_VERSIONS, drift=0.05, noise=0.02, seed=5)
    with VersionedStore.create(
        path, "ttd", keyframe_interval=KF_INTERVAL, chunk_bytes=2048,
        keyframe_opts={"max_rank": 8}, delta_opts={"max_rank": 2},
    ) as s:
        stats = [s.append(x) for x in data]
    return path, data, stats


# ---------------------------------------------------------------- container
class TestContainerV4:
    def test_version_index_round_trip(self, store):
        path, _, _ = store
        codec, chunks, versions = container.container_index(path)
        assert codec == "ttd"
        assert len(versions) == N_VERSIONS
        assert [v.base for v in versions] == [-1, 0, 1, 2, -1]
        assert versions[0].chunk_start == 0
        assert versions[-1].chunk_stop == len(chunks)
        for prev, cur in zip(versions, versions[1:]):
            assert cur.chunk_start == prev.chunk_stop

    def test_legacy_apis_reject_v4(self, store):
        path, _, _ = store
        with pytest.raises(ValueError, match="open_container"):
            container.open_chunks(path)
        with pytest.raises(ValueError, match="container_index"):
            container.chunk_index(path)

    def test_corrupt_version_count_rejected(self, store):
        path, _, _ = store
        with open(path, "rb") as f:
            data = bytearray(f.read())
        at = data.rfind(container.VINDEX_MAGIC) + 4
        data[at : at + 4] = struct.pack("<I", 999)
        with pytest.raises(ValueError, match="truncated|version"):
            load_bytes(bytes(data))

    def test_corrupt_version_entry_rejected(self, store):
        path, _, _ = store
        with open(path, "rb") as f:
            data = bytearray(f.read())
        at = data.rfind(container.VINDEX_MAGIC) + 8
        data[at : at + 16] = struct.pack("<qII", 0, 0, 1)  # v0 not a keyframe
        with pytest.raises(ValueError, match="version"):
            load_bytes(bytes(data))

    def test_load_bytes_returns_latest_chain(self, store):
        path, data, _ = store
        with open(path, "rb") as f:
            enc = load_bytes(f.read())
        with VersionedStore.open(path) as reader:
            np.testing.assert_array_equal(enc.to_dense(), reader.decode())

    def test_writer_version_discipline(self, tmp_path):
        path = str(tmp_path / "w.tcdc")
        w = ChunkedWriter(path, "ttd", delta=True)
        with pytest.raises(ValueError, match="outside begin_version"):
            w.append(b"x")
        with pytest.raises(ValueError, match="keyframe"):
            w.begin_version(0)  # version 0 must be a keyframe
        w.begin_version(-1)
        with pytest.raises(ValueError, match="no chunks"):
            w.sync()  # open version is empty
        w.append(b"body")
        with pytest.raises(ValueError, match="bad base"):
            w.begin_version(1)  # forward reference
        w.close()
        _, _, versions = container.container_index(path)
        assert len(versions) == 1 and versions[0].is_keyframe

    def test_sync_leaves_readable_file(self, tmp_path):
        path = str(tmp_path / "s.tcdc")
        w = ChunkedWriter(path, "ttd", delta=True)
        w.begin_version(-1)
        w.append(b"aaaa")
        w.sync()
        _, chunks, versions = container.container_index(path)
        assert (len(chunks), len(versions)) == (1, 1)
        w.begin_version(0)
        w.append(b"bbbb")  # truncates the synced footer, keeps appending
        w.close()
        _, chunks, versions = container.container_index(path)
        assert (len(chunks), len(versions)) == (2, 2)


# ---------------------------------------------------------------- store
class TestVersionedStore:
    def test_round_trip_fitness(self, store):
        path, data, stats = store
        with VersionedStore.open(path) as reader:
            assert reader.n_versions == N_VERSIONS
            for v, x in enumerate(data):
                hat = reader.decode(version=v)
                x64 = np.asarray(x, np.float64)
                fit = 1 - np.linalg.norm(x64 - hat) / np.linalg.norm(x64)
                assert fit == pytest.approx(stats[v]["fitness"], abs=1e-6)
                assert fit > 0.9
            np.testing.assert_array_equal(reader.decode(), reader.decode(version=4))

    def test_deltas_much_smaller_than_keyframes(self, store):
        _, _, stats = store
        kf = [s["bytes"] for s in stats if s["keyframe"]]
        deltas = [s["bytes"] for s in stats if not s["keyframe"]]
        assert len(kf) == 2 and len(deltas) == 3
        assert max(deltas) * 3 < min(kf)

    def test_rekey_below_forces_keyframe(self, tmp_path):
        data = drifting_versions((10, 8, 6), 3, drift=0.3, noise=0.1, seed=9)
        with VersionedStore.create(
            str(tmp_path / "r.tcdc"), "ttd", keyframe_interval=100,
            keyframe_opts={"max_rank": 6}, delta_opts={"max_rank": 1},
            rekey_below=0.999,
        ) as s:
            stats = [s.append(x) for x in data]
        # a rank-1 residual cannot hold the chain above .999 -> rekeyed
        assert any(st["rekeyed"] for st in stats[1:])
        for st in stats:
            assert st["rekeyed"] == (st["keyframe"] and st["version"] > 0)

    def test_shape_mismatch_rejected(self, tmp_path):
        with VersionedStore.create(
            str(tmp_path / "m.tcdc"), "ttd", keyframe_opts={"max_rank": 2}
        ) as s:
            s.append(np.zeros((4, 4, 4), np.float32) + 1)
            with pytest.raises(ValueError, match="shape"):
                s.append(np.ones((4, 4, 5), np.float32))


# ---------------------------------------------------------------- service
def _probe(n=256, seed=0):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, n) for s in SHAPE], axis=1)


class TestServiceVersioned:
    def test_decode_at_matches_reader(self, store):
        path, _, _ = store
        idx = _probe()
        with VersionedStore.open(path) as reader:
            for tile_entries in (None, 64):
                svc = CodecService()
                svc.load_stream("t", path, tile_entries=tile_entries)
                assert svc.info("t").n_versions == N_VERSIONS
                for v in (0, 2, 4, None):
                    np.testing.assert_array_equal(
                        svc.decode_at("t", idx, version=v),
                        reader.decode_at(idx, version=v),
                    )

    def test_version_validation(self, store):
        path, _, _ = store
        svc = CodecService()
        svc.load_stream("t", path)
        with pytest.raises(ValueError, match="out of range"):
            svc.decode_at("t", _probe(), version=N_VERSIONS)
        from repro.codecs import get_codec

        rng = np.random.default_rng(0)
        flat = get_codec("ttd").fit(rng.random((4, 4, 4)).astype(np.float32),
                                    max_rank=2)
        svc.load("flat", flat)
        with pytest.raises(ValueError, match="not versioned"):
            svc.decode_at("flat", np.zeros((1, 3), np.int64), version=0)

    def test_submit_flush_mixed_versions(self, store):
        path, _, _ = store
        svc = CodecService()
        svc.load_stream("t", path, tile_entries=64)
        idx = _probe()
        tickets = {v: svc.submit("t", idx, version=v) for v in (0, 1, None)}
        out = svc.flush()
        for v, t in tickets.items():
            np.testing.assert_array_equal(out[t], svc.decode_at("t", idx, version=v))

    def test_keyframe_tiles_shared_across_versions(self, store):
        path, _, _ = store
        svc = CodecService()
        svc.load_stream("t", path, tile_entries=64)
        idx = _probe()
        svc.decode_at("t", idx, version=1)  # cold: keyframe 0 + delta 1 tiles
        h0, m0 = svc.cache_stats.hits, svc.cache_stats.misses
        svc.decode_at("t", idx, version=2)  # shares v0 AND v1 tiles, adds v2
        h1, m1 = svc.cache_stats.hits, svc.cache_stats.misses
        assert h1 - h0 > 0  # base-chain tiles hit
        assert m1 - m0 > 0  # only version 2's own tiles missed
        svc.decode_at("t", idx, version=2)  # fully warm
        h2, m2 = svc.cache_stats.hits, svc.cache_stats.misses
        assert m2 == m1 and h2 > h1

    def test_cache_budget_bounds_versioned_state(self, store):
        path, _, _ = store
        budget = 16 << 10
        svc = CodecService(cache_bytes=budget)
        svc.load_stream("t", path, tile_entries=64)
        idx = _probe()
        for v in range(N_VERSIONS):
            svc.decode_at("t", idx, version=v)
            assert svc.cache_stats.resident_bytes <= budget
        assert svc.cache_stats.evictions > 0


# ---------------------------------------------------------------- fleet
class TestFleetVersioned:
    @pytest.mark.parametrize("tile_entries", [None, 64])
    def test_three_instances_bit_identical(self, store, tile_entries):
        path, _, _ = store
        single = CodecService()
        single.load_stream("t", path, tile_entries=tile_entries)
        fleet = FleetFrontend(3)
        fleet.load_stream("t", path, tile_entries=tile_entries)
        idx = _probe(512, seed=3)
        for v in (0, 1, 2, 3, 4, None):
            np.testing.assert_array_equal(
                fleet.decode_at("t", idx, version=v),
                single.decode_at("t", idx, version=v),
            )
        fleet.close()

    def test_socket_workers_bit_identical(self, store):
        path, _, _ = store
        single = CodecService()
        single.load_stream("t", path, tile_entries=64)
        fleet = FleetFrontend(
            ["w0", "w1"], transport_factory=lambda iid: SocketTransport.spawn(iid)
        )
        try:
            fleet.load_stream("t", path, tile_entries=64)
            idx = _probe(512, seed=4)
            for v in (0, 3, None):
                np.testing.assert_array_equal(
                    fleet.decode_at("t", idx, version=v),
                    single.decode_at("t", idx, version=v),
                )
        finally:
            fleet.close()


# ---------------------------------------------------------------- nttd delta
def test_nttd_warm_started_delta(tmp_path):
    """The paper codec's stream fitter resumes across residuals: the chain
    stays near (here: above) the keyframe's own fitness at a fraction of
    the keyframe bytes."""
    data = drifting_versions((8, 6, 5), 2, drift=0.05, noise=0.02, seed=2)
    with VersionedStore.create(
        str(tmp_path / "n.tcdc"), "nttd", keyframe_interval=4,
        keyframe_opts=dict(rank=4, hidden=8, epochs=20, batch_size=512,
                           eval_batch=512, init_reorder=False,
                           update_reorder=False, seed=0),
        delta_opts=dict(rank=2, hidden=4, d_prime=2, lr=1e-2,
                        batch_size=256, steps_per_slab=100, seed=0),
    ) as s:
        stats = [s.append(x) for x in data]
    assert not stats[1]["keyframe"]
    assert stats[1]["bytes"] < stats[0]["bytes"]
    assert stats[1]["fitness"] >= stats[0]["fitness"] - 0.05
    with VersionedStore.open(str(tmp_path / "n.tcdc")) as reader:
        assert reader.decode(version=1).shape == (8, 6, 5)


def test_delta_fitter_persists_across_residuals():
    fitter = DeltaFitter((8, 6, 5), "nttd", passes=1,
                         opts=dict(rank=2, hidden=4, batch_size=256, seed=0))
    rng = np.random.default_rng(0)
    r = rng.standard_normal((8, 6, 5)).astype(np.float32) * 0.1
    fitter.fit_residual(r)
    inner = fitter._fitter
    fitter.fit_residual(r * 0.5)
    assert fitter._fitter is inner  # warm start: same fitter object resumes


# ---------------------------------------------------------------- checkpoint
class TestVersionedCheckpointer:
    def _trees(self, n=3):
        rng = np.random.default_rng(7)
        mats = drifting_versions((16, 12, 10), n, drift=0.05, noise=0.02, seed=3)
        bias = rng.standard_normal(8).astype(np.float32)
        return [{"w": m, "b": bias + k} for k, m in enumerate(mats)]

    def _cfg(self, **kw):
        from repro.compress.checkpoint_codec import VersionedCheckpointConfig

        base = dict(codec="ttd", min_elements=256, min_fitness=0.9,
                    keyframe_interval=4, keyframe_opts={"max_rank": 8},
                    delta_opts={"max_rank": 2})
        base.update(kw)
        return VersionedCheckpointConfig(**base)

    def test_save_restore_steps(self, tmp_path):
        from repro.compress.checkpoint_codec import VersionedCheckpointer

        trees = self._trees()
        with VersionedCheckpointer(str(tmp_path / "ck"), self._cfg()) as ck:
            stats = [ck.save_step(t) for t in trees]
            r1 = ck.restore_step(1, trees[0])
        assert [s["leaves_store"] for s in stats] == [1, 1, 1]
        assert stats[1]["bytes"] < stats[0]["bytes"] / 2  # delta step
        np.testing.assert_array_equal(r1["b"], trees[1]["b"])  # raw: exact
        w64 = np.asarray(trees[1]["w"], np.float64)
        fit = 1 - np.linalg.norm(w64 - r1["w"]) / np.linalg.norm(w64)
        assert fit > 0.9

    def test_reopen_is_restore_only(self, tmp_path):
        from repro.compress.checkpoint_codec import VersionedCheckpointer

        trees = self._trees(2)
        path = str(tmp_path / "ck")
        with VersionedCheckpointer(path, self._cfg()) as ck:
            for t in trees:
                ck.save_step(t)
        ck2 = VersionedCheckpointer(path, self._cfg())
        assert ck2.n_steps == 2
        r0 = ck2.restore_step(0, trees[0])
        np.testing.assert_array_equal(r0["b"], trees[0]["b"])
        with pytest.raises(ValueError, match="restore-only"):
            ck2.save_step(trees[0])

    def test_unfit_leaf_demoted_to_raw(self, tmp_path):
        from repro.compress.checkpoint_codec import VersionedCheckpointer

        # rank-1 TT cannot reach .99 on random data -> permanent demotion
        cfg = self._cfg(min_fitness=0.99, keyframe_opts={"max_rank": 1})
        rng = np.random.default_rng(1)
        trees = [{"w": rng.standard_normal((24, 20)).astype(np.float32)}
                 for _ in range(2)]
        with VersionedCheckpointer(str(tmp_path / "ck"), cfg) as ck:
            s0 = ck.save_step(trees[0])
            s1 = ck.save_step(trees[1])
            r1 = ck.restore_step(1, trees[0])
        assert s0["leaves_store"] == 0 and s0["leaves_raw"] == 1
        assert s1["leaves_raw"] == 1
        assert not os.path.exists(str(tmp_path / "ck" / "leaf0.tcdc"))
        np.testing.assert_array_equal(r1["w"], trees[1]["w"])  # raw: exact
