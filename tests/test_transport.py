"""repro.fleet.transport: wire framing, LocalTransport/SocketTransport
equivalence, and the failure modes that must degrade cleanly — a worker
killed mid-batch, truncated frames, request timeouts — instead of
hanging the fleet."""
import os
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.codecs import get_codec
from repro.fleet import (
    FleetFrontend,
    LocalTransport,
    RemoteError,
    SocketTransport,
    TransportError,
    rebalance,
)
from repro.fleet.transport import (
    ProtocolError,
    Reader,
    Writer,
    pack_ownership,
    parse_address,
    recv_frame,
    send_frame,
    unpack_ownership,
)
from repro.serve.codec_service import CodecService, Ownership
from repro.stream import write_chunked

SHAPE = (16, 16, 8)


@pytest.fixture(scope="module")
def payload_path(tmp_path_factory):
    rng = np.random.default_rng(0)
    x = rng.random(SHAPE).astype(np.float32)
    enc = get_codec("ttd").fit(x, max_rank=4)
    path = str(tmp_path_factory.mktemp("transport") / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=1024)
    return path


def _idx(n=100, seed=1):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, n) for s in SHAPE], axis=1)


def _spawn(iid, **kw):
    kw.setdefault("timeout", 10.0)
    return SocketTransport.spawn(iid, **kw)


# ---------------------------------------------------------------------------
# wire framing
# ---------------------------------------------------------------------------
def test_writer_reader_roundtrip():
    arr = np.arange(24, dtype=np.float64).reshape(4, 6)
    body = (
        Writer().u8(7).u16(300).u32(1 << 20).u64(1 << 40).i64(-5)
        .str("payload/α").blob(b"raw bytes").array(arr).bytes()
    )
    r = Reader(body)
    assert (r.u8(), r.u16(), r.u32(), r.u64(), r.i64()) == (
        7, 300, 1 << 20, 1 << 40, -5
    )
    assert r.str() == "payload/α"
    assert r.blob() == b"raw bytes"
    np.testing.assert_array_equal(r.array(), arr)  # bit-exact


def test_reader_rejects_truncated_body():
    body = Writer().u64(1).bytes()
    with pytest.raises(ProtocolError, match="truncated"):
        Reader(body[:3]).u64()
    with pytest.raises(ProtocolError, match="truncated"):
        Reader(Writer().str("hello").bytes()[:4]).str()


@pytest.mark.parametrize(
    "ownership",
    [
        None,
        Ownership(),
        Ownership(chunk_ids=frozenset({1, 5}), tile_ids=None),
        Ownership(chunk_ids=frozenset(), tile_ids=frozenset({0, 2, 9})),
    ],
)
def test_ownership_roundtrip(ownership):
    w = Writer()
    pack_ownership(w, ownership)
    got = unpack_ownership(Reader(w.bytes()))
    if ownership is None:
        assert got is None
    else:
        assert got.chunk_ids == ownership.chunk_ids
        assert got.tile_ids == ownership.tile_ids


def test_parse_address():
    assert parse_address("unix:/tmp/x.sock") == (socket.AF_UNIX, "/tmp/x.sock")
    assert parse_address("tcp:127.0.0.1:7070") == (
        socket.AF_INET, ("127.0.0.1", 7070)
    )
    with pytest.raises(ValueError, match="bad"):
        parse_address("http://nope")
    with pytest.raises(ValueError, match="bad tcp"):
        parse_address("tcp:missing-port")


def test_frame_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    with a, b:
        send_frame(a, b"hello frame")
        assert recv_frame(b) == b"hello frame"
        a.close()
        assert recv_frame(b) is None  # clean EOF at a frame boundary


def test_truncated_frame_is_protocol_error_not_hang():
    a, b = socket.socketpair()
    with a, b:
        a.sendall(struct.pack("<I", 100) + b"only a little")
        a.close()
        with pytest.raises(ProtocolError, match="truncated frame"):
            recv_frame(b)


# ---------------------------------------------------------------------------
# LocalTransport semantics
# ---------------------------------------------------------------------------
def test_local_transport_defers_submit_errors_to_flush(payload_path):
    t = LocalTransport("l0")
    t.load_stream("t", payload_path)
    bad = t.submit("nope", _idx(4))  # unknown payload: deferred, not raised
    good = t.submit("t", _idx(4))
    results, failures = t.flush()
    assert good in results and bad in failures
    assert isinstance(failures[bad], KeyError)
    assert t.flush() == ({}, {})  # reported exactly once


def test_local_transport_full_surface(payload_path):
    t = LocalTransport("l0")
    t.load_stream("t", payload_path, tile_entries=64)
    assert t.payloads() == ["t"]
    assert t.shape_of("t") == SHAPE
    rid = t.submit("t", _idx(10))
    results, failures = t.flush()
    assert not failures and results[rid].shape == (10,)
    stats = t.stats()
    assert stats["misses"] > 0 and "t" in stats["per_payload"]
    t.set_ownership("t", Ownership(tile_ids=frozenset()))
    assert t.drop_unowned("t") > 0
    t.unload("t")
    assert t.payloads() == []


# ---------------------------------------------------------------------------
# socket transport vs local: bit-identical round trip (satellite)
# ---------------------------------------------------------------------------
def test_socket_and_local_transport_bit_identical(payload_path):
    local = LocalTransport("l0")
    local.load_stream("t", payload_path, tile_entries=64)
    remote = _spawn("w0")
    try:
        remote.load_stream("t", payload_path, tile_entries=64)
        assert remote.payloads() == ["t"]
        assert remote.shape_of("t") == SHAPE
        batches = [_idx(n, seed=n) for n in (3, 57, 200)]
        l_tickets = [local.submit("t", b) for b in batches]
        r_tickets = [remote.submit("t", b) for b in batches]
        l_res, l_fail = local.flush()
        r_res, r_fail = remote.flush()
        assert not l_fail and not r_fail
        for lt, rt in zip(l_tickets, r_tickets):
            np.testing.assert_array_equal(l_res[lt], r_res[rt])
            assert l_res[lt].dtype == r_res[rt].dtype
        # ownership verbs round-trip: export tiles, drop, re-admit
        tiles = remote.export_tiles("t")
        assert tiles and all(isinstance(v, np.ndarray) for v in tiles.values())
        assert tiles.keys() == local.export_tiles("t").keys()
        tid, values = next(iter(tiles.items()))
        np.testing.assert_array_equal(values, local.export_tiles("t")[tid])
        remote.set_ownership("t", Ownership(tile_ids=frozenset()))
        assert remote.drop_unowned("t") > 0
        remote.set_ownership("t", None)
        assert remote.admit_tile("t", tid, values)
        # stats snapshots share one schema
        assert set(remote.stats()) == set(local.stats())
        # a remote service error comes back as RemoteError, not a hang
        bad = remote.submit("nope", _idx(2))
        _, fail = remote.flush()
        assert isinstance(fail[bad], RemoteError)
        assert "nope" in str(fail[bad])
        with pytest.raises(RemoteError, match="no payload"):
            remote.shape_of("ghost")
        # ...and the transport is still healthy afterwards
        rid = remote.submit("t", batches[0])
        res, fail = remote.flush()
        assert not fail
        np.testing.assert_array_equal(res[rid], l_res[l_tickets[0]])
    finally:
        remote.close()
    with pytest.raises(TransportError):  # closed transports fail fast
        remote.submit("t", batches[0])


def test_spawned_socket_dir_removed_on_close(payload_path):
    remote = _spawn("w0")
    sock_dir = remote._owned_dir
    assert sock_dir is not None and os.path.isdir(sock_dir)
    remote.close()
    assert not os.path.exists(sock_dir)  # no /tmp litter per spawn


def test_spawn_instance_replay_failure_closes_transport(payload_path, tmp_path):
    """A joiner whose payload replay fails must be closed (its worker
    process reaped), not leaked outside fleet.transports."""

    class FailingTransport(LocalTransport):
        closed = False

        def load_stream(self, name, path, *, tile_entries=None):
            raise ValueError("replay boom")

        def close(self):
            FailingTransport.closed = True
            super().close()

    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path)
    fleet._transport_factory = FailingTransport
    with pytest.raises(ValueError, match="replay boom"):
        rebalance(fleet, add=["i9"])
    assert "i9" not in fleet.transports
    assert FailingTransport.closed


def test_worker_closes_on_garbage_frame(payload_path):
    remote = _spawn("w0")
    try:
        remote.load_stream("t", payload_path)
        # a length prefix promising more bytes than ever arrive: the worker
        # must treat it as a protocol error and close — not hang waiting
        remote._sock.sendall(struct.pack("<I", 64) + b"garbage")
        remote._sock.shutdown(socket.SHUT_WR)
        assert remote._proc.wait(timeout=10) == 0  # exited, no hang
    finally:
        remote.close()


# ---------------------------------------------------------------------------
# multi-process fleet: bit-identical + live rebalance (tentpole acceptance)
# ---------------------------------------------------------------------------
def test_socket_fleet_bit_identical_with_rebalance(payload_path):
    single = CodecService()
    single.load_stream("t", payload_path, tile_entries=64)
    fleet = FleetFrontend(
        ["w0", "w1", "w2"], transport_factory=lambda iid: _spawn(iid)
    )
    try:
        fleet.load_stream("t", payload_path, tile_entries=64)
        batches = [_idx(80, seed=s) for s in range(4)]
        refs = [single.decode_at("t", b) for b in batches]
        for b, ref in zip(batches, refs):
            np.testing.assert_array_equal(fleet.decode_at("t", b), ref)
        # live rebalance mid-query-stream: a real worker process retires
        pending = [fleet.submit("t", b) for b in batches[:2]]
        report = rebalance(fleet, remove=["w2"])
        out = fleet.flush()
        assert not fleet.failed  # ZERO failed tickets across the change
        assert report.removed == ["w2"]
        for t, ref in zip(pending, refs[:2]):
            np.testing.assert_array_equal(out[t], ref)
        assert fleet.instances() == ["w0", "w1"]
        for b, ref in zip(batches, refs):
            np.testing.assert_array_equal(fleet.decode_at("t", b), ref)
    finally:
        fleet.close()


def test_worker_killed_mid_batch_fails_cleanly_then_replica_serves(payload_path):
    """Kill a worker with tickets in flight: those tickets fail cleanly
    (no hang), the instance lands in ``excluded``, and with replication=2
    the very next query is served bit-identically by the survivor."""
    single = CodecService()
    single.load_stream("t", payload_path, tile_entries=64)
    fleet = FleetFrontend(
        ["w0", "w1"],
        replication=2,
        transport_factory=lambda iid: _spawn(iid),
    )
    try:
        fleet.load_stream("t", payload_path, tile_entries=64)
        idx = _idx(300)
        ref = single.decode_at("t", idx)
        np.testing.assert_array_equal(fleet.decode_at("t", idx), ref)
        victim = "w1"
        fleet.transports[victim]._proc.kill()
        tickets = [fleet.submit("t", _idx(40, seed=s)) for s in range(3)]
        t0 = time.monotonic()
        out = fleet.flush()  # must not hang on the dead socket
        assert time.monotonic() - t0 < 10
        assert victim in fleet.excluded
        assert isinstance(fleet.exclusion_errors[victim], TransportError)
        for t in tickets:  # every ticket resolved: result or clean failure
            assert (t in out) != (t in fleet.failed)
        # replication=2: every group still has a live owner -> full answers
        np.testing.assert_array_equal(fleet.decode_at("t", idx), ref)
        # the fleet still registers NEW payloads while a member is dead —
        # survivors load it; the corpse catches up at rebalance (never: it
        # is being removed below)
        fleet.load_stream("u", payload_path, tile_entries=64)
        single.load_stream("u", payload_path, tile_entries=64)
        np.testing.assert_array_equal(
            fleet.decode_at("u", idx), single.decode_at("u", idx)
        )
        # removing the dead member for real must not hang either
        report = rebalance(fleet, remove=[victim])
        assert report.removed == [victim]
        assert fleet.instances() == ["w0"] and not fleet.excluded
        np.testing.assert_array_equal(fleet.decode_at("t", idx), ref)
    finally:
        fleet.close()


def test_dead_worker_without_replicas_is_unroutable_error(payload_path):
    fleet = FleetFrontend(["w0"], transport_factory=lambda iid: _spawn(iid))
    try:
        fleet.load_stream("t", payload_path, tile_entries=64)
        fleet.transports["w0"]._proc.kill()
        with pytest.raises(TransportError):
            fleet.decode_at("t", _idx(10))  # the death itself, reported cleanly
        assert fleet.excluded == {"w0"}
        with pytest.raises(TransportError, match="every replica is excluded"):
            fleet.decode_at("t", _idx(10))  # now routed around — and empty
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# client-side failure modes against a fake server (no worker spawn)
# ---------------------------------------------------------------------------
def _fake_server(behavior):
    """A one-connection TCP server running ``behavior(conn)`` in a thread."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]

    def run():
        conn, _ = srv.accept()
        with conn:
            behavior(conn)
        srv.close()

    threading.Thread(target=run, daemon=True).start()
    return f"tcp:127.0.0.1:{port}"


def test_truncated_response_is_transport_error_not_hang():
    def truncate(conn):
        conn.recv(1 << 16)  # swallow the request
        conn.sendall(struct.pack("<I", 500) + b"half a frame")
        # close without sending the rest

    addr = _fake_server(truncate)
    t = SocketTransport("fake", addr, timeout=5.0, connect_timeout=5.0)
    with pytest.raises(TransportError, match="truncated"):
        t.ping()
    with pytest.raises(TransportError):  # dead from then on, fails fast
        t.stats()


def test_unresponsive_server_hits_request_timeout():
    def stall(conn):
        conn.recv(1 << 16)
        time.sleep(5)  # never answer

    addr = _fake_server(stall)
    t = SocketTransport("fake", addr, timeout=0.5, connect_timeout=5.0)
    t0 = time.monotonic()
    with pytest.raises(TransportError, match="timed out"):
        t.ping()
    assert time.monotonic() - t0 < 3  # the timeout bounded the wait


def test_out_of_order_response_id_is_protocol_error():
    def wrong_rid(conn):
        payload = recv_frame(conn)
        (_, rid) = struct.unpack("<BQ", payload[:9])
        send_frame(conn, struct.pack("<BQ", 0, rid + 999))

    addr = _fake_server(wrong_rid)
    t = SocketTransport("fake", addr, timeout=5.0, connect_timeout=5.0)
    with pytest.raises(ProtocolError, match="response id"):
        t.ping()


def test_connect_retry_gives_up_with_clear_error():
    with pytest.raises(TransportError, match="could not connect"):
        SocketTransport(
            "ghost", "unix:/tmp/definitely-not-a-socket-xyz.sock",
            connect_timeout=0.5, retry_delay=0.1,
        )


# ---------------------------------------------------------------------------
# tracing is observational only: answers + counters identical on or off
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["local", "socket"])
def test_tracing_on_off_bit_identical(payload_path, kind, monkeypatch):
    from repro import obs

    def run(traced: bool):
        monkeypatch.setenv("REPRO_TRACE", "1" if traced else "0")
        if traced:
            obs.enable_tracing()
            obs.get_recorder().clear()
        else:
            obs.disable_tracing()
        t = (
            LocalTransport("l0")
            if kind == "local"
            else _spawn("w0")  # worker inherits REPRO_TRACE from the env
        )
        try:
            t.load_stream("t", payload_path, tile_entries=64)
            tickets = [t.submit("t", _idx(n, seed=n)) for n in (3, 57, 200)]
            results, failures = t.flush()
            assert not failures
            return [results[k] for k in tickets], t.stats()
        finally:
            t.close()

    try:
        res_off, stats_off = run(traced=False)
        res_on, stats_on = run(traced=True)
    finally:
        obs.disable_tracing()
        obs.get_recorder().clear()
    for a, b in zip(res_off, res_on):
        np.testing.assert_array_equal(a, b)  # bit-exact
        assert a.dtype == b.dtype
    assert stats_off == stats_on  # every cache counter identical
