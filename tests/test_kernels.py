"""Per-kernel validation: Pallas (interpret=True) vs the ref.py oracles,
swept over shapes and dtypes (the property-sweep substitute for hypothesis,
which is unavailable offline)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "b,k,r,dtype",
    [
        (b, k, r, dt)
        for b, k, r in [(32, 0, 4), (64, 5, 8), (100, 10, 16), (7, 3, 8), (256, 8, 32)]
        for dt in [jnp.float32, jnp.bfloat16]
    ],
    ids=lambda v: str(v).split(".")[-1] if hasattr(v, "dtype") else str(v),
)
def test_tt_contract_sweep(b, k, r, dtype):
    f = jnp.asarray(RNG.normal(size=(b, r)), dtype)
    # keep the chain product O(1) so bf16 tolerances are meaningful
    m = jnp.asarray(RNG.normal(size=(b, k, r, r)) * (0.5 / np.sqrt(r)), dtype)
    last = jnp.asarray(RNG.normal(size=(b, r)), dtype)
    want = ops.tt_contract(f, m, last, impl="ref")
    got = ops.tt_contract(f, m, last, impl="pallas_interpret", tile_b=32)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize(
    "b,t,h,dtype",
    [
        (b, t, h, dt)
        for b, t, h in [(16, 6, 8), (50, 9, 16), (33, 12, 32), (8, 3, 64)]
        for dt in [jnp.float32, jnp.bfloat16]
    ],
)
def test_lstm_scan_sweep(b, t, h, dtype):
    x = jnp.asarray(RNG.normal(size=(b, t, h)), dtype)
    wi = jnp.asarray(RNG.normal(size=(h, 4 * h)) * 0.3, dtype)
    wh = jnp.asarray(RNG.normal(size=(h, 4 * h)) * 0.3, dtype)
    bb = jnp.asarray(RNG.normal(size=(4 * h,)) * 0.1, dtype)
    want = ops.lstm_scan(x, wi, wh, bb, impl="ref")
    got = ops.lstm_scan(x, wi, wh, bb, impl="pallas_interpret", tile_b=16)
    tol = 2e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize(
    "b,s,hq,hkv,d",
    [(1, 128, 4, 4, 64), (2, 256, 8, 2, 64), (2, 128, 4, 1, 128)],
)
def test_flash_attention_sweep(b, s, hq, hkv, d):
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref")
    got = ops.attention(q, k, v, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_offset():
    """Decode shape: 1 query attending a longer KV with causal offset."""
    b, skv, h, d = 2, 256, 4, 64
    q = jnp.asarray(RNG.normal(size=(b, 128, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref", q_offset=128)
    got = ops.attention(q, k, v, impl="pallas_interpret", q_offset=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_attention_matches_ref():
    from repro.kernels import ref

    b, s, h, d = 2, 4096, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    want = ref.mha_attention(q, k, v)
    got = ref.mha_attention_chunked(q, k, v, chunk=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_kv_len_masking():
    from repro.kernels import ref

    b, sq, skv, h, d = 3, 1, 64, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    kv_len = jnp.asarray([10, 32, 64], jnp.int32)
    out = ref.mha_attention(q, k, v, causal=False, kv_len=kv_len)
    # manual check for batch 0: only first 10 kv positions participate
    out0 = ref.mha_attention(q[:1], k[:1, :10], v[:1, :10], causal=False)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out0[0]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# explicit-impl attention on non-aligned sequence lengths (pad + mask path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal", [False, True])
def test_attention_explicit_impl_odd_seq(causal):
    """seq=130 is not a multiple of the 128 tile: an EXPLICIT pallas impl
    must pad+mask and run the kernel, not silently fall back to ref."""
    b, s, h, d = 1, 130, 2, 64
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref", causal=causal)
    got = ops.attention(q, k, v, impl="pallas_interpret", causal=causal)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_attention_explicit_impl_odd_kv_only():
    """Cross-attention shape: aligned queries, ragged KV (skv=130)."""
    b, sq, skv, h, d = 1, 128, 130, 2, 64
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref")
    got = ops.attention(q, k, v, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
def test_chunked_attention_ragged_tail(causal):
    """sq=2049 = 4*512 + 1: the scan covers the aligned prefix and the
    ragged tail is finished separately (no silent full-score fallback)."""
    from repro.kernels import ref

    b, s, h, d = 1, 2049, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    want = ref.mha_attention(q, k, v, causal=causal)
    got = ref.mha_attention_chunked(q, k, v, chunk=512, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused NTTD decode tile: interpret-mode Pallas vs the jnp oracle
# ---------------------------------------------------------------------------
def _decode_tile_args(b, t, m, hid, rank, dtype, seed=1):
    rng = np.random.default_rng(seed)

    def mk(*shape, scale=0.3):
        return jnp.asarray(rng.normal(size=shape) * scale, dtype)

    idx = jnp.asarray(rng.integers(0, m, size=(b, t)), jnp.int32)
    return idx, (
        mk(t, m, hid),                              # emb
        mk(hid, 4 * hid), mk(hid, 4 * hid), mk(4 * hid, scale=0.1),  # lstm
        mk(hid, rank), mk(rank, scale=0.1),         # first head
        mk(hid, rank * rank, scale=0.5 / np.sqrt(rank)), mk(rank * rank, scale=0.1),
        mk(hid, rank), mk(rank, scale=0.1),         # last head
    )


@pytest.mark.parametrize(
    "rank,t,dtype",
    [
        (r, t, dt)
        for r in [4, 8, 32]
        for t in [2, 3, 8]
        for dt in [jnp.float32, jnp.bfloat16]
    ],
)
def test_decode_tile_parity_sweep(rank, t, dtype):
    """Interpret-mode Pallas is BIT-IDENTICAL to the jitted oracle (same
    compiled op order), and within eager-vs-jit ulp noise of the eager
    oracle."""
    from repro.kernels import ref

    idx, ws = _decode_tile_args(32, t, 10, 16, rank, dtype)
    got = ops.nttd_decode_tile(idx, *ws, impl="pallas_interpret", tile_b=16)
    fused = ops.nttd_decode_tile(idx, *ws, impl="fused")
    assert got.dtype == ws[0].dtype
    assert np.array_equal(np.asarray(got), np.asarray(fused)), (
        "interpret kernel drifted from jitted oracle"
    )
    eager = ref.nttd_decode_tile(idx, *ws)
    tol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(eager, np.float32),
        rtol=tol, atol=tol,
    )


def test_decode_tile_non_multiple_batch():
    """b=33 with tile_b=16: the wrapper pads the batch to a tile multiple
    and slices the result back."""
    idx, ws = _decode_tile_args(33, 3, 7, 16, 8, jnp.float32)
    got = ops.nttd_decode_tile(idx, *ws, impl="pallas_interpret", tile_b=16)
    fused = ops.nttd_decode_tile(idx, *ws, impl="fused")
    assert got.shape == (33,)
    assert np.array_equal(np.asarray(got), np.asarray(fused))


def test_decode_tile_empty_batch():
    idx, ws = _decode_tile_args(0, 3, 7, 16, 8, jnp.float32)
    for impl in ("pallas_interpret", "fused", "ref", "auto"):
        out = ops.nttd_decode_tile(idx, *ws, impl=impl)
        assert out.shape == (0,)
        assert out.dtype == ws[0].dtype


def test_decode_tile_rejects_short_chain():
    idx, ws = _decode_tile_args(8, 2, 7, 16, 8, jnp.float32)
    with pytest.raises(ValueError, match="T >= 2"):
        ops.nttd_decode_tile(idx[:, :1], *(w if i else w[:1] for i, w in enumerate(ws)))


def test_fused_apply_matches_ref_apply():
    """kernel_impl='fused' routes nttd.apply through the one-program
    decode; values must match the per-op ref chain."""
    import jax

    from repro.core import nttd
    from repro.core.folding import make_folding_spec

    spec = make_folding_spec((20, 18, 12))
    cfg_ref = nttd.NTTDConfig(rank=6, hidden=12, kernel_impl="ref")
    cfg_fused = nttd.NTTDConfig(rank=6, hidden=12, kernel_impl="fused")
    params = nttd.init_params(jax.random.PRNGKey(3), spec, cfg_ref)
    rng = np.random.default_rng(5)
    pos = jnp.asarray(
        np.stack([rng.integers(0, s, 257) for s in spec.shape], axis=1), jnp.int32
    )
    want = nttd.apply_at_positions(params, pos, spec, cfg_ref)
    got = nttd.apply_at_positions(params, pos, spec, cfg_fused)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
    )
