"""Per-kernel validation: Pallas (interpret=True) vs the ref.py oracles,
swept over shapes and dtypes (the property-sweep substitute for hypothesis,
which is unavailable offline)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "b,k,r,dtype",
    [
        (b, k, r, dt)
        for b, k, r in [(32, 0, 4), (64, 5, 8), (100, 10, 16), (7, 3, 8), (256, 8, 32)]
        for dt in [jnp.float32, jnp.bfloat16]
    ],
    ids=lambda v: str(v).split(".")[-1] if hasattr(v, "dtype") else str(v),
)
def test_tt_contract_sweep(b, k, r, dtype):
    f = jnp.asarray(RNG.normal(size=(b, r)), dtype)
    # keep the chain product O(1) so bf16 tolerances are meaningful
    m = jnp.asarray(RNG.normal(size=(b, k, r, r)) * (0.5 / np.sqrt(r)), dtype)
    last = jnp.asarray(RNG.normal(size=(b, r)), dtype)
    want = ops.tt_contract(f, m, last, impl="ref")
    got = ops.tt_contract(f, m, last, impl="pallas_interpret", tile_b=32)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize(
    "b,t,h,dtype",
    [
        (b, t, h, dt)
        for b, t, h in [(16, 6, 8), (50, 9, 16), (33, 12, 32), (8, 3, 64)]
        for dt in [jnp.float32, jnp.bfloat16]
    ],
)
def test_lstm_scan_sweep(b, t, h, dtype):
    x = jnp.asarray(RNG.normal(size=(b, t, h)), dtype)
    wi = jnp.asarray(RNG.normal(size=(h, 4 * h)) * 0.3, dtype)
    wh = jnp.asarray(RNG.normal(size=(h, 4 * h)) * 0.3, dtype)
    bb = jnp.asarray(RNG.normal(size=(4 * h,)) * 0.1, dtype)
    want = ops.lstm_scan(x, wi, wh, bb, impl="ref")
    got = ops.lstm_scan(x, wi, wh, bb, impl="pallas_interpret", tile_b=16)
    tol = 2e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize(
    "b,s,hq,hkv,d",
    [(1, 128, 4, 4, 64), (2, 256, 8, 2, 64), (2, 128, 4, 1, 128)],
)
def test_flash_attention_sweep(b, s, hq, hkv, d):
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref")
    got = ops.attention(q, k, v, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_offset():
    """Decode shape: 1 query attending a longer KV with causal offset."""
    b, skv, h, d = 2, 256, 4, 64
    q = jnp.asarray(RNG.normal(size=(b, 128, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    want = ops.attention(q, k, v, impl="ref", q_offset=128)
    got = ops.attention(q, k, v, impl="pallas_interpret", q_offset=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_attention_matches_ref():
    from repro.kernels import ref

    b, s, h, d = 2, 4096, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    want = ref.mha_attention(q, k, v)
    got = ref.mha_attention_chunked(q, k, v, chunk=512)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_kv_len_masking():
    from repro.kernels import ref

    b, sq, skv, h, d = 3, 1, 64, 2, 16
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, h, d)), jnp.float32)
    kv_len = jnp.asarray([10, 32, 64], jnp.int32)
    out = ref.mha_attention(q, k, v, causal=False, kv_len=kv_len)
    # manual check for batch 0: only first 10 kv positions participate
    out0 = ref.mha_attention(q[:1], k[:1, :10], v[:1, :10], causal=False)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out0[0]), rtol=1e-5, atol=1e-5)
