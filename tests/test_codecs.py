"""Unified codec API: registry, container round-trips, payload accounting,
the codec service, and codec-backed checkpoints."""
import numpy as np
import pytest

from repro import codecs
from repro.codecs import adapters, available, container, get_codec

RNG = np.random.default_rng(0)
SHAPE = (12, 10, 8)
# the six this repo ships; the registry may grow, and parametrized tests
# below iterate available() so new codecs join the matrix automatically
SEED_CODECS = ["cpd", "nttd", "szlite", "tensor_ring", "ttd", "tucker"]
ALL_CODECS = sorted(available())


def _tensor() -> np.ndarray:
    rng = np.random.default_rng(7)
    x = (
        np.sin(np.linspace(0, 6, SHAPE[0]))[:, None, None]
        + np.cos(np.linspace(0, 3, SHAPE[1]))[None, :, None]
        + 0.1 * rng.normal(size=SHAPE)
    )
    return x.astype(np.float32)


def _fit(name: str):
    x = _tensor()
    if name == "nttd":
        return x, get_codec(name).fit(x, rank=4, hidden=8, epochs=3, batch_size=512)
    return x, get_codec(name).fit(x, 4000)


def _sample_indices(shape, n=23):
    rng = np.random.default_rng(3)
    return np.stack([rng.integers(0, s, size=n) for s in shape], axis=1)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_all_six():
    assert set(SEED_CODECS) <= set(available())
    for name in available():
        codec = get_codec(name)
        assert codec.name == name
        assert codec.encoded_cls.codec_name == name


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown codec 'nope'"):
        get_codec("nope")


# ---------------------------------------------------------------------------
# container round-trips (satellite: all six, bit-exact)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_CODECS)
def test_container_roundtrip_bit_exact(name):
    x, enc = _fit(name)
    blob = codecs.save_bytes(enc)
    enc2 = codecs.load_bytes(blob)
    assert type(enc2) is type(enc)
    # re-serialization is byte-identical and decode is bit-exact
    assert codecs.save_bytes(enc2) == blob
    idx = _sample_indices(x.shape)
    np.testing.assert_array_equal(enc.decode_at(idx), enc2.decode_at(idx))
    np.testing.assert_array_equal(
        np.asarray(enc.to_dense()), np.asarray(enc2.to_dense())
    )
    assert enc2.payload_bytes() == enc.payload_bytes()
    assert enc2.shape == enc.shape == SHAPE


@pytest.mark.parametrize("name", ALL_CODECS)
def test_decode_at_matches_dense_gather(name):
    x, enc = _fit(name)
    idx = _sample_indices(x.shape)
    gathered = np.asarray(enc.to_dense())[tuple(idx[:, k] for k in range(x.ndim))]
    np.testing.assert_allclose(enc.decode_at(idx), gathered, rtol=1e-6, atol=1e-6)


def test_container_rejects_bad_magic():
    with pytest.raises(ValueError, match="not a TensorCodec container"):
        codecs.load_bytes(b"XXXX" + b"\x00" * 64)


def test_container_rejects_unknown_codec_id():
    _, enc = _fit("ttd")
    blob = codecs.save_bytes(enc)
    # splice a bogus codec id of equal length over the header name field
    name = b"ttd"
    assert blob[8 : 8 + len(name)] == name
    bad = blob[:8] + b"xyz" + blob[8 + len(name):]
    with pytest.raises(ValueError, match="unknown codec id 'xyz'"):
        codecs.load_bytes(bad)


@pytest.mark.parametrize("cut", [5, 12, -3])
def test_container_rejects_truncated(cut):
    _, enc = _fit("cpd")
    blob = codecs.save_bytes(enc)
    with pytest.raises(ValueError, match="truncated|corrupt"):
        codecs.load_bytes(blob[:cut])


def test_container_rejects_corrupt_body():
    _, enc = _fit("tucker")
    blob = bytearray(codecs.save_bytes(enc))
    blob[-1] ^= 0xFF
    with pytest.raises(ValueError, match="checksum"):
        codecs.load_bytes(bytes(blob))


def test_legacy_headerless_nttd_blob_loads():
    from repro.core import serialization

    _, enc = _fit("nttd")
    legacy = serialization.save_bytes(enc.ct, np.float32)
    enc2 = codecs.load_bytes(legacy)
    assert isinstance(enc2, adapters.NTTDEncoded)
    idx = _sample_indices(SHAPE)
    np.testing.assert_array_equal(enc.decode_at(idx), enc2.decode_at(idx))


def test_container_file_io(tmp_path):
    _, enc = _fit("tensor_ring")
    path = str(tmp_path / "t.tcdc")
    n = container.save_file(path, enc)
    import os

    assert os.path.getsize(path) == n
    enc2 = container.load_file(path)
    np.testing.assert_array_equal(enc.to_dense(), enc2.to_dense())


# ---------------------------------------------------------------------------
# payload accounting (satellite: one convention everywhere)
# ---------------------------------------------------------------------------
def test_payload_accounting_conventions_agree():
    """Every codec accounts parameters at the SAME bytes_per_param (the
    paper's fp64 convention), so budget-matched comparisons are fair."""
    bpp = {get_codec(n).bytes_per_param for n in available()}
    assert bpp == {8}

    x = _tensor()
    # decomposition baselines: payload == n_params * 8, matching their
    # dataclasses' own convention
    for name, attr in [("ttd", "tt"), ("tucker", "tk"), ("cpd", "cp"),
                       ("tensor_ring", "tr")]:
        _, enc = _fit(name)
        inner = getattr(enc, attr)
        assert enc.payload_bytes() == inner.n_params * 8
        assert enc.payload_bytes() == inner.payload_bytes(8)
    # NTTD: the paper's bit-level count (theta fp64 + bit-packed pi + norm)
    _, enc = _fit("nttd")
    assert enc.payload_bytes() == enc.ct.payload_bytes(8)
    n_params = sum(
        int(np.prod(np.shape(v)))
        for v in __import__("jax").tree_util.tree_leaves(enc.ct.params)
    )
    from repro.core.codec import nttd_payload_bits

    assert enc.payload_bytes() == (nttd_payload_bits(n_params, SHAPE, 8) + 7) // 8
    # SZ-lite is entropy-coded: accounting is the true stored byte count
    _, enc = _fit("szlite")
    assert enc.payload_bytes() == enc.sz.payload_bytes()


def test_budget_is_respected():
    x = _tensor()
    budget = 3000
    for name in available():
        if name == "nttd":
            continue  # NTTD's budget search is architecture-quantized
        enc = get_codec(name).fit(x, budget)
        assert enc.payload_bytes() <= budget * 1.05, name


def test_szlite_budget_infeasible_raises():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(32, 32, 32)).astype(np.float32)  # noise: high floor
    with pytest.raises(ValueError, match="cannot meet budget"):
        get_codec("szlite").fit(x, 64)


def test_szlite_to_dense_does_not_alias_cache():
    x, enc = _fit("szlite")
    d = enc.to_dense()
    d *= 0.0
    idx = _sample_indices(SHAPE)
    np.testing.assert_array_equal(enc.decode_at(idx), enc.to_dense()[
        tuple(idx[:, k] for k in range(x.ndim))])
    assert np.abs(enc.to_dense()).max() > 0  # cache untouched by caller edit


def test_nttd_budget_to_rank_monotone():
    codec = get_codec("nttd")
    r_small = codec._rank_for_budget(SHAPE, 2000, {})
    r_big = codec._rank_for_budget(SHAPE, 20000, {})
    assert 1 <= r_small <= r_big
    with pytest.raises(ValueError, match="cannot meet budget"):
        codec._rank_for_budget(SHAPE, 16, {})


# ---------------------------------------------------------------------------
# cached inverse permutations (satellite)
# ---------------------------------------------------------------------------
def test_inv_pi_cached_and_correct():
    _, enc = _fit("nttd")
    ct = enc.ct
    inv = ct.inv_pi
    assert ct.inv_pi is inv  # cached, not recomputed
    for p, q in zip(ct.pi, inv):
        np.testing.assert_array_equal(p[q], np.arange(len(p)))


# ---------------------------------------------------------------------------
# serve/codec_service
# ---------------------------------------------------------------------------
def test_codec_service_direct_and_batched():
    from repro.serve.codec_service import CodecService

    svc = CodecService(max_batch=16)
    payloads = {}
    for name in ["ttd", "szlite"]:
        x, enc = _fit(name)
        info = svc.load(name, codecs.save_bytes(enc))
        assert info.codec == name
        payloads[name] = (x, enc)

    assert svc.payloads() == ["szlite", "ttd"]
    idx = _sample_indices(SHAPE, n=50)  # > max_batch: exercises chunking
    for name, (x, enc) in payloads.items():
        np.testing.assert_allclose(
            svc.decode_at(name, idx), enc.decode_at(idx), rtol=1e-7, atol=1e-7
        )
        assert svc.info(name).decode_calls >= 4  # ceil(50/16)

    # coalesced path: interleaved submits resolve per-ticket
    t0 = svc.submit("ttd", idx[:7])
    t1 = svc.submit("szlite", idx[7:20])
    t2 = svc.submit("ttd", idx[20:])
    out = svc.flush()
    np.testing.assert_allclose(out[t0], payloads["ttd"][1].decode_at(idx[:7]))
    np.testing.assert_allclose(out[t1], payloads["szlite"][1].decode_at(idx[7:20]))
    np.testing.assert_allclose(out[t2], payloads["ttd"][1].decode_at(idx[20:]))

    with pytest.raises(KeyError, match="no payload"):
        svc.decode_at("missing", idx)


def test_codec_service_rejects_malformed_at_submit():
    from repro.serve.codec_service import CodecService

    svc = CodecService()
    x, enc = _fit("ttd")
    svc.load("t", enc)
    idx = _sample_indices(SHAPE, n=5)

    with pytest.raises(ValueError, match=r"must be \[B, 3\]"):
        svc.submit("t", idx[:, :2])  # wrong width
    with pytest.raises(ValueError, match="out of range"):
        svc.submit("t", idx + 1000)
    with pytest.raises(ValueError, match="out of range"):
        svc.decode_at("t", idx - 100)  # direct path validates too
    with pytest.raises(ValueError, match="integral"):
        svc.submit("t", idx.astype(np.float64))
    with pytest.raises(KeyError, match="no payload"):
        svc.submit("missing", idx)
    assert svc.info("t").requests == 0  # rejected requests leave stats alone

    # a bad request never poisons queued good ones
    good = svc.submit("t", idx)
    out = svc.flush()
    np.testing.assert_allclose(out[good], enc.decode_at(idx))
    assert svc.failed == {}


# ---------------------------------------------------------------------------
# codec-backed checkpoints (tentpole consumer)
# ---------------------------------------------------------------------------
def test_checkpoint_codec_with_registry_codec():
    from repro.compress import checkpoint_codec as cc

    rng = np.random.default_rng(0)
    u = (rng.normal(size=(64, 4)) @ rng.normal(size=(4, 48))).astype(np.float32)
    tree = {"w": u, "b": rng.normal(size=(4,)).astype(np.float32)}
    payload, stats = cc.compress_tree(
        tree,
        cc.CodecCheckpointConfig(
            codec="ttd", min_elements=1024, min_fitness=0.9, budget_ratio=0.5
        ),
    )
    assert payload["b"]["kind"] == "raw"
    assert payload["w"]["kind"] == "ttd"
    restored = cc.decompress_tree(payload, tree)
    rel = np.linalg.norm(restored["w"] - u) / np.linalg.norm(u)
    assert rel < 0.2
    assert stats["leaves_codec"] == 1


def test_checkpoint_codec_infeasible_budget_falls_back_to_raw():
    from repro.compress import checkpoint_codec as cc

    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(64, 48)).astype(np.float32)}  # noise leaf
    payload, stats = cc.compress_tree(
        tree,
        cc.CodecCheckpointConfig(
            codec="szlite", min_elements=1024, budget_ratio=0.001
        ),
    )
    assert payload["w"]["kind"] == "raw"  # infeasible budget, no crash
    restored = cc.decompress_tree(payload, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])
    assert stats["leaves_raw"] == 1
