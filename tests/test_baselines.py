"""Competitor baselines: TT-SVD, CP-ALS, Tucker, TR, SZ-lite."""
import numpy as np

from repro.core import cpd, szlite, tensor_ring, ttd, tucker

RNG = np.random.default_rng(0)


def test_ttsvd_exact_on_planted_rank():
    g1 = RNG.normal(size=(1, 20, 4))
    g2 = RNG.normal(size=(4, 18, 4))
    g3 = RNG.normal(size=(4, 16, 1))
    x = np.einsum("aib,bjc,ckd->ijk", g1, g2, g3)
    t = ttd.tt_svd(x, max_rank=4)
    assert t.fitness(x) > 0.9999


def test_ttsvd_eps_guarantee():
    x = RNG.normal(size=(20, 18, 16))
    for eps in [0.3, 0.5, 0.8]:
        t = ttd.tt_svd(x, eps=eps)
        err = np.linalg.norm(x - t.to_dense()) / np.linalg.norm(x)
        assert err <= eps + 1e-9, (eps, err)


def test_ttsvd_rank_budget_monotone():
    shape = (30, 30, 30)
    p1 = ttd.tt_rank_for_budget(shape, 5000)
    p2 = ttd.tt_rank_for_budget(shape, 20000)
    assert p2 >= p1
    assert ttd._tt_params(shape, p2) <= 20000


def test_cp_als_recovers_planted():
    a, b, c = RNG.normal(size=(20, 3)), RNG.normal(size=(18, 3)), RNG.normal(size=(16, 3))
    x = np.einsum("ir,jr,kr->ijk", a, b, c)
    d = cpd.cp_als(x, 3, iters=80)
    assert d.fitness(x) > 0.999


def test_cp_als_4order():
    fs = [RNG.normal(size=(10, 2)) for _ in range(4)]
    x = np.einsum("ir,jr,kr,lr->ijkl", *fs)
    d = cpd.cp_als(x, 2, iters=80)
    assert d.fitness(x) > 0.999


def test_tucker_hooi_exact_on_planted():
    core = RNG.normal(size=(3, 3, 3))
    us = [np.linalg.qr(RNG.normal(size=(n, 3)))[0] for n in (20, 18, 16)]
    x = np.einsum("abc,ia,jb,kc->ijk", core, *us)
    t = tucker.tucker_hooi(x, [3, 3, 3])
    assert t.fitness(x) > 0.9999


def test_tucker_hooi_beats_or_matches_hosvd():
    x = RNG.normal(size=(15, 14, 13))
    hosvd = tucker.tucker_hooi(x, [4, 4, 4], iters=0)
    hooi = tucker.tucker_hooi(x, [4, 4, 4], iters=8)
    assert hooi.fitness(x) >= hosvd.fitness(x) - 1e-9


def test_tensor_ring_reconstructs():
    g1 = RNG.normal(size=(1, 12, 3))
    g2 = RNG.normal(size=(3, 11, 3))
    g3 = RNG.normal(size=(3, 10, 1))
    x = np.einsum("aib,bjc,ckd->ijk", g1, g2, g3)  # TT is a special TR
    t = tensor_ring.tr_svd(x, 4)
    assert t.fitness(x) > 0.99


def test_szlite_error_bound_and_ratio():
    smooth = np.cumsum(RNG.normal(size=20000) * 0.01).reshape(100, 200)
    for eb in [1e-2, 1e-3]:
        c = szlite.compress(smooth, eb)
        rec = szlite.decompress(c)
        assert np.abs(rec - smooth).max() <= eb + 1e-12
    c = szlite.compress(smooth, 1e-2)
    assert smooth.size * 8 / c.payload_bytes() > 8  # smooth data compresses hard


def test_budget_helpers():
    shape = (40, 30, 20)
    r = cpd.cp_rank_for_budget(shape, 5000)
    assert (sum(shape) + 1) * r <= 5000
    ranks = tucker.tucker_ranks_for_budget(shape, 8000)
    n = int(np.prod(ranks)) + sum(a * b for a, b in zip(shape, ranks))
    assert n <= 8000
