"""repro.fleet: consistent-hash routing, the multi-instance frontend,
warm rebalancing, metrics roll-up, and container integrity on the fleet
path."""
import numpy as np
import pytest
import test_container_corruption as container_corruption

from repro.codecs import container, get_codec
from repro.fleet import FleetFrontend, HashRing, PayloadRoute, collect, rebalance
from repro.serve.codec_service import CodecService, NotOwnedError, Ownership
from repro.stream import write_chunked

SHAPE = (32, 32, 16)


@pytest.fixture(scope="module")
def payload():
    rng = np.random.default_rng(0)
    x = rng.random(SHAPE).astype(np.float32)
    return get_codec("ttd").fit(x, max_rank=4)


@pytest.fixture()
def payload_path(payload, tmp_path):
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, payload, chunk_bytes=1024)
    return path


def _idx(n=200, seed=1, shape=SHAPE):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, n) for s in shape], axis=1)


def _single(path, tile_entries=None, **kw):
    svc = CodecService(**kw)
    svc.load_stream("t", path, tile_entries=tile_entries)
    return svc


# ---------------------------------------------------------------------------
# hash ring
# ---------------------------------------------------------------------------
def test_ring_deterministic_and_distinct_replicas():
    a = HashRing(["i0", "i1", "i2", "i3"], replication=2)
    b = HashRing(["i3", "i1", "i0", "i2"], replication=2)  # order-independent
    for k in range(50):
        owners = a.owners(f"key{k}")
        assert owners == b.owners(f"key{k}")
        assert len(owners) == 2 and len(set(owners)) == 2
    assert a.owner("key0") == a.owners("key0")[0]


def test_ring_membership_change_moves_few_keys():
    ring = HashRing(["i0", "i1", "i2", "i3"])
    keys = [f"p/c{k}" for k in range(400)]
    before = {k: ring.owner(k) for k in keys}
    ring.remove("i3")
    moved = [k for k in keys if before[k] != ring.owner(k)]
    # ONLY keys i3 owned move — consistent hashing's whole point
    assert all(before[k] == "i3" for k in moved)
    assert len(moved) == sum(1 for k in keys if before[k] == "i3")
    ring.add("i3")  # re-adding restores the original assignment
    assert {k: ring.owner(k) for k in keys} == before


def test_ring_rejects_bad_membership():
    ring = HashRing(["i0"])
    with pytest.raises(ValueError, match="already"):
        ring.add("i0")
    with pytest.raises(KeyError, match="not on the ring"):
        ring.remove("nope")
    ring.remove("i0")
    with pytest.raises(RuntimeError, match="empty"):
        ring.owner("k")


# ---------------------------------------------------------------------------
# payload routing
# ---------------------------------------------------------------------------
def test_route_uses_recorded_entry_ranges(payload_path):
    name, chunks = container.chunk_index(payload_path)
    assert all(c.entry_start is not None for c in chunks)
    route = PayloadRoute("t", SHAPE, chunks)
    n = int(np.prod(SHAPE))
    flat = np.arange(n, dtype=np.int64)
    cids = route.chunk_of(flat)
    # every chunk id valid, monotone, and matching the recorded partition
    assert cids.min() == 0 and cids.max() == len(chunks) - 1
    for i, c in enumerate(chunks):
        assert (cids[c.entry_start : c.entry_stop] == i).all()


def test_route_uniform_fallback_and_tiles():
    chunks = [container.ChunkEntry(0, 10, 0), container.ChunkEntry(10, 10, 0)]
    route = PayloadRoute("t", (8, 4), chunks, tile_entries=8)
    flat = np.arange(32, dtype=np.int64)
    assert (route.chunk_of(flat[:16]) == 0).all()
    assert (route.chunk_of(flat[16:]) == 1).all()
    assert route.n_tiles == 4 and route.tiled
    assert (route.group_of(flat) == flat // 8).all()


def test_route_rejects_broken_partition():
    chunks = [
        container.ChunkEntry(0, 10, 0, entry_start=0, entry_stop=10),
        container.ChunkEntry(10, 10, 0, entry_start=12, entry_stop=32),  # gap
    ]
    with pytest.raises(ValueError, match="partition"):
        PayloadRoute("t", (8, 4), chunks)


# ---------------------------------------------------------------------------
# frontend correctness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tile_entries", [None, 64])
def test_fleet_bit_identical_to_single_instance(payload_path, tile_entries):
    single = CodecService()
    single.load_stream("t", payload_path, tile_entries=tile_entries)
    fleet = FleetFrontend(4)
    fleet.load_stream("t", payload_path, tile_entries=tile_entries)
    for seed in range(3):
        idx = _idx(seed=seed)
        np.testing.assert_array_equal(
            fleet.decode_at("t", idx), single.decode_at("t", idx)
        )


def test_fleet_tickets_resolve_in_request_order(payload_path):
    fleet = FleetFrontend(3)
    fleet.load_stream("t", payload_path, tile_entries=64)
    single = _single(payload_path, tile_entries=64)
    batches = [_idx(n, seed=n) for n in (7, 113, 64)]
    tickets = [fleet.submit("t", b) for b in batches]
    out = fleet.flush()
    assert not fleet.failed
    for t, b in zip(tickets, batches):
        np.testing.assert_array_equal(out[t], single.decode_at("t", b))


def test_fleet_validates_before_fanout(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path)
    with pytest.raises(KeyError, match="no payload"):
        fleet.submit("nope", _idx())
    with pytest.raises(ValueError, match=r"must be \[B, 3\]"):
        fleet.submit("t", np.zeros((4, 2), np.int64))
    with pytest.raises(ValueError, match="out of range"):
        fleet.submit("t", np.full((1, 3), 99, np.int64))
    with pytest.raises(ValueError, match="integral"):
        fleet.submit("t", np.zeros((1, 3), np.float32))
    assert fleet.flush() == {}  # nothing slipped into the queue


def test_fleet_empty_batch(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path)
    out = fleet.decode_at("t", np.zeros((0, 3), np.int64))
    assert out.shape == (0,)


def test_decode_at_holds_concurrent_results_for_next_flush(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    queued = fleet.submit("t", _idx(10))
    fleet.decode_at("t", _idx(5, seed=9))  # resolves the queued ticket too
    out = fleet.flush()
    assert queued in out and out[queued].shape == (10,)


def test_early_resolved_failures_reported_once_by_next_flush(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    doomed = fleet.submit("t", _idx(4))
    fleet.unload("t")  # doomed will fail when resolved
    fleet.load_stream("u", payload_path)
    fleet.decode_at("u", _idx(3))  # resolves doomed; failure must be held
    assert not fleet.failed  # ...deferred, not reported early
    out = fleet.flush()
    assert doomed in fleet.failed and doomed not in out
    fleet.flush()
    assert doomed not in fleet.failed  # reported exactly once, not forever


def test_only_owners_materialize_untiled_payload(payload_path):
    fleet = FleetFrontend(4)
    route = fleet.load_stream("t", payload_path)  # chunk-granular routing
    fleet.decode_at("t", _idx(400))
    owners = {
        fleet.ring.owner(route.chunk_key(c)) for c in range(route.n_chunks)
    }
    for iid, svc in fleet.services.items():
        materialized = svc._streams["t"].enc is not None
        assert materialized == (iid in owners), iid


def test_shape_peek_body_is_accounted_and_evictable(payload_path):
    """The fleet loader's shape peek materializes a body — it must join
    the LRU ledger and be droppable once ownership moves away entirely."""
    svc = CodecService()
    svc.load_stream("t", payload_path, tile_entries=64)
    svc.shape_of("t")
    assert svc.cache_stats.resident_bytes > 0  # accounted, not off-ledger
    svc.set_ownership("t", Ownership(chunk_ids=frozenset(), tile_ids=frozenset()))
    assert svc.drop_unowned("t") > 0
    assert svc._streams["t"].enc is None
    assert svc.cache_stats.resident_bytes == 0


def test_not_owned_error_on_misroute(payload_path):
    svc = CodecService()
    svc.load_stream("t", payload_path)
    svc.set_ownership("t", Ownership(chunk_ids=frozenset()))
    with pytest.raises(NotOwnedError, match="not owned"):
        svc.decode_at("t", _idx(4))


def test_replication_spreads_replicas(payload_path):
    fleet = FleetFrontend(4, replication=2)
    route = fleet.load_stream("t", payload_path, tile_entries=64)
    # every tile key has two distinct owners; both hold the ownership filter
    for tid in range(route.n_tiles):
        owners = fleet.ring.owners(route.tile_key(tid))
        assert len(set(owners)) == 2
    np.testing.assert_array_equal(
        fleet.decode_at("t", _idx()), _single(payload_path).decode_at("t", _idx())
    )


def test_admission_control_backpressure(payload_path):
    idx = _idx(500)
    fleet = FleetFrontend(2, max_inflight_bytes=2048)
    fleet.load_stream("t", payload_path, tile_entries=64)
    tickets = [fleet.submit("t", idx[s : s + 50]) for s in range(0, 500, 50)]
    out = fleet.flush()
    assert not fleet.failed
    assert fleet.backpressure_flushes > 0  # budget forced early flushes
    got = np.concatenate([out[t] for t in tickets])
    single = _single(payload_path, tile_entries=64)
    np.testing.assert_array_equal(got, single.decode_at("t", idx))


# ---------------------------------------------------------------------------
# acceptance: sharded residency + live rebalance
# ---------------------------------------------------------------------------
def test_resident_bytes_shard_to_quarter(payload_path):
    """4-instance tiled fleet: every instance resident ~1/4 of the single
    instance (body replicated, tiles sharded — tiles dominate here)."""
    idx = np.stack(
        np.meshgrid(*[np.arange(s) for s in SHAPE], indexing="ij"), axis=-1
    ).reshape(-1, len(SHAPE))  # EVERY entry -> every tile decoded once
    single = _single(payload_path, tile_entries=64)
    single.decode_at("t", idx)
    total = single.cache_stats.resident_bytes

    fleet = FleetFrontend(4)
    fleet.load_stream("t", payload_path, tile_entries=64)
    out = fleet.decode_at("t", idx)
    np.testing.assert_array_equal(out, single.decode_at("t", idx))
    residents = [
        svc.cache_stats.resident_bytes for svc in fleet.services.values()
    ]
    for r in residents:
        assert r < 0.45 * total, (residents, total)
    # replication=1: fleet-wide tile bytes equal the single instance's
    # (each tile cached exactly once); only the small body is per-instance
    tile_bytes = lambda svc: sum(  # noqa: E731
        e.nbytes for k, e in svc._cache.items() if k[0] == "tile"
    )
    assert sum(tile_bytes(s) for s in fleet.services.values()) == tile_bytes(single)


def test_live_rebalance_4_to_3_zero_failed_tickets(payload_path):
    """Acceptance: a ring change mid-query-stream completes with zero
    failed tickets and stays bit-identical."""
    fleet = FleetFrontend(4)
    fleet.load_stream("t", payload_path, tile_entries=64)
    batches = [_idx(60, seed=s) for s in range(6)]
    tickets = [fleet.submit("t", b) for b in batches[:3]]
    report = rebalance(fleet, remove=["i3"])  # drains the 3 queued tickets
    assert fleet.instances() == ["i0", "i1", "i2"]
    assert report.removed == ["i3"] and report.total_moved > 0
    tickets += [fleet.submit("t", b) for b in batches[3:]]
    out = fleet.flush()
    assert not fleet.failed  # ZERO failed tickets across the change
    single = _single(payload_path, tile_entries=64)
    for t, b in zip(tickets, batches):
        np.testing.assert_array_equal(out[t], single.decode_at("t", b))


def test_rebalance_scale_up_warm_handoff(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    idx = _idx(400)
    fleet.decode_at("t", idx)  # warm the 2-instance caches
    report = rebalance(fleet, add=["i2", "i3"])
    assert fleet.instances() == ["i0", "i1", "i2", "i3"]
    assert report.tiles_warmed["t"] > 0  # joiners start warm, not cold
    assert report.bytes_dropped > 0  # old owners dropped moved tiles
    misses_before = collect(fleet).fleet.misses
    np.testing.assert_array_equal(
        fleet.decode_at("t", idx),
        _single(payload_path, tile_entries=64).decode_at("t", idx),
    )
    # the handoff means the re-query is mostly warm: few new tile decodes
    new_tile_misses = collect(fleet).fleet.misses - misses_before
    assert new_tile_misses <= 2 + len(fleet.services)  # bodies, not tiles


def test_rebalance_rejects_bad_membership(payload_path):
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path)
    with pytest.raises(ValueError, match="already"):
        rebalance(fleet, add=["i0"])
    with pytest.raises(KeyError, match="not in the fleet"):
        rebalance(fleet, remove=["nope"])
    with pytest.raises(ValueError, match="empty fleet"):
        rebalance(fleet, remove=["i0", "i1"])


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_metrics_roll_up(payload_path):
    fleet = FleetFrontend(3)
    fleet.load_stream("t", payload_path, tile_entries=64)
    idx = _idx(300)
    fleet.decode_at("t", idx)
    fleet.decode_at("t", idx)  # second pass: hits
    m = collect(fleet)
    assert set(m.instances) == {"i0", "i1", "i2"}
    assert m.fleet.hits == sum(i.cache.hits for i in m.instances.values())
    assert m.fleet.resident_bytes == sum(
        i.cache.resident_bytes for i in m.instances.values()
    )
    assert m.per_payload["t"].hits == m.fleet.hits  # single payload
    assert 0 < m.per_payload["t"].hit_rate < 1
    for im in m.instances.values():
        if im.flushes:
            assert im.decode_p50_ms is not None
            assert im.decode_p99_ms >= im.decode_p50_ms
    d = m.as_dict()
    assert d["instances"]["i0"]["per_payload"]["t"]["misses"] >= 0
    import json

    json.dumps(d)  # JSON-able for BENCH_fleet.json


def test_metrics_zero_flush_instance_reports_none_not_crash(payload_path):
    """Satellite: an instance that never flushed reports None percentiles
    (both windowed and all-time), not a crash, and zero flushes."""
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    m = collect(fleet)  # loaded but never queried: all instances idle
    for im in m.instances.values():
        assert im.flushes == 0
        assert im.decode_p50_ms is None and im.decode_p99_ms is None
        assert im.decode_p50_ms_total is None and im.decode_p99_ms_total is None
    d = m.as_dict()
    assert d["instances"]["i0"]["decode_p50_ms"] is None
    assert d["instances"]["i0"]["decode_p99_ms_total"] is None

    # after queries, both views populate and all-time tracks the window
    fleet.decode_at("t", _idx(100))
    m2 = collect(fleet)
    flushed = [im for im in m2.instances.values() if im.flushes]
    assert flushed
    for im in flushed:
        assert im.decode_p99_ms >= im.decode_p50_ms > 0
        assert im.decode_p99_ms_total >= im.decode_p50_ms_total > 0


def test_metrics_collect_survives_transport_dying_mid_poll(payload_path):
    """Satellite: a transport that dies BETWEEN routing and the stats
    poll is demoted to the excluded list of the same snapshot."""
    from repro.fleet.transport import TransportError

    # replication=2 so the survivors can still route the dead member's
    # groups afterwards
    fleet = FleetFrontend(3, replication=2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    fleet.decode_at("t", _idx(100))

    def dead_stats():
        raise TransportError("i1: worker killed during metrics poll")

    fleet.transports["i1"].stats = dead_stats
    m = collect(fleet)
    assert set(m.instances) == {"i0", "i2"}  # the dead row is absent...
    assert m.excluded == ["i1"]  # ...and listed as excluded
    assert "i1" in fleet.excluded  # routing skips it from now on
    # the fleet keeps answering (and collecting) on the survivors
    fleet.decode_at("t", _idx(80, seed=9))
    m2 = collect(fleet)
    assert set(m2.instances) == {"i0", "i2"} and m2.excluded == ["i1"]


def test_per_payload_cache_stats_on_service(payload_path, tmp_path, payload):
    """Satellite: CodecService.cache_stats carries a per-payload breakdown."""
    p2 = str(tmp_path / "q.tcdc")
    write_chunked(p2, payload, chunk_bytes=1024)
    svc = CodecService()
    svc.load_stream("a", payload_path, tile_entries=64)
    svc.load_stream("b", p2)
    idx = _idx(50)
    svc.decode_at("a", idx)
    svc.decode_at("a", idx)
    svc.decode_at("b", idx)
    per = svc.cache_stats.per_payload
    assert set(per) == {"a", "b"}
    assert per["a"].hits > 0 and per["a"].misses > 0
    assert per["b"].misses == 1  # one body materialization
    assert per["a"].resident_bytes + per["b"].resident_bytes == (
        svc.cache_stats.resident_bytes
    )
    assert svc.cache_stats.hits == per["a"].hits + per["b"].hits
    svc.unload("a")
    assert svc.cache_stats.per_payload["a"].resident_bytes == 0
    assert svc.cache_stats.per_payload["a"].evictions > 0


# ---------------------------------------------------------------------------
# container v3 integrity on the fleet path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "corruptor, match",
    [
        (container_corruption.corrupt_chunk_byte, "chunk checksum"),
        (container_corruption.truncate_footer, "truncated|footer"),
        (container_corruption.index_past_eof, "outside data region"),
    ],
)
def test_fleet_rejects_corrupt_containers(payload_path, tmp_path, corruptor, match):
    bad = str(tmp_path / "bad.tcdc")
    corruptor(payload_path, bad)
    fleet = FleetFrontend(3)
    with pytest.raises(ValueError, match=match):
        fleet.load_stream("t", bad, tile_entries=64)
    # nothing half-registered: the fleet still serves other payloads
    fleet.load_stream("ok", payload_path)
    assert fleet.decode_at("ok", _idx(4)).shape == (4,)


def test_failed_reload_unregisters_cleanly(payload_path, tmp_path):
    """Re-loading a served name with a corrupt file must not leave a
    stale route pointing at unloaded instance registrations."""
    fleet = FleetFrontend(2)
    fleet.load_stream("t", payload_path, tile_entries=64)
    bad = str(tmp_path / "bad.tcdc")
    container_corruption.corrupt_chunk_byte(payload_path, bad)
    with pytest.raises(ValueError, match="chunk checksum"):
        fleet.load_stream("t", bad)
    assert "t" not in fleet.payloads()  # fully unregistered, not half
    fleet.load_stream("t", payload_path)  # and immediately reloadable
    assert fleet.decode_at("t", _idx(4)).shape == (4,)


# ---------------------------------------------------------------------------
# prefetch: background warm + pipelined tile inputs change nothing observable
# ---------------------------------------------------------------------------
def _drain_prefetch(svc):
    if svc._prefetch_pool is not None:
        svc._prefetch_pool.shutdown(wait=True)


def test_prefetch_bit_identical_service(payload_path):
    """prefetch=True overlaps input-side work (payload warm, chunk reads,
    tile index blocks) with decode — answers AND cache counters must be
    bit-identical to the synchronous path."""
    queries = [_idx(200, seed=s) for s in (1, 2, 3)] + [_idx(200, seed=1)]
    outs, stats, infos = {}, {}, {}
    for pf in (False, True):
        svc = _single(payload_path, tile_entries=128, prefetch=pf)
        outs[pf] = [svc.decode_at("t", q) for q in queries]
        _drain_prefetch(svc)
        stats[pf] = svc.cache_stats.as_dict()
        info = svc.info("t")
        infos[pf] = (info.requests, info.entries_decoded, info.decode_calls,
                     info.cache_hits, info.cache_misses)
    for a, b in zip(outs[False], outs[True]):
        assert np.array_equal(a, b), "prefetch changed decoded values"
    assert stats[False] == stats[True]
    assert infos[False] == infos[True]


def test_prefetch_bit_identical_fleet(payload_path):
    """Same guarantee one level up: a prefetching fleet answers exactly
    like a non-prefetching one and like a single resident service."""
    queries = [_idx(150, seed=s) for s in (4, 5)]
    ref_svc = _single(payload_path, tile_entries=128)
    want = [ref_svc.decode_at("t", q) for q in queries]
    for pf in (False, True):
        fleet = FleetFrontend(3, prefetch=pf)
        fleet.load_stream("t", payload_path, tile_entries=128)
        got = [fleet.decode_at("t", q) for q in queries]
        fleet.close()
        for g, w in zip(got, want):
            assert np.array_equal(g, w)


def test_prefetch_warm_materializes_in_background(payload_path):
    """load_stream with prefetch on parses the body ahead of the first
    query; the materialization still counts exactly one miss."""
    svc = _single(payload_path, prefetch=True)
    _drain_prefetch(svc)  # warm has landed before any query
    assert svc._streams["t"].enc is not None
    assert svc.info("t").cache_misses == 1
    out = svc.decode_at("t", _idx(50))
    assert out.shape == (50,)
    assert svc.info("t").cache_misses == 1  # no double materialization


def test_empty_query_accounting(payload_path):
    """An empty query decodes nothing: decode_calls stays 0 on BOTH the
    tiled and untiled paths (the untiled path used to report 1)."""
    empty = np.empty((0, len(SHAPE)), dtype=np.int64)
    for tile_entries in (None, 128):
        svc = _single(payload_path, tile_entries=tile_entries)
        out = svc.decode_at("t", empty)
        assert out.shape == (0,)
        info = svc.info("t")
        assert info.requests == 1
        assert info.entries_decoded == 0
        assert info.decode_calls == 0
