"""repro.stream: slab sources, incremental fitters, the chunked container,
and lazy serving (CodecService.load_stream + caches)."""
import numpy as np
import pytest

from repro import codecs
from repro.codecs import container, get_codec
from repro.serve.codec_service import CodecService
from repro.stream import (
    ChunkedWriter,
    DenseSource,
    MMapTensorSource,
    SyntheticTensorSource,
    fit_stream,
    write_chunked,
    write_tensor_file,
)

SHAPE = (16, 12, 10)


def _source(slab_entries=300, seed=3):
    return SyntheticTensorSource(SHAPE, slab_entries=slab_entries, seed=seed)


def _materialize(src) -> np.ndarray:
    x = np.zeros(src.shape, np.float32)
    for slab in src.iter_slabs():
        x[tuple(slab.indices[:, k] for k in range(len(src.shape)))] = slab.values
    return x


def _sample_indices(shape, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, size=n) for s in shape], axis=1)


# ---------------------------------------------------------------------------
# slab sources
# ---------------------------------------------------------------------------
def test_slab_source_deterministic_resumable_cursor():
    src = _source()
    a, b = src.slab_at(2), src.slab_at(2)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.values, b.values)
    # resuming mid-stream sees exactly the tail an uninterrupted run sees
    tail = [s.cursor for s in src.iter_slabs(start=3)]
    assert tail == list(range(3, src.n_slabs))
    with pytest.raises(IndexError, match="cursor"):
        src.slab_at(src.n_slabs)


def test_slab_sources_agree_on_layout(tmp_path):
    src = _source()
    x = _materialize(src)
    dense = DenseSource(x, slab_entries=300)
    path = str(tmp_path / "t.bin")
    write_tensor_file(path, x)
    mm = MMapTensorSource(path, x.shape, np.float32, slab_entries=300)
    assert dense.n_slabs == mm.n_slabs == src.n_slabs
    for c in range(src.n_slabs):
        np.testing.assert_array_equal(dense.slab_at(c).values, src.slab_at(c).values)
        np.testing.assert_array_equal(mm.slab_at(c).values, src.slab_at(c).values)
        np.testing.assert_array_equal(mm.slab_at(c).indices, src.slab_at(c).indices)


def test_mmap_source_rejects_short_file(tmp_path):
    path = str(tmp_path / "short.bin")
    np.zeros(10, np.float32).tofile(path)
    with pytest.raises(ValueError, match="entries on disk"):
        MMapTensorSource(path, SHAPE, np.float32)


# ---------------------------------------------------------------------------
# chunked container v3
# ---------------------------------------------------------------------------
def _tt_payload():
    src = _source()
    x = _materialize(src)
    return get_codec("ttd").fit(x, max_rank=4)


def test_chunked_roundtrip_bit_exact(tmp_path):
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    import os

    n = write_chunked(path, enc, chunk_bytes=512)
    assert os.path.getsize(path) == n
    enc2 = container.load_file(path)
    assert type(enc2) is type(enc)
    assert enc2.to_bytes() == enc.to_bytes()  # chunks concatenate to the body
    np.testing.assert_array_equal(enc.to_dense(), enc2.to_dense())
    # lazy open sees the same chunks the loader reassembled
    name, chunks, view = container.open_chunks(path)
    assert name == "ttd" and len(chunks) > 1
    assert b"".join(container.read_chunk(view, c) for c in chunks) == enc.to_bytes()
    view.release()


def test_open_chunks_on_monolithic_file(tmp_path):
    enc = _tt_payload()
    path = str(tmp_path / "mono.tcdc")
    container.save_file(path, enc)
    name, chunks, view = container.open_chunks(path)
    assert name == "ttd" and len(chunks) == 1
    assert container.read_chunk(view, chunks[0]) == enc.to_bytes()
    view.release()


@pytest.mark.parametrize("cut", [1, 11, 200])
def test_chunked_truncated_file_rejected(tmp_path, cut):
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    with open(path, "rb") as f:
        blob = f.read()
    with pytest.raises(ValueError, match="truncated|corrupt"):
        codecs.load_bytes(blob[:-cut])


def test_chunked_corrupt_chunk_rejected(tmp_path):
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF  # flip a bit inside some chunk
    with pytest.raises(ValueError, match="chunk checksum"):
        codecs.load_bytes(bytes(blob))


def test_chunked_writer_aborted_file_rejected(tmp_path):
    path = str(tmp_path / "abort.tcdc")
    try:
        with ChunkedWriter(path, "ttd") as w:
            w.append(b"some chunk")
            raise RuntimeError("producer died")
    except RuntimeError:
        pass
    with pytest.raises(ValueError, match="truncated"):
        codecs.load_bytes(open(path, "rb").read())


def test_chunked_writer_rejects_use_after_close(tmp_path):
    w = ChunkedWriter(str(tmp_path / "w.tcdc"), "ttd")
    w.append(b"x")
    w.close()
    with pytest.raises(ValueError, match="closed"):
        w.append(b"y")


def test_load_stream_corrupt_chunk_is_clean_error_not_garbage(tmp_path):
    """Lazy loading defers chunk reads — a flipped byte must surface as a
    checksum ValueError at first decode, never as silently wrong values."""
    import test_container_corruption as container_corruption

    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    bad = str(tmp_path / "bad.tcdc")
    container_corruption.corrupt_chunk_byte(path, bad)
    svc = CodecService()
    svc.load_stream("t", bad)  # index parses fine; corruption is in a body
    with pytest.raises(ValueError, match="chunk checksum"):
        svc.decode_at("t", _sample_indices(SHAPE))


@pytest.mark.parametrize("mode, match", [
    ("truncate_footer", "truncated|footer"),
    ("index_past_eof", "outside data region"),
])
def test_load_stream_rejects_broken_chunk_index(tmp_path, mode, match):
    import test_container_corruption as container_corruption

    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    bad = str(tmp_path / "bad.tcdc")
    getattr(container_corruption, mode)(path, bad)
    svc = CodecService()
    with pytest.raises(ValueError, match=match):
        svc.load_stream("t", bad)
    assert svc.payloads() == []


def test_chunk_index_records_entry_ranges(tmp_path):
    """write_chunked stamps each chunk with its slice of the flat entry
    space — the routing partition the fleet ring shards ownership by."""
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    name, chunks = container.chunk_index(path)
    assert name == "ttd" and len(chunks) > 1
    n = int(np.prod(SHAPE))
    assert chunks[0].entry_start == 0 and chunks[-1].entry_stop == n
    for a, b in zip(chunks[:-1], chunks[1:]):
        assert a.entry_stop == b.entry_start  # contiguous partition
    # a writer that records no ranges still produces a loadable file
    plain = str(tmp_path / "plain.tcdc")
    with ChunkedWriter(plain, "ttd") as w:
        w.append(enc.to_bytes())
    _, plain_chunks = container.chunk_index(plain)
    assert plain_chunks[0].entry_start is None
    assert container.load_file(plain).to_bytes() == enc.to_bytes()


# ---------------------------------------------------------------------------
# fit_stream
# ---------------------------------------------------------------------------
def test_fallback_accumulate_matches_one_shot_fit():
    src = _source()
    x = _materialize(src)
    enc_stream = fit_stream("tucker", src, 4000)
    enc_fit = get_codec("tucker").fit(x, 4000)
    assert codecs.save_bytes(enc_stream) == codecs.save_bytes(enc_fit)


def test_nttd_resume_from_cursor_bit_identical():
    src = _source()
    opts = dict(rank=3, hidden=6, steps_per_slab=2, batch_size=256, seed=0)
    full = get_codec("nttd").fit_stream(src, **opts)
    # same slabs split across two calls sharing one fitter
    codec = get_codec("nttd")
    fitter = codec.stream_fitter(src.shape, **opts)
    codec.fit_stream(src, stop=3, fitter=fitter)
    resumed = codec.fit_stream(src, start=3, fitter=fitter)
    assert codecs.save_bytes(resumed) == codecs.save_bytes(full)


def test_fit_stream_resume_rejects_new_opts():
    codec = get_codec("nttd")
    fitter = codec.stream_fitter(SHAPE, rank=3, hidden=6)
    with pytest.raises(ValueError, match="resume"):
        codec.fit_stream(_source(), 4000, fitter=fitter)


def test_nttd_budget_translation_matches_fit():
    codec = get_codec("nttd")
    fitter = codec.stream_fitter(SHAPE, budget=20000)
    assert fitter.cfg.rank == codec._rank_for_budget(SHAPE, 20000, {})


def test_ttice_streaming_tracks_tt_svd():
    src = _source(slab_entries=250)  # not a multiple of the 120-entry rows
    x = _materialize(src)
    enc = fit_stream("ttd", src, max_rank=6)
    ref = get_codec("ttd").fit(x, max_rank=6)
    assert enc.fitness(x) > ref.fitness(x) - 0.05
    assert max(enc.tt.ranks) <= 6


def test_ttice_extra_passes_are_no_ops():
    src = _source()
    x = _materialize(src)
    once = fit_stream("ttd", src, max_rank=6)
    again = fit_stream("ttd", src, max_rank=6, passes=3)
    assert codecs.save_bytes(again) == codecs.save_bytes(once)
    # a partial cursor range re-read must not trip the contiguity check
    partial = get_codec("ttd").fit_stream(src, max_rank=6, stop=3, passes=2)
    assert partial.shape == SHAPE


def test_ttice_rejects_non_contiguous_slabs():
    src = _source()
    fitter = get_codec("ttd").stream_fitter(SHAPE, max_rank=4)
    slab = src.slab_at(1)  # starts mid-tensor
    with pytest.raises(ValueError, match="contiguous"):
        fitter.update(slab.indices, slab.values)


def test_nttd_stream_fitness_parity_with_one_shot():
    """Acceptance: fit_stream within 0.05 of one-shot fit on a RAM-sized
    control tensor (same rank/lr/seed, matched optimization budgets)."""
    shape = (32, 24, 16)
    src = SyntheticTensorSource(shape, slab_entries=2048, seed=5)
    x = _materialize(src)
    one_shot = get_codec("nttd").fit(
        x, rank=4, hidden=8, epochs=10, batch_size=4096, lr=2e-2,
        init_reorder=False, update_reorder=False, seed=0,
    )
    stream = fit_stream(
        "nttd", src, rank=4, hidden=8, steps_per_slab=4, batch_size=4096,
        lr=2e-2, passes=10, seed=0,
    )
    f_one, f_stream = one_shot.fitness(x), stream.fitness(x)
    assert f_stream > f_one - 0.05, (f_one, f_stream)


# ---------------------------------------------------------------------------
# serve: lazy load_stream + caches
# ---------------------------------------------------------------------------
def test_load_stream_lazy_and_bit_exact(tmp_path):
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    svc = CodecService()
    info = svc.load_stream("t", path)
    assert info.codec == "ttd"
    assert svc._streams["t"].enc is None  # nothing materialized yet
    idx = _sample_indices(SHAPE)
    np.testing.assert_array_equal(svc.decode_at("t", idx), enc.decode_at(idx))
    assert svc._streams["t"].enc is not None
    assert svc.cache_stats.misses == 1
    np.testing.assert_array_equal(svc.decode_at("t", idx), enc.decode_at(idx))
    assert svc.cache_stats.hits == 1
    assert info.payload_bytes == enc.payload_bytes()  # refreshed on load
    assert svc.payloads() == ["t"]
    svc.unload("t")
    assert svc.payloads() == []


def test_load_stream_rejects_unknown_codec_id(tmp_path):
    path = str(tmp_path / "bad.tcdc")
    with ChunkedWriter(path, "nope") as w:  # well-formed file, bogus codec
        w.append(b"body")
    svc = CodecService()
    with pytest.raises(ValueError, match="unknown codec id 'nope'"):
        svc.load_stream("x", path)
    assert svc.payloads() == []


def test_load_stream_eviction_under_byte_budget(tmp_path):
    enc = _tt_payload()
    body = len(enc.to_bytes())
    paths = []
    for i in range(2):
        p = str(tmp_path / f"p{i}.tcdc")
        write_chunked(p, enc, chunk_bytes=512)
        paths.append(p)
    svc = CodecService(cache_bytes=int(body * 1.5))  # room for ONE payload
    svc.load_stream("a", paths[0])
    svc.load_stream("b", paths[1])
    idx = _sample_indices(SHAPE)
    svc.decode_at("a", idx)
    assert svc._streams["a"].enc is not None
    svc.decode_at("b", idx)  # admitting b evicts a (LRU)
    assert svc._streams["a"].enc is None
    assert svc._streams["b"].enc is not None
    assert svc.cache_stats.evictions >= 1
    # evicted payloads still serve — they just pay rematerialization
    np.testing.assert_array_equal(svc.decode_at("a", idx), enc.decode_at(idx))
    assert svc.info("a").cache_misses == 2


def test_tiled_decode_cache_hits_and_correctness(tmp_path):
    enc = _tt_payload()
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    svc = CodecService(cache_bytes=1 << 20)
    svc.load_stream("t", path, tile_entries=64)
    idx = _sample_indices(SHAPE, n=100)
    out = svc.decode_at("t", idx)
    np.testing.assert_allclose(out, np.asarray(enc.decode_at(idx)), rtol=1e-12)
    misses = svc.info("t").cache_misses
    assert misses > 1  # several tiles decoded
    out2 = svc.decode_at("t", idx)  # identical query: pure cache hits
    np.testing.assert_array_equal(out, out2)
    assert svc.info("t").cache_misses == misses
    assert svc.info("t").cache_hits > 0
    assert svc.info("t").decode_calls >= misses - 1  # tile decodes counted


def test_szlite_dense_cache_bounded_with_counters():
    src = _source()
    x = _materialize(src)
    enc = get_codec("szlite").fit(x, error_bound=0.05)
    assert enc.cache_nbytes() == 0
    idx = _sample_indices(SHAPE)
    enc.decode_at(idx)
    dense_nbytes = x.size * 8  # decompress reconstructs at float64
    assert enc.cache_misses == 1 and enc.cache_nbytes() == dense_nbytes
    enc.decode_at(idx)
    assert enc.cache_hits == 1
    enc.drop_caches()
    assert enc.cache_nbytes() == 0
    enc.decode_at(idx)
    assert enc.cache_misses == 2  # rebuilt after eviction

    # under a service byte budget the reconstruction is evicted, not kept
    svc = CodecService(cache_bytes=100)  # far below x.nbytes
    svc.load("sz", codecs.save_bytes(enc))
    sz = svc._payloads["sz"]
    svc.decode_at("sz", idx)
    assert sz.cache_nbytes() == 0  # evicted right after accounting
    assert svc.cache_stats.evictions >= 1
    assert svc.info("sz").cache_misses >= 1
    # an unbounded service keeps it warm and mirrors the hit counters
    svc2 = CodecService()
    svc2.load("sz", sz)
    svc2.decode_at("sz", idx)
    svc2.decode_at("sz", idx)
    assert svc2.info("sz").cache_hits >= 1
    assert sz.cache_nbytes() == dense_nbytes


# ---------------------------------------------------------------------------
# acceptance: out-of-core end to end at 2^24 entries
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_stream_end_to_end_2e24(tmp_path):
    shape = (4096, 64, 64)  # 2^24 entries, never materialized
    src = SyntheticTensorSource(shape, slab_entries=1 << 18, seed=1)
    dense_nbytes = src.n_entries * 4
    assert src.slab_nbytes * 8 <= dense_nbytes  # resident slab <= 1/8 dense
    enc = fit_stream(
        "nttd", src, rank=6, hidden=12, steps_per_slab=6, batch_size=8192,
        lr=2e-2, seed=0,
    )
    assert enc.shape == shape
    path = str(tmp_path / "big.tcdc")
    write_chunked(path, enc, chunk_bytes=1 << 14)
    svc = CodecService()
    svc.load_stream("big", path)
    idx = _sample_indices(shape, n=512, seed=7)
    served = svc.decode_at("big", idx)
    np.testing.assert_array_equal(served, np.asarray(enc.decode_at(idx)))
    # the fit learned signal, not noise: decoded entries correlate with truth
    truth = src.values_at(idx)
    corr = float(np.corrcoef(truth, served)[0, 1])
    assert corr > 0.5, corr
