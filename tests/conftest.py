# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


import pytest


@pytest.fixture
def fault_injector():
    """Install worker-CLI-style fault specs on a transport or service.

    Specs use the EXACT ``--debug-corrupt-chunk NAME:CHUNK`` /
    ``--debug-fitness-noise NAME:LO:HI:SIGMA[:SEED]`` grammar the worker
    process parses (``repro.fleet.worker.parse_fault_flags``) and are
    applied through the same ``inject_fault`` verb the wire protocol
    exposes — one injection surface shared by the CI repair drill
    (scripts/repair_drill.py), the SLO drill, and the unit tests.
    """
    from repro.fleet.worker import parse_fault_flags

    def install(target, *, corrupt=None, noise=None):
        specs = parse_fault_flags(corrupt, noise)
        for name, faults in specs.items():
            for fault in faults:
                target.inject_fault(name, fault)
        return specs

    return install


def pytest_configure(config):
    # mirror pyproject [tool.pytest.ini_options] so the marker stays
    # registered even when pytest is pointed somewhere without the rootdir
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end cells (multi-pod dry-run compiles); "
        "run in tier-1, deselect with -m 'not slow'",
    )
