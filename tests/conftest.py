# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # mirror pyproject [tool.pytest.ini_options] so the marker stays
    # registered even when pytest is pointed somewhere without the rootdir
    config.addinivalue_line(
        "markers",
        "slow: long-running end-to-end cells (multi-pod dry-run compiles); "
        "run in tier-1, deselect with -m 'not slow'",
    )
