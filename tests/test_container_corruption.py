"""One canonical corruption recipe per container-v3 failure mode, shared
by the load_stream (test_stream) and fleet (test_fleet) integrity tests
so a footer-layout change cannot silently de-fang one suite — plus the
recipes' own tests, so this file is COLLECTED by pytest (it used to be
``container_corruption.py``, which matched no test pattern and never
ran on its own)."""
import struct

import numpy as np
import pytest

from repro.codecs import container, get_codec
from repro.stream import write_chunked


def corrupt_chunk_byte(path: str, out: str) -> None:
    """Flip one byte inside the first chunk's body (CRC must catch it)."""
    blob = bytearray(open(path, "rb").read())
    _, chunks = container.chunk_index(path)
    blob[chunks[0].offset] ^= 0xFF
    open(out, "wb").write(bytes(blob))


def truncate_footer(path: str, out: str) -> None:
    blob = open(path, "rb").read()
    open(out, "wb").write(blob[:-6])


def index_past_eof(path: str, out: str) -> None:
    """Rewrite the footer so one chunk's extent points past EOF."""
    blob = open(path, "rb").read()
    _, chunks = container.chunk_index(path)
    bad = [
        container.ChunkEntry(c.offset, c.length + (1 << 20) * (i == 0), c.crc)
        for i, c in enumerate(chunks)
    ]
    (footer_len,) = struct.unpack("<Q", blob[-12:-4])
    body_end = len(blob) - 12 - footer_len
    open(out, "wb").write(blob[:body_end] + container.pack_footer(bad))


# ---------------------------------------------------------------------------
# the recipes' own tests (tier-1 collects these directly)
# ---------------------------------------------------------------------------
RECIPES = {
    "corrupt_chunk_byte": (corrupt_chunk_byte, "chunk checksum"),
    "truncate_footer": (truncate_footer, "truncated|footer"),
    "index_past_eof": (index_past_eof, "outside data region"),
}


@pytest.fixture(scope="module")
def clean_path(tmp_path_factory):
    rng = np.random.default_rng(0)
    x = rng.random((16, 8, 8)).astype(np.float32)
    enc = get_codec("ttd").fit(x, max_rank=3)
    path = str(tmp_path_factory.mktemp("corruption") / "clean.tcdc")
    write_chunked(path, enc, chunk_bytes=512)
    return path


def test_clean_file_loads(clean_path):
    enc = container.load_file(clean_path)
    assert enc.codec_name == "ttd"


@pytest.mark.parametrize("recipe", sorted(RECIPES))
def test_recipe_mutates_the_file(clean_path, tmp_path, recipe):
    corruptor, _ = RECIPES[recipe]
    bad = str(tmp_path / f"{recipe}.tcdc")
    corruptor(clean_path, bad)
    assert open(bad, "rb").read() != open(clean_path, "rb").read()


@pytest.mark.parametrize("recipe", sorted(RECIPES))
def test_recipe_is_rejected_by_monolithic_load(clean_path, tmp_path, recipe):
    corruptor, match = RECIPES[recipe]
    bad = str(tmp_path / f"{recipe}.tcdc")
    corruptor(clean_path, bad)
    with pytest.raises(ValueError, match=match):
        container.load_file(bad)


@pytest.mark.parametrize("recipe", sorted(RECIPES))
def test_recipe_is_rejected_by_lazy_open_or_read(clean_path, tmp_path, recipe):
    """The lazy path defers chunk reads; corruption must surface by the
    time chunk bytes are actually materialized."""
    corruptor, match = RECIPES[recipe]
    bad = str(tmp_path / f"{recipe}.tcdc")
    corruptor(clean_path, bad)
    with pytest.raises(ValueError, match=match):
        name, chunks, view = container.open_chunks(bad)
        try:
            for c in chunks:
                container.read_chunk(view, c)
        finally:
            view.release()
