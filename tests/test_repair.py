"""Replica-aware read repair: RepairController unit/integration suite.

Covers the repair loop below the CI drill (scripts/repair_drill.py):

- corruption repair restores a quarantined chunk byte-exactly from a
  donor replica while answers stay bit-identical throughout;
- quality repair re-compresses a breached range online and the repaired
  held-out fitness recovers to within epsilon of the pre-corruption
  payload — on LocalTransport AND on real socket workers spawned with
  the ``--debug-fitness-noise`` CLI flag;
- repairing a keyframe chunk of a v4 delta file re-validates every
  dependent version chain (``repro.temporal.revalidate_chains``);
- poll() dedup, the ``_range_shape`` factoring helpers, and the
  mid-stream ``refine_orders`` hook of the NTTD stream fitter.
"""
import shutil

import numpy as np
import pytest

from repro.codecs import container
from repro.codecs.base import get_codec
from repro.codecs.indexing import flat_to_multi
from repro.fleet import (
    FleetFrontend,
    RepairConfig,
    RepairController,
    SocketTransport,
)
from repro.fleet.repair import _nearest_divisor, _range_shape
from repro.serve.codec_service import CodecService
from repro.stream import sample_heldout, write_chunked
from repro.stream.fit import NTTDStreamFitter
from repro.temporal import VersionedStore, drifting_versions, revalidate_chains
from repro.temporal.store import _fitness

SHAPE = (16, 12, 8)
CANARY_MIN_FITNESS = 0.95


def _truth() -> np.ndarray:
    # genuinely low-TT-rank (separable harmonics): the base fit is
    # near-exact, so any fitness regression the tests see is injected
    i, j, k = np.meshgrid(*[np.arange(s) for s in SHAPE], indexing="ij")
    return (
        np.sin(0.3 * i) * np.cos(0.2 * j) * np.sin(0.15 * k)
        + 0.5 * np.cos(0.1 * i) * np.sin(0.25 * j) * np.cos(0.3 * k)
    ).astype(np.float32)


@pytest.fixture(scope="module")
def pristine(tmp_path_factory):
    """(path, truth) for a chunked ttd payload with a held-out block."""
    x = _truth()
    enc = get_codec("ttd").fit(x, max_rank=4)
    path = str(tmp_path_factory.mktemp("repair") / "pristine.tcdc")
    write_chunked(path, enc, chunk_bytes=1024,
                  heldout=sample_heldout(x, 128, seed=3))
    return path, x


@pytest.fixture
def payload(pristine, tmp_path):
    """A per-test copy — repairs mutate the file (rewrite/append)."""
    src, x = pristine
    path = str(tmp_path / "payload.tcdc")
    shutil.copyfile(src, path)
    return path, x


def _batches(n=4, per=400):
    rng = np.random.default_rng(2)
    return [
        np.stack([rng.integers(0, s, per) for s in SHAPE], axis=1)
        for _ in range(n)
    ]


def _chunk_range(path: str, cid: int) -> tuple[int, int]:
    _, chunks, _ = container.container_index(path)
    return int(chunks[cid].entry_start), int(chunks[cid].entry_stop)


def _heldout_fitness(path: str, svc: CodecService, name: str) -> float:
    """Held-out fitness of the payload as currently served."""
    oc = container.open_container(path)
    try:
        h_idx, h_vals = oc.heldout.indices.copy(), oc.heldout.values.copy()
    finally:
        oc.close()
    hat = svc.decode_at(name, flat_to_multi(h_idx, SHAPE))
    return _fitness(h_vals, np.asarray(hat, np.float64))


# ---------------------------------------------------------------- corruption
class TestCorruptionRepair:
    def test_restore_from_donor_bit_identical(self, payload, fault_injector):
        path, _ = payload
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        batches = _batches()
        reference = [single.decode_at("e", idx) for idx in batches]

        fleet = FleetFrontend(["i0", "i1", "i2"], replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            route = fleet.routes["e"]
            lo, _hi = _chunk_range(path, 1)
            # corrupt the chunk on its PRIMARY owner so drill traffic is
            # guaranteed to hit the fault and fail over to the replica
            gid = int(route.group_of(np.array([lo], dtype=np.int64))[0])
            victim = fleet._group_owners["e"][gid][0]
            fault_injector(fleet.transports[victim], corrupt=["e:1"])

            def serve_round():
                for k, idx in enumerate(batches):
                    out = fleet.decode_at("e", idx)
                    assert np.array_equal(out, reference[k]), f"batch {k}"
                assert not fleet.failed, fleet.failed

            serve_round()  # bit-identical THROUGH the corruption (failover)
            ctl = RepairController(fleet)
            tickets = ctl.poll()
            corrupt = [t for t in tickets if t.kind == "corruption"]
            assert corrupt and corrupt[0].chunk == 1
            assert corrupt[0].payload == "e"
            assert (corrupt[0].entry_start, corrupt[0].entry_stop) == \
                _chunk_range(path, 1)

            reports = ctl.run()
            assert all(r.ok for r in reports), [r.error for r in reports]
            restore = next(r for r in reports if r.kind == "corruption")
            assert restore.chunks_restored == [1]
            assert restore.donors[1] != victim

            serve_round()  # bit-identical AFTER the swap
            assert not ctl.poll(), "tickets remain after repair"
            for iid, t in fleet.transports.items():
                assert not t.stats().get("quarantine"), iid
        finally:
            fleet.close()

    def test_no_donor_fails_cleanly(self, payload, fault_injector):
        """Every replica quarantined -> the repair reports failure instead
        of corrupting the file with unvouched bytes."""
        path, _ = payload
        fleet = FleetFrontend(["i0", "i1"], replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            lo, hi = _chunk_range(path, 1)
            idx = flat_to_multi(np.arange(lo, hi, dtype=np.int64), SHAPE)
            for iid in ("i0", "i1"):
                fault_injector(fleet.transports[iid], corrupt=["e:1"])
                with pytest.raises(ValueError):
                    fleet.services[iid].decode_at("e", idx)
            ctl = RepairController(fleet)
            [report] = ctl.run()
            assert not report.ok
            assert "no live replica" in report.error
        finally:
            fleet.close()

    def test_poll_dedup_across_replicas(self, payload, fault_injector):
        """R replicas reporting the same damaged chunk is ONE ticket."""
        path, _ = payload
        fleet = FleetFrontend(["i0", "i1"], replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            lo, hi = _chunk_range(path, 1)
            idx = flat_to_multi(np.arange(lo, hi, dtype=np.int64), SHAPE)
            for iid in ("i0", "i1"):
                fault_injector(fleet.transports[iid], corrupt=["e:1"])
                with pytest.raises(ValueError):
                    fleet.services[iid].decode_at("e", idx)
                assert fleet.transports[iid].stats()["quarantine"], iid
            tickets = RepairController(fleet).poll()
            assert len(tickets) == 1, tickets
            assert tickets[0].kind == "corruption" and tickets[0].chunk == 1
        finally:
            fleet.close()


# ------------------------------------------------------------------- quality
class TestQualityRepair:
    def test_refit_recovers_precorruption_fitness(self, payload, fault_injector):
        """Direct repair_quality round-trip: decode-tile densify + held-out
        overlay + NTTD refit (with mid-stream order refinement) must bring
        held-out fitness back to within epsilon of the pre-corruption
        payload, and leave untouched entries bit-identical."""
        path, x = payload
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        pre_fitness = _heldout_fitness(path, single, "e")
        assert pre_fitness > 0.999  # the base fit is near-exact

        fleet = FleetFrontend(["i0", "i1"], replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            lo, hi = _chunk_range(path, 1)
            noise = [f"e:{lo}:{hi}:0.4:5"]
            for t in fleet.transports.values():
                fault_injector(t, noise=noise)

            all_idx = flat_to_multi(
                np.arange(int(np.prod(SHAPE)), dtype=np.int64), SHAPE
            )
            outside = (np.arange(len(all_idx)) < lo) | (np.arange(len(all_idx)) >= hi)
            ref_outside = single.decode_at("e", all_idx[outside])

            ctl = RepairController(fleet, RepairConfig(reorder=True))
            report = ctl.repair_quality("e", lo, hi)
            assert report.ok, report.error
            assert report.fitness_before < CANARY_MIN_FITNESS  # was degraded
            assert report.fitness_after >= pre_fitness - 0.05
            assert report.refit_entries > 0
            assert report.refit_entries_per_sec > 0

            # untouched ranges: bit-identical after the patch lands
            # (refresh cleared the injected noise with the old epoch)
            out = fleet.decode_at("e", all_idx[outside])
            assert np.array_equal(out, ref_outside)
            # repaired range: the refit recovers TRUTH where truth exists
            # (the held-out sample — everywhere else the degraded decode
            # was the best available estimate, so noise bakes in there)
            oc = container.open_container(path)
            try:
                sel = (oc.heldout.indices >= lo) & (oc.heldout.indices < hi)
                h_idx = oc.heldout.indices[sel].copy()
                h_vals = oc.heldout.values[sel].copy()
            finally:
                oc.close()
            assert len(h_idx) > 4
            hat = fleet.decode_at("e", flat_to_multi(h_idx, SHAPE))
            assert _fitness(h_vals, np.asarray(hat, np.float64)) >= \
                pre_fitness - 0.05
        finally:
            fleet.close()

    def test_bad_ranges_fail_cleanly(self, payload):
        path, _ = payload
        fleet = FleetFrontend(["i0"], replication=1)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            ctl = RepairController(fleet)
            assert not ctl.repair_quality("e", 10, 10).ok   # empty
            assert not ctl.repair_quality("e", -4, 10).ok   # negative
            small = RepairController(
                fleet, RepairConfig(max_patch_entries=8)
            ).repair_quality("e", 0, 256)
            assert not small.ok and "max_patch_entries" in small.error
        finally:
            fleet.close()

    def test_canary_ticket_to_repair_local(self, payload, fault_injector):
        """End-to-end on LocalTransport: injected regression -> canary
        breach -> quality ticket -> online refit -> untouched entries
        bit-identical during AND after the in-flight repair."""
        path, _ = payload
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        batches = _batches()
        reference = [single.decode_at("e", idx) for idx in batches]

        fleet = FleetFrontend(
            ["i0", "i1", "i2"], replication=2,
            canary_fraction=1.0, canary_min_fitness=CANARY_MIN_FITNESS,
        )
        try:
            fleet.load_stream("e", path, tile_entries=256)
            lo, hi = _chunk_range(path, 2)
            for t in fleet.transports.values():
                fault_injector(t, noise=[f"e:{lo}:{hi}:0.4:5"])

            def untouched(idx):
                flat = np.ravel_multi_index(tuple(idx.T), SHAPE)
                return (flat < lo) | (flat >= hi)

            def serve_round():
                for k, idx in enumerate(batches):
                    out = fleet.decode_at("e", idx)
                    keep = untouched(idx)
                    assert np.array_equal(out[keep], reference[k][keep])
                assert not fleet.failed, fleet.failed

            ctl = RepairController(fleet)
            quality = []
            for _ in range(8):  # canary sampling is per-call deterministic
                serve_round()  # untouched stays exact while damage is live
                quality = [t for t in ctl.poll() if t.kind == "quality"]
                if quality:
                    break
            assert quality, "canary never fired on the injected regression"
            assert (quality[0].entry_start, quality[0].entry_stop) == (lo, hi)

            reports = ctl.run()
            refit = next(r for r in reports if r.kind == "quality")
            assert refit.ok, refit.error
            assert refit.fitness_after > refit.fitness_before
            serve_round()  # untouched ranges exact after the swap too
        finally:
            fleet.close()

    def test_canary_ticket_to_repair_socket(self, payload):
        """Same loop over REAL worker processes, with the fitness fault
        installed at spawn through the --debug-fitness-noise CLI flag
        (the drill covers --debug-corrupt-chunk; this covers the other
        worker fault flag end to end)."""
        path, _ = payload
        lo, hi = _chunk_range(path, 2)
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        batches = _batches()
        reference = [single.decode_at("e", idx) for idx in batches]

        def factory(iid):
            return SocketTransport.spawn(
                iid,
                timeout=60.0,
                canary_fraction=1.0,
                canary_min_fitness=CANARY_MIN_FITNESS,
                debug_fitness_noise=[f"e:{lo}:{hi}:0.4:5"],
            )

        fleet = FleetFrontend(["w0", "w1"], transport_factory=factory,
                              replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)

            def untouched(idx):
                flat = np.ravel_multi_index(tuple(idx.T), SHAPE)
                return (flat < lo) | (flat >= hi)

            def serve_round():
                for k, idx in enumerate(batches):
                    out = fleet.decode_at("e", idx)
                    keep = untouched(idx)
                    assert np.array_equal(out[keep], reference[k][keep])
                assert not fleet.failed, fleet.failed

            ctl = RepairController(fleet)
            quality = []
            for _ in range(8):
                serve_round()
                quality = [t for t in ctl.poll() if t.kind == "quality"]
                if quality:
                    break
            assert quality, "canary never fired across the wire"
            assert (quality[0].entry_start, quality[0].entry_stop) == (lo, hi)

            reports = ctl.run()
            refit = next(r for r in reports if r.kind == "quality")
            assert refit.ok, refit.error
            assert refit.fitness_after > refit.fitness_before
            serve_round()  # untouched ranges exact after the swap
        finally:
            fleet.close()

    def test_versioned_payload_rejected(self, tmp_path):
        path = str(tmp_path / "v4.tcdc")
        data = drifting_versions(SHAPE, 3, drift=0.05, noise=0.02, seed=5)
        with VersionedStore.create(
            path, "ttd", keyframe_interval=4, chunk_bytes=2048,
            keyframe_opts={"max_rank": 4}, delta_opts={"max_rank": 2},
        ) as s:
            for x in data:
                s.append(x)
        fleet = FleetFrontend(["i0"], replication=1)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            report = RepairController(fleet).repair_quality("e", 0, 64)
            assert not report.ok and "versioned" in report.error
        finally:
            fleet.close()


# ------------------------------------------------------------ v4 delta chains
class TestKeyframeRepairRevalidatesChains:
    N_VERSIONS = 5

    @pytest.fixture()
    def v4(self, tmp_path):
        path = str(tmp_path / "chain.tcdc")
        data = drifting_versions(
            SHAPE, self.N_VERSIONS, drift=0.05, noise=0.02, seed=5
        )
        with VersionedStore.create(
            path, "ttd", keyframe_interval=4, chunk_bytes=2048,
            keyframe_opts={"max_rank": 4}, delta_opts={"max_rank": 2},
        ) as s:
            for x in data:
                s.append(x)
        return path, data

    def test_revalidate_clean_and_corrupt(self, v4):
        """On-disk rot in a keyframe chunk fails EVERY dependent chain,
        not just the keyframe's own version."""
        path, data = v4
        truth = {v: x for v, x in enumerate(data)}
        health = revalidate_chains(path, truth=truth)
        assert len(health) == self.N_VERSIONS
        assert all(h.ok for h in health)
        assert all(h.fitness is not None and h.fitness > 0.5 for h in health)
        # chains: v0 keyframe <- v1 <- v2 <- v3; v4 fresh keyframe
        assert health[3].chain[0] == 0 and len(health[3].chain) == 4
        assert health[4].chain == [4]

        _, chunks, versions = container.container_index(path)
        kf = versions[0]
        c = chunks[kf.chunk_start]  # first chunk of keyframe 0's payload
        with open(path, "r+b") as f:
            f.seek(c.offset + c.length // 2)
            b = f.read(1)
            f.seek(c.offset + c.length // 2)
            f.write(bytes([b[0] ^ 0xFF]))
        health = revalidate_chains(path)
        by_v = {h.version: h for h in health}
        for v in range(4):  # keyframe 0 and every delta decoding through it
            assert not by_v[v].ok, v
            assert by_v[v].error
        assert by_v[4].ok  # the independent keyframe is untouched

    def test_keyframe_restore_revalidates_dependents(self, v4, fault_injector):
        """Corruption repair of a keyframe chunk on a v4 payload restores
        the bytes from a donor AND re-validates every version chain before
        reporting ok; all versions decode bit-identically afterwards."""
        path, _ = v4
        _, chunks, versions = container.container_index(path)
        kf_chunk = int(versions[0].chunk_start)

        fleet = FleetFrontend(["i0", "i1"], replication=2)
        try:
            fleet.load_stream("e", path, tile_entries=256)
            probe = np.stack(
                [np.arange(8) % s for s in SHAPE], axis=1
            ).astype(np.int64)
            reference = [
                fleet.decode_at("e", probe, version=v)
                for v in range(self.N_VERSIONS)
            ]

            fault_injector(fleet.transports["i0"], corrupt=[f"e:{kf_chunk}"])
            with pytest.raises(ValueError):
                # any chain through keyframe 0 needs the corrupt chunk
                fleet.services["i0"].decode_at("e", probe, version=0)
            assert fleet.transports["i0"].stats()["quarantine"]

            ctl = RepairController(fleet)
            tickets = ctl.poll()
            assert [t.chunk for t in tickets] == [kf_chunk]
            [report] = ctl.run()
            assert report.ok, report.error
            assert report.chunks_restored == [kf_chunk]
            assert report.donors[kf_chunk] == "i1"
            assert report.chains_revalidated == self.N_VERSIONS

            for v in range(self.N_VERSIONS):
                out = fleet.decode_at("e", probe, version=v)
                assert np.array_equal(out, reference[v]), f"version {v}"
            assert not ctl.poll()
        finally:
            fleet.close()


# ------------------------------------------------------------------- helpers
class TestRangeShape:
    @pytest.mark.parametrize("n", [1, 2, 7, 97, 256, 384, 1536, 4096, 30030])
    def test_product_and_balance(self, n):
        dims = _range_shape(n)
        assert int(np.prod(dims)) == max(n, 1)
        assert 1 <= len(dims) <= 3
        assert all(d > 1 for d in dims) or dims == (max(n, 1),)

    def test_prime_falls_back_to_1d(self):
        assert _range_shape(7) == (7,)
        assert _range_shape(9973) == (9973,)

    def test_nearest_divisor(self):
        assert _nearest_divisor(12, 3) == 3
        assert _nearest_divisor(12, 5) == 4      # 4 and 6 tie, lower wins
        assert _nearest_divisor(7, 3) == 1       # prime: only trivial divisors
        assert _nearest_divisor(100, 1000) == 100  # target clamped to n


# --------------------------------------------------- mid-stream order refine
class TestRefineOrders:
    SHAPE = (8, 6, 4)

    def _fitter(self, **kw):
        kw.setdefault("rank", 6)
        kw.setdefault("steps_per_slab", 8)
        kw.setdefault("batch_size", 192)
        kw.setdefault("lr", 1e-2)
        return NTTDStreamFitter(self.SHAPE, seed=0, **kw)

    def _feed(self, fitter, x, passes=1):
        n = int(np.prod(self.SHAPE))
        idx = flat_to_multi(np.arange(n, dtype=np.int64), self.SHAPE)
        for _ in range(passes):
            fitter.update(idx, x.ravel())
        return idx

    def test_empty_reservoir_raises(self):
        with pytest.raises(ValueError, match="empty reservoir"):
            self._fitter().refine_orders()

    def test_shape_mismatch_raises(self):
        f = self._fitter()
        self._feed(f, np.zeros(self.SHAPE, np.float32))
        with pytest.raises(ValueError, match="shape"):
            f.refine_orders(np.zeros((3, 3), np.float32))

    def test_orders_are_permutations_and_reservoir_remaps(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=self.SHAPE).astype(np.float32)
        f = self._fitter()
        self._feed(f, x)
        before = f._reservoir_orig().copy()
        orders = f.refine_orders()
        for k, s in enumerate(self.SHAPE):
            assert np.array_equal(np.sort(orders[k]), np.arange(s))
        # the reservoir's ORIGINAL-index view survives the remap exactly
        assert np.array_equal(f._reservoir_orig(), before)
        assert f._inv is not None
        # a second refinement round-trips through non-identity orders
        f.refine_orders(x)
        assert np.array_equal(f._reservoir_orig(), before)

    def test_training_continues_warm_after_refine(self):
        rng = np.random.default_rng(1)
        # mode-0 slices shuffled so identity order is deliberately bad
        i, j, k = np.meshgrid(*[np.arange(s) for s in self.SHAPE], indexing="ij")
        x = np.sin(0.4 * i + 0.3 * j + 0.5 * k).astype(np.float32)
        x = x[rng.permutation(self.SHAPE[0])]
        f = self._fitter()
        idx = self._feed(f, x, passes=2)
        seen = f.entries_seen
        f.refine_orders()
        self._feed(f, x, passes=6)  # same ORIGINAL indices, post-refine
        assert f.entries_seen == seen + 6 * int(np.prod(self.SHAPE))
        enc = f.finalize()
        hat = np.asarray(enc.decode_at(idx), np.float64)
        assert np.all(np.isfinite(hat))
        assert _fitness(x.ravel(), hat) > 0.3
