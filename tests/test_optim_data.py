"""Optimizer, schedules, data pipeline, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import MMapSource, PipelineConfig, SyntheticSource, write_corpus
from repro.optim import optimizers, schedules
from repro.train import checkpoint as ckpt_lib


# --------------------------------------------------------------------- optim
def test_adam_minimizes_quadratic():
    opt = optimizers.adam(0.1)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = optimizers.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_decays_weights():
    opt = optimizers.adamw(0.01, weight_decay=0.5)
    params = {"w": jnp.asarray([10.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.0])}
    upd, state = opt.update(grads, state, params)
    p2 = optimizers.apply_updates(params, upd)
    assert float(p2["w"][0]) < 10.0


def test_grad_clipping():
    big = {"w": jnp.full((4,), 1e6)}
    clipped, norm = optimizers.clip_by_global_norm(big, 1.0)
    assert float(optimizers.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1e5


def test_schedules_shapes():
    for sched in [
        schedules.constant(1e-3),
        schedules.cosine(1e-3, 100, warmup=10),
        schedules.wsd(1e-3, 100, warmup=10),
    ]:
        vals = [float(sched(jnp.asarray(s))) for s in [0, 5, 50, 99]]
        assert all(v >= 0 for v in vals)
    wsd = schedules.wsd(1e-3, 100, warmup=10, decay_frac=0.2)
    assert abs(float(wsd(jnp.asarray(50))) - 1e-3) < 1e-9  # stable plateau
    assert float(wsd(jnp.asarray(99))) < 5e-4            # decayed
    assert float(wsd(jnp.asarray(5))) < 1e-3             # warming up


# ---------------------------------------------------------------------- data
def test_synthetic_deterministic_and_rank_disjoint():
    c0 = PipelineConfig(batch_size=4, seq_len=32, vocab=100, seed=7, rank=0, world=2)
    c1 = PipelineConfig(batch_size=4, seq_len=32, vocab=100, seed=7, rank=1, world=2)
    s0, s0b, s1 = SyntheticSource(c0), SyntheticSource(c0), SyntheticSource(c1)
    a = s0.batch_at(3)
    b = s0b.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    c = s1.batch_at(3)
    assert not np.array_equal(a["tokens"], c["tokens"])      # ranks differ
    # labels are next-token
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_mmap_source(tmp_path):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 50, size=10000).astype(np.int32)
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, toks)
    cfg = PipelineConfig(batch_size=3, seq_len=64, vocab=50, seed=1)
    src = MMapSource(path, cfg)
    b = src.batch_at(0)
    assert b["tokens"].shape == (3, 64)
    np.testing.assert_array_equal(
        src.batch_at(5)["tokens"], src.batch_at(5)["tokens"]
    )


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = ckpt_lib.Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
    for step in [10, 20, 30]:
        ck.save(step, jax.tree.map(lambda x: x * step, tree))
    assert ck.all_steps() == [20, 30]  # gc kept last 2
    restored, manifest = ck.restore(30, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.arange(10.0) * 30)
    assert manifest["step"] == 30


def test_checkpoint_async_and_auto_resume(tmp_path):
    ck = ckpt_lib.Checkpointer(str(tmp_path), async_save=True)
    tree = {"w": jnp.full((4,), 7.0)}
    ck.save(5, tree)
    ck.wait()
    restored, step = ckpt_lib.auto_resume(ck, tree)
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["w"]), 7.0)


def test_auto_resume_empty_dir(tmp_path):
    ck = ckpt_lib.Checkpointer(str(tmp_path))
    tree, step = ckpt_lib.auto_resume(ck, {"w": jnp.zeros(2)})
    assert tree is None and step == 0


# ---------------------------------------------------------- grad compression
def test_int8_error_feedback_converges():
    from repro.dist.grad_compress import ErrorFeedbackInt8

    comp = ErrorFeedbackInt8()
    params = {"w": jnp.asarray([2.0, -1.0])}
    state = comp.init(params)
    opt = optimizers.adam(0.05)
    ost = opt.init(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        grads, state = comp.transform(grads, state)
        upd, ost = opt.update(grads, ost, params)
        params = optimizers.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_topk_error_feedback_preserves_mass():
    from repro.dist.grad_compress import TopK

    comp = TopK(fraction=0.25)
    params = {"w": jnp.arange(16.0)}
    state = comp.init(params)
    grads = {"w": jnp.arange(16.0)}
    g1, state = comp.transform(grads, state)
    # error feedback: residual + next grad reappears
    g2, state = comp.transform(grads, state)
    total = np.asarray(g1["w"] + g2["w"])
    assert total.sum() > np.asarray(grads["w"]).sum()  # catching up on skipped mass
