"""One canonical corruption recipe per container-v3 failure mode, shared
by the load_stream (test_stream) and fleet (test_fleet) integrity tests
so a footer-layout change cannot silently de-fang one suite."""
import struct

from repro.codecs import container


def corrupt_chunk_byte(path: str, out: str) -> None:
    """Flip one byte inside the first chunk's body (CRC must catch it)."""
    blob = bytearray(open(path, "rb").read())
    _, chunks = container.chunk_index(path)
    blob[chunks[0].offset] ^= 0xFF
    open(out, "wb").write(bytes(blob))


def truncate_footer(path: str, out: str) -> None:
    blob = open(path, "rb").read()
    open(out, "wb").write(blob[:-6])


def index_past_eof(path: str, out: str) -> None:
    """Rewrite the footer so one chunk's extent points past EOF."""
    blob = open(path, "rb").read()
    _, chunks = container.chunk_index(path)
    bad = [
        container.ChunkEntry(c.offset, c.length + (1 << 20) * (i == 0), c.crc)
        for i, c in enumerate(chunks)
    ]
    (footer_len,) = struct.unpack("<Q", blob[-12:-4])
    body_end = len(blob) - 12 - footer_len
    open(out, "wb").write(blob[:body_end] + container.pack_footer(bad))
