"""Online fitness canaries: the TCDQ held-out footer block (write/parse/
corruption), CodecService canary sampling (deterministic, breach events
naming the offending chunk), and the serving contract — answers are
bit-identical with canaries off or on, across Local and Socket fleets,
and legacy files without the block serve unchanged."""
import numpy as np
import pytest

from repro import obs
from repro.codecs import container, get_codec
from repro.fleet import FleetFrontend, SocketTransport, collect
from repro.serve.codec_service import CodecService
from repro.stream import ChunkedWriter, sample_heldout, write_chunked

SHAPE = (16, 12, 8)


@pytest.fixture(scope="module")
def source():
    rng = np.random.default_rng(7)
    x = rng.random(SHAPE).astype(np.float32)
    return x, get_codec("ttd").fit(x, max_rank=4)


@pytest.fixture(scope="module")
def canary_path(source, tmp_path_factory):
    x, enc = source
    path = str(tmp_path_factory.mktemp("canary") / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=1024,
                  heldout=sample_heldout(x, 64, seed=3))
    return path


@pytest.fixture(scope="module")
def legacy_path(source, tmp_path_factory):
    _, enc = source
    path = str(tmp_path_factory.mktemp("canary") / "legacy.tcdc")
    write_chunked(path, enc, chunk_bytes=1024)
    return path


def _idx(n=64, seed=1):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, n) for s in SHAPE], axis=1)


# ---------------------------------------------------------------------------
# TCDQ container block
# ---------------------------------------------------------------------------
def test_heldout_round_trips_bit_exact(source, canary_path):
    x, _ = source
    idx, vals = sample_heldout(x, 64, seed=3)
    oc = container.open_container(canary_path)
    try:
        assert oc.heldout is not None and len(oc.heldout) == 64
        assert np.array_equal(oc.heldout.indices, idx)
        assert np.array_equal(oc.heldout.values, vals)  # float64, exact
    finally:
        oc.close()


def test_legacy_file_has_no_heldout_and_loads(source, legacy_path):
    _, enc = source
    oc = container.open_container(legacy_path)
    try:
        assert oc.heldout is None
    finally:
        oc.close()
    assert np.array_equal(container.load_file(legacy_path).to_dense(),
                          enc.to_dense())


def test_record_heldout_unseals_synced_footer(source, tmp_path):
    x, enc = source
    idx, vals = sample_heldout(x, 10, seed=0)
    path = str(tmp_path / "w.tcdc")
    w = ChunkedWriter(path, "ttd")
    w.append(enc.to_bytes())
    w.record_heldout(idx[:4], vals[:4])
    w.sync()  # footer now holds 4 entries
    w.record_heldout(idx[4:], vals[4:])  # must unseal + rewrite
    w.close()
    oc = container.open_container(path)
    try:
        assert len(oc.heldout) == 10
        assert np.array_equal(oc.heldout.indices, idx)
    finally:
        oc.close()


def test_record_heldout_rejects_bad_input(tmp_path, source):
    _, enc = source
    w = ChunkedWriter(str(tmp_path / "w.tcdc"), "ttd")
    with pytest.raises(ValueError, match="length mismatch"):
        w.record_heldout(np.array([1, 2]), np.array([0.5]))
    with pytest.raises(ValueError, match="non-negative"):
        w.record_heldout(np.array([-1]), np.array([0.5]))
    with pytest.raises(ValueError, match="out of range"):
        write_chunked(
            str(tmp_path / "x.tcdc"), enc,
            heldout=(np.array([10**9]), np.array([0.5])),
        )


def test_corrupt_heldout_block_is_rejected(canary_path, tmp_path):
    blob = open(canary_path, "rb").read()
    # truncate mid-footer: drop the last 8 bytes of the TCDQ payload
    # (before the u64 footer_len + TCDX trailer, which must stay intact)
    foot_len = int.from_bytes(blob[-12:-4], "little")
    cut = bytearray(blob)
    del cut[len(blob) - 12 - 8 : len(blob) - 12]
    cut[-12:-4] = (foot_len - 8).to_bytes(8, "little")
    bad = tmp_path / "trunc.tcdc"
    bad.write_bytes(bytes(cut))
    with pytest.raises(ValueError, match="corrupt|truncated"):
        container.open_container(str(bad)).close()


def test_sample_heldout_is_deterministic_and_sorted(source):
    x, _ = source
    a = sample_heldout(x, 32, seed=5)
    b = sample_heldout(x, 32, seed=5)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    assert np.all(np.diff(a[0]) > 0)  # sorted, distinct
    assert np.array_equal(a[1], x.reshape(-1)[a[0]].astype(np.float64))


# ---------------------------------------------------------------------------
# CodecService canary sampling
# ---------------------------------------------------------------------------
def test_canary_checks_update_gauge_and_stats(canary_path):
    svc = CodecService(canary_fraction=1.0)
    svc.load_stream("e", canary_path)
    for seed in range(3):
        svc.decode_at("e", _idx(seed=seed))
    cs = svc.canary_stats()["e"]
    assert cs["checks"] == 3 and cs["breaches"] == 0
    assert 0.0 < cs["last_fitness"] <= 1.0
    assert cs["rolling_fitness"] == pytest.approx(cs["last_fitness"])
    gauges = {
        (g["name"], g["labels"].get("payload")): g["value"]
        for g in svc.metrics.as_dict()["gauges"]
    }
    assert gauges[("canary_fitness", "e")] == pytest.approx(cs["rolling_fitness"])
    assert svc.stats()["canary"]["e"] == cs  # rides the wire stats schema


def test_canary_sampling_is_deterministic_in_call_sequence(canary_path):
    a = CodecService(canary_fraction=0.5)
    b = CodecService(canary_fraction=0.5)
    for svc in (a, b):
        svc.load_stream("e", canary_path)
        for seed in range(20):
            svc.decode_at("e", _idx(8, seed=seed))
    assert a.canary_stats() == b.canary_stats()
    checks = a.canary_stats()["e"]["checks"]
    assert 0 < checks < 20  # a fraction, not all-or-nothing


def test_quality_breach_event_names_offending_chunk(canary_path):
    obs.clear_events()
    svc = CodecService(canary_fraction=1.0, canary_min_fitness=0.999999)
    svc.load_stream("e", canary_path)
    svc.decode_at("e", _idx())
    assert svc.canary_stats()["e"]["breaches"] == 1
    evs = obs.events("quality_breach")
    assert len(evs) == 1
    ev = evs[0]
    assert ev["payload"] == "e" and ev["fitness"] < 0.999999
    assert ev["entry_start"] <= ev["worst_index"] < ev["entry_stop"]
    oc = container.open_container(canary_path)
    try:
        c = oc.chunks[ev["chunk"]]
        assert (c.entry_start, c.entry_stop) == (ev["entry_start"], ev["entry_stop"])
    finally:
        oc.close()


def test_canary_skips_legacy_payloads_cleanly(legacy_path):
    svc = CodecService(canary_fraction=1.0, canary_min_fitness=0.99)
    svc.load_stream("l", legacy_path)
    svc.decode_at("l", _idx())
    assert svc.canary_stats() == {}


def test_canary_rejects_bad_fraction():
    with pytest.raises(ValueError, match="canary_fraction"):
        CodecService(canary_fraction=1.5)


# ---------------------------------------------------------------------------
# serving contract: answers bit-identical off/on, Local and Socket
# ---------------------------------------------------------------------------
def _drill(fleet):
    out = []
    try:
        fleet.load_stream("e", fleet._canary_test_path, tile_entries=256)
        for seed in range(6):
            out.append(fleet.decode_at("e", _idx(seed=seed)))
        assert not fleet.failed
        return out
    finally:
        fleet.close()


def test_local_fleet_answers_bit_identical_with_canaries(canary_path):
    answers = {}
    for frac in (0.0, 1.0):
        fleet = FleetFrontend(
            2, cache_bytes=1 << 22, canary_fraction=frac,
            canary_min_fitness=0.999999 if frac else None,
        )
        fleet._canary_test_path = canary_path
        answers[frac] = _drill(fleet)
    for off, on in zip(answers[0.0], answers[1.0]):
        assert off.dtype == on.dtype
        assert np.array_equal(off, on)


def test_socket_fleet_answers_bit_identical_with_canaries(canary_path):
    answers, stats = {}, None
    for frac in (0.0, 1.0):
        fleet = FleetFrontend(
            ["w0", "w1"],
            transport_factory=lambda iid, frac=frac: SocketTransport.spawn(
                iid, timeout=10.0, canary_fraction=frac,
                canary_min_fitness=0.999999 if frac else None,
            ),
        )
        fleet._canary_test_path = canary_path
        try:
            fleet.load_stream("e", canary_path, tile_entries=256)
            answers[frac] = [
                fleet.decode_at("e", _idx(seed=seed)) for seed in range(6)
            ]
            assert not fleet.failed
            if frac:  # canary stats cross the wire in the stats blob
                stats = collect(fleet)
        finally:
            fleet.close()
    for off, on in zip(answers[0.0], answers[1.0]):
        assert np.array_equal(off, on)
    assert stats.canary["e"]["checks"] > 0
    assert stats.canary["e"]["breaches"] == stats.canary["e"]["checks"]
    assert any(m.canary for m in stats.instances.values())
