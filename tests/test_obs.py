"""repro.obs: span recorder semantics (disabled path allocates nothing,
enabled path bounds memory), histogram percentiles (all-time buckets vs
exact window, empty -> None), Chrome trace export + report CLI, fit
telemetry JSONL, and the headline end-to-end property — one traced
``FleetFrontend.decode_at`` over a two-worker socket fleet yields a
single stitched trace holding frontend, transport, worker service-stage,
and kernel spans."""
import io
import json

import numpy as np
import pytest

from repro import obs
from repro.codecs import get_codec
from repro.fleet import FleetFrontend, SocketTransport
from repro.fleet.metrics import collect
from repro.obs import report
from repro.obs.trace import TraceRecorder
from repro.stream import write_chunked


@pytest.fixture()
def recorder():
    """A clean, enabled global recorder; restored to disabled after."""
    rec = obs.enable_tracing()
    rec.clear()
    yield rec
    obs.disable_tracing()
    rec.clear()


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------
def test_disabled_recorder_allocates_no_spans():
    rec = obs.get_recorder()
    obs.disable_tracing()
    before = rec.span_allocs
    for _ in range(100):
        with obs.span("hot", k=1):
            pass
    assert rec.span_allocs == before  # zero Span objects on the off path
    assert len(rec) == 0 or rec.snapshot()[-1].name != "hot"
    # the disabled context manager is one shared object, not per-call
    assert obs.span("a") is obs.span("b")


def test_enabled_recorder_records_nested_parentage(recorder):
    with obs.span("outer", stage="o") as outer:
        with obs.span("inner") as inner:
            pass
    spans = recorder.snapshot()[-2:]
    by_name = {s.name: s for s in spans}
    assert by_name["inner"].trace_id == by_name["outer"].trace_id
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].parent_id == 0  # root
    assert by_name["inner"].t_start >= by_name["outer"].t_start
    assert by_name["inner"].t_end <= by_name["outer"].t_end
    assert outer.attrs == {"stage": "o"}
    assert inner.duration >= 0.0


def test_ring_capacity_bounds_memory_and_counts_drops():
    rec = TraceRecorder(capacity=4)
    rec.enabled = True
    for k in range(10):
        with rec.span(f"s{k}"):
            pass
    assert len(rec) == 4
    assert rec.dropped == 6
    assert [s.name for s in rec.snapshot()] == ["s6", "s7", "s8", "s9"]


def test_span_records_exception_and_reraises(recorder):
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    s = recorder.snapshot()[-1]
    assert s.name == "boom" and s.attrs["error"] == "ValueError"


def test_ingest_rebases_clock_and_labels_instance(recorder):
    remote = TraceRecorder(capacity=8)
    remote.enabled = True
    with remote.span("w"):
        pass
    (w,) = remote.drain()
    recorder.ingest([w], clock_offset=100.0, instance="w3")
    got = recorder.snapshot()[-1]
    assert got.instance == "w3"
    assert got.t_start == pytest.approx(w.t_start + 100.0)
    assert got.duration == pytest.approx(w.duration)


def test_remote_context_adopts_parent(recorder):
    with obs.remote_context((42, 7)):
        with obs.span("adopted"):
            pass
    s = recorder.snapshot()[-1]
    assert (s.trace_id, s.parent_id) == (42, 7)
    # and the ambient context is restored
    assert obs.current_context() is None


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_histogram_empty_percentiles_are_none_not_crash():
    h = obs.Histogram("lat", ())
    assert h.percentile(50) is None
    assert h.percentile(99) is None
    assert h.window_percentile(50) is None
    assert h.mean is None


def test_histogram_window_percentiles_are_exact():
    h = obs.Histogram("lat", (), window=100)
    vals = [0.001 * k for k in range(1, 101)]
    for v in vals:
        h.observe(v)
    assert h.window_percentile(50) == pytest.approx(np.percentile(vals, 50))
    assert h.window_percentile(99) == pytest.approx(np.percentile(vals, 99))
    assert h.count == 100 and h.min == vals[0] and h.max == vals[-1]


def test_histogram_alltime_survives_window_wrap():
    h = obs.Histogram("lat", (), window=4)
    for _ in range(100):
        h.observe(0.001)  # old regime
    for _ in range(10):
        h.observe(1.0)  # recent regime fills the whole window
    assert h.window_percentile(50) == pytest.approx(1.0)
    # all-time view still remembers the 100 fast samples
    assert h.percentile(50) == pytest.approx(0.001, rel=1.0)
    assert h.count == 110


def test_registry_get_or_create_remove_and_as_dict():
    reg = obs.MetricsRegistry()
    c = reg.counter("requests", instance="i0")
    assert reg.counter("requests", instance="i0") is c
    c.inc(3)
    g = reg.gauge("peak", instance="i0")
    g.set_max(10)
    g.set_max(5)  # peak keeps the high-water mark
    reg.histogram("lat", instance="i0").observe(0.5)
    d = reg.as_dict()
    assert d["counters"] == [
        {"name": "requests", "labels": {"instance": "i0"}, "value": 3}
    ]
    assert d["gauges"][0]["value"] == 10
    assert d["histograms"][0]["count"] == 1
    assert d["histograms"][0]["window_p99"] == pytest.approx(0.5)
    reg.remove("lat", instance="i0")
    assert reg.as_dict()["histograms"] == []


# ---------------------------------------------------------------------------
# export + report
# ---------------------------------------------------------------------------
def test_chrome_trace_export_is_valid_and_loadable(tmp_path, recorder):
    with obs.span("stage_a", payload="p"):
        with obs.span("stage_b"):
            pass
    path = str(tmp_path / "trace.json")
    n = obs.export_chrome_trace(path, metrics={"fleet": None, "instances": {}})
    assert n == 2
    doc = json.load(open(path))
    assert isinstance(doc["traceEvents"], list)
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"stage_a", "stage_b"}
    assert ms[0]["name"] == "process_name"
    for e in xs:  # required Chrome trace-event fields
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["ts"] >= 0 and e["dur"] >= 0
    assert doc["repro_metrics"]["instances"] == {}


def test_report_cli_renders_breakdown(tmp_path, recorder, capsys):
    with obs.span("decode_at", payload="p"):
        with obs.span("tile_decode", tiles=3):
            pass
    path = str(tmp_path / "trace.json")
    obs.export_chrome_trace(path)
    assert report.main([path, "--top", "3"]) == 0
    out = capsys.readouterr().out
    assert "decode_at" in out and "tile_decode" in out
    assert "stage" in out and "share" in out


def test_report_cli_rejects_non_trace_file(tmp_path, capsys):
    bad = tmp_path / "not_trace.json"
    bad.write_text('{"foo": 1}')
    assert report.main([str(bad)]) == 1
    assert "traceEvents" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# fit telemetry
# ---------------------------------------------------------------------------
def test_jsonl_event_log_and_fit_event_hook():
    buf = io.StringIO()
    log = obs.set_fit_log(obs.JsonlEventLog(buf))
    try:
        assert obs.fit_telemetry_enabled()
        obs.fit_event("fit_slab", step=1, loss=0.5)
        obs.fit_event("version_append", version=0, keyframe=True)
        assert log.events_written == 2
    finally:
        obs.set_fit_log(None)
    assert not obs.fit_telemetry_enabled()
    obs.fit_event("dropped")  # no sink: must be a silent no-op
    recs = [json.loads(line) for line in buf.getvalue().splitlines()]
    assert [r["event"] for r in recs] == ["fit_slab", "version_append"]
    assert recs[0]["loss"] == 0.5 and "t" in recs[0]


def test_stream_fit_emits_slab_events(tmp_path):
    from repro.stream.fit import NTTDStreamFitter

    path = tmp_path / "fit.jsonl"
    obs.set_fit_log(str(path))
    try:
        rng = np.random.default_rng(0)
        shape = (8, 6, 4)
        fitter = NTTDStreamFitter(
            shape, rank=2, hidden=4, steps_per_slab=2, batch_size=64,
            replay_capacity=128,
        )
        idx = np.stack(
            [rng.integers(0, s, 200) for s in shape], axis=1
        )
        fitter.update(idx, rng.random(200).astype(np.float32))
        fitter.update(idx, rng.random(200).astype(np.float32))
    finally:
        obs.set_fit_log(None)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    slabs = [r for r in recs if r["event"] == "fit_slab"]
    assert len(slabs) == 2
    for r in slabs:
        assert r["codec"] == "nttd"
        assert isinstance(r["loss"], float)
        assert r["entries"] == 200
        assert r["entries_per_sec"] > 0
        assert 0 < r["reservoir_fill"] <= r["reservoir_capacity"] == 128
    assert slabs[0]["step"] == 0 and slabs[1]["step"] == 1


def test_versioned_store_emits_rekey_events(tmp_path):
    from repro.temporal import VersionedStore

    path = tmp_path / "fit.jsonl"
    obs.set_fit_log(str(path))
    try:
        rng = np.random.default_rng(3)
        base = rng.random((12, 10)).astype(np.float32)
        with VersionedStore.create(
            str(tmp_path / "v.tcdc"), "ttd", keyframe_interval=4,
            keyframe_opts={"max_rank": 4}, delta_opts={"max_rank": 2},
        ) as store:
            for k in range(3):
                store.append(base + 0.01 * k)
    finally:
        obs.set_fit_log(None)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    vas = [r for r in recs if r["event"] == "version_append"]
    assert [r["version"] for r in vas] == [0, 1, 2]
    assert vas[0]["keyframe"] is True and vas[1]["keyframe"] is False
    for r in vas:
        assert r["bytes"] > 0 and 0 <= r["fitness"] <= 1 + 1e-9
        assert r["rekeyed"] is False


# ---------------------------------------------------------------------------
# end-to-end: one stitched cross-process trace
# ---------------------------------------------------------------------------
def _nttd_payload(tmp_path) -> tuple[str, tuple[int, ...]]:
    rng = np.random.default_rng(1)
    shape = (16, 12, 8)
    x = rng.random(shape).astype(np.float32)
    enc = get_codec("nttd").fit(
        x, rank=4, hidden=8, epochs=1, init_reorder=False,
        update_reorder=False, batch_size=2048, eval_batch=2048,
    )
    path = str(tmp_path / "nttd.tcdc")
    write_chunked(path, enc, chunk_bytes=2048)
    return path, shape


def test_socket_fleet_decode_is_one_stitched_trace(tmp_path, recorder,
                                                   monkeypatch):
    # fused impl routes worker decode through ops.nttd_decode_tile, so
    # the trace must contain kernel_decode spans; spawned workers inherit
    # the env (REPRO_TRACE included) from this process
    monkeypatch.setenv("REPRO_DECODE_IMPL", "fused")
    monkeypatch.setenv("REPRO_TRACE", "1")
    path, shape = _nttd_payload(tmp_path)
    fleet = FleetFrontend(
        ["w0", "w1"],
        transport_factory=lambda iid: SocketTransport.spawn(iid, timeout=60.0),
    )
    try:
        fleet.load_stream("nttd", path, tile_entries=96)
        recorder.clear()  # only the query's spans, not load-time ones
        rng = np.random.default_rng(5)
        idx = np.stack([rng.integers(0, s, 300) for s in shape], axis=1)
        fleet.decode_at("nttd", idx)
        metrics = collect(fleet).as_dict()
    finally:
        fleet.close()

    spans = recorder.snapshot()
    root = [s for s in spans if s.name == "fleet.decode_at"]
    assert len(root) == 1
    trace = [s for s in spans if s.trace_id == root[0].trace_id]
    names = {s.name for s in trace}
    # frontend + transport + worker service stages + kernel, ONE trace id
    assert {
        "fleet.decode_at", "fleet.submit", "fleet.flush", "transport.flush",
        "decode_at", "coalesce_flush", "tile_decode", "kernel_decode",
    } <= names
    instances = {s.instance for s in trace}
    assert "frontend" in instances
    assert instances & {"w0", "w1"}  # worker spans stitched in
    # worker spans were re-based onto the frontend timeline: every span
    # nests inside the root's window (small slack for clock-offset error)
    slack = 0.05
    for s in trace:
        assert s.t_start >= root[0].t_start - slack
        assert s.t_end <= root[0].t_end + slack
    # kernel spans parent under a worker-side stage of the same trace
    kid = next(s for s in trace if s.name == "kernel_decode")
    assert kid.parent_id in {s.span_id for s in trace}

    # export renders it as a loadable Chrome trace with the metrics riding
    out = str(tmp_path / "trace.json")
    obs.export_chrome_trace(out, spans=trace, metrics=metrics)
    doc = json.load(open(out))
    pids = {
        e["args"]["name"]
        for e in doc["traceEvents"]
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert "frontend" in pids and pids & {"w0", "w1"}
    assert report.main([out]) == 0
