"""Per-arch smoke tests (reduced configs): forward/train step shapes +
finiteness, prefill/decode consistency against teacher forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.optim import optimizers
from repro.train import step as step_lib

ARCHS = configs.ARCH_IDS


def _inputs(cfg, key, B=2, S=16):
    if cfg.input_kind == "embeddings":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32) * 0.1}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    B, S = 2, 16
    inp = _inputs(cfg, key, B, S)
    logits, aux = model.forward(params, cfg, **inp)
    assert logits.shape[:2] == (B, S)
    assert logits.shape[2] >= cfg.vocab
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = dict(inp, labels=labels)
    opt = optimizers.adamw(1e-3, max_grad_norm=1.0)
    opt_state = opt.init(params)
    train_step = jax.jit(step_lib.make_train_step(cfg, opt))
    params2, opt_state, metrics = train_step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = optimizers.global_norm(
        jax.tree.map(lambda a, b: a - b, params, params2)
    )
    assert float(delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency(arch):
    # no-drop capacity so MoE decode == teacher forcing exactly
    cfg = dataclasses.replace(configs.get_smoke(arch), moe_capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    B, S = 2, 16
    inp = _inputs(cfg, key, B, S)
    logits, _ = model.forward(params, cfg, **inp)

    cache = model.init_cache(cfg, B, S + 4)
    lg_pref, cache = model.prefill(params, cfg, cache=cache, **inp)
    np.testing.assert_allclose(
        np.asarray(lg_pref, np.float32),
        np.asarray(logits[:, -1:, :], np.float32),
        rtol=2e-3, atol=2e-3,
    )

    if cfg.input_kind == "embeddings":
        nxt = {"embeds": jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model)) * 0.1}
        ext = {"embeds": jnp.concatenate([inp["embeds"], nxt["embeds"]], axis=1)}
        dec = {"embeds": nxt["embeds"]}
    else:
        t = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
        ext = {"tokens": jnp.concatenate([inp["tokens"], t], axis=1)}
        dec = {"token": t}
    lg_dec, cache = model.decode_step(params, cfg, cache=cache, cache_len=jnp.int32(S), **dec)
    lg_ext, _ = model.forward(params, cfg, **ext)
    np.testing.assert_allclose(
        np.asarray(lg_dec, np.float32),
        np.asarray(lg_ext[:, -1:, :], np.float32),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """Full-size configs build abstract trees (no allocation) with sane counts."""
    cfg = configs.get(arch)
    ab = model.abstract_params(cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(ab))
    expected_ballpark = {
        "deepseek-coder-33b": 33e9, "minicpm-2b": 2.7e9, "starcoder2-15b": 15e9,
        "qwen1.5-4b": 4e9, "grok-1-314b": 314e9,
        "llama4-maverick-400b-a17b": 400e9, "jamba-1.5-large-398b": 398e9,
        "mamba2-1.3b": 1.3e9, "internvl2-76b": 70e9, "musicgen-medium": 1.5e9,
    }[arch]
    assert 0.5 * expected_ballpark < n < 2.2 * expected_ballpark, (arch, n)


def test_scan_vs_unrolled_equivalence():
    cfg = configs.get_smoke("deepseek-coder-33b")
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab)
    a, _ = model.forward(params, cfg, tokens=toks)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    b, _ = model.forward(params, cfg2, tokens=toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
