"""NTTD model unit tests (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nttd
from repro.core.folding import make_folding_spec


def _setup(shape=(12, 10, 8), rank=4, hidden=8):
    spec = make_folding_spec(shape)
    cfg = nttd.NTTDConfig(rank=rank, hidden=hidden)
    params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg)
    return spec, cfg, params


def test_output_shape_and_finite():
    spec, cfg, params = _setup()
    rng = np.random.default_rng(0)
    pos = np.stack([rng.integers(0, n, 64) for n in spec.shape], axis=1)
    out = nttd.apply_at_positions(params, jnp.asarray(pos, jnp.int32), spec, cfg)
    assert out.shape == (64,)
    assert bool(jnp.isfinite(out).all())


def test_gradients_reach_every_param():
    spec, cfg, params = _setup()
    rng = np.random.default_rng(1)
    pos = np.stack([rng.integers(0, n, 128) for n in spec.shape], axis=1)
    vals = jnp.asarray(rng.normal(size=128), jnp.float32)

    def loss(p):
        preds = nttd.apply_at_positions(p, jnp.asarray(pos, jnp.int32), spec, cfg)
        return jnp.sum((preds - vals) ** 2)

    grads = jax.grad(loss)(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert float(jnp.abs(g).sum()) > 0, f"dead gradient at {path}"


def test_chain_matches_manual_product():
    """The TT chain equals an explicit per-entry matrix product."""
    spec, cfg, params = _setup(rank=3, hidden=8)
    rng = np.random.default_rng(2)
    pos = np.stack([rng.integers(0, n, 8) for n in spec.shape], axis=1)
    fidx = spec.fold_indices(pos)
    out = nttd.apply(params, jnp.asarray(fidx, jnp.int32), spec, cfg)

    # manual recomputation
    embeds = [
        params[f"embed_{m}"][fidx[:, j]] for j, m in enumerate(spec.folded_shape)
    ]
    x = jnp.stack(embeds, axis=1)
    from repro.kernels import ref

    hs = ref.lstm_scan(x, params["lstm"]["wi"], params["lstm"]["wh"], params["lstm"]["b"])
    r = cfg.rank
    manual = []
    for b in range(8):
        t = (hs[b, 0] @ params["head_first"]["w"] + params["head_first"]["b"])[None, :]
        for k in range(1, spec.d_prime - 1):
            m = (hs[b, k] @ params["head_mid"]["w"] + params["head_mid"]["b"]).reshape(r, r)
            t = t @ m
        last = (hs[b, -1] @ params["head_last"]["w"] + params["head_last"]["b"])[:, None]
        manual.append((t @ last)[0, 0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(manual), rtol=2e-5, atol=2e-5)


def test_count_params_matches_theorem1_structure():
    spec, cfg, params = _setup(rank=4, hidden=8)
    h, r = 8, 4
    expected = (
        sum(m * h for m in set(spec.folded_shape))  # shared embedding tables
        + (h * 4 * h) * 2 + 4 * h                   # LSTM
        + h * r + r                                 # first head
        + h * r * r + r * r                         # shared mid head
        + h * r + r                                 # last head
    )
    assert nttd.count_params(params) == expected


def test_generate_tensor_matches_pointwise():
    spec, cfg, params = _setup(shape=(6, 5, 4))
    full = nttd.generate_tensor(params, spec, cfg, batch=64)
    rng = np.random.default_rng(3)
    pos = np.stack([rng.integers(0, n, 32) for n in spec.shape], axis=1)
    vals = nttd.apply_at_positions(params, jnp.asarray(pos, jnp.int32), spec, cfg)
    np.testing.assert_allclose(
        full[tuple(pos[:, j] for j in range(3))], np.asarray(vals), rtol=1e-5, atol=1e-5
    )
