"""Backward-compatibility matrix over the checked-in golden containers.

Every on-disk format the loaders have ever produced must keep decoding
to the values frozen in ``tests/golden/expected.npz`` — through the
eager path (``codecs.load_bytes``) and, for container formats, the lazy
serve path (``CodecService.load_stream``).  Regenerate the fixtures only
via ``scripts/make_golden.py`` (and only to ADD a format).
"""
import os

import numpy as np
import pytest

from repro.codecs import container, load_bytes
from repro.serve.codec_service import CodecService

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")
_NPZ = np.load(os.path.join(GOLDEN, "expected.npz"))
IDX = _NPZ["indices"]


def _path(name: str) -> str:
    return os.path.join(GOLDEN, name)


def _read(name: str) -> bytes:
    with open(_path(name), "rb") as f:
        return f.read()


def _check(values, key: str) -> None:
    np.testing.assert_allclose(
        np.asarray(values, np.float64), _NPZ[key], rtol=1e-5, atol=1e-6
    )


class TestLoadBytes:
    def test_v2_legacy_nttd(self):
        enc = load_bytes(_read("v2_nttd.bin"))
        _check(enc.decode_at(IDX), "v2_nttd")

    def test_v3_monolithic(self):
        enc = load_bytes(_read("v3_mono.tcdc"))
        _check(enc.decode_at(IDX), "v3")

    def test_v3_chunked(self):
        enc = load_bytes(_read("v3_chunked.tcdc"))
        _check(enc.decode_at(IDX), "v3")

    def test_v4_delta_latest(self):
        enc = load_bytes(_read("v4_delta.tcdc"))  # chain of the LATEST version
        _check(enc.decode_at(IDX), "v4_version2")


class TestServeLayer:
    @pytest.mark.parametrize("name,key", [
        ("v3_mono.tcdc", "v3"),
        ("v3_chunked.tcdc", "v3"),
    ])
    def test_v3_load_stream(self, name, key):
        svc = CodecService()
        svc.load_stream("g", _path(name))
        _check(svc.decode_at("g", IDX), key)

    def test_v4_load_stream_all_versions(self):
        svc = CodecService()
        svc.load_stream("g", _path("v4_delta.tcdc"))
        assert svc.info("g").n_versions == 3
        for v in range(3):
            _check(svc.decode_at("g", IDX, version=v), f"v4_version{v}")

    def test_v2_has_no_lazy_open(self):
        with pytest.raises(ValueError, match="lazy open"):
            container.open_container(_path("v2_nttd.bin"))
