"""SLO engine semantics (streaks, hysteresis, wildcards, burn rate),
Prometheus text exposition + the scrape server, bounded fit-log rotation,
the events buffer, and ``obs.report --format json``."""
import io
import json
import urllib.request

import pytest

from repro import obs
from repro.obs import report, serve_metrics
from repro.obs.exposition import render_exposition
from repro.obs.serve_metrics import MetricsServer
from repro.obs.slo import SLOEngine, SLOSpec, fleet_slo_sample


def _eval_seq(engine, key, values):
    out = []
    for t, v in enumerate(values):
        out.append(engine.evaluate({key: v}, now=float(t)))
    return out


# ---------------------------------------------------------------------------
# SLO engine
# ---------------------------------------------------------------------------
def test_breach_opens_on_exactly_the_nth_consecutive_violation():
    eng = SLOEngine([SLOSpec("lat", "p99", target=5.0, breach_for=3)])
    evs = _eval_seq(eng, "p99", [6.0, 6.0, 4.0, 6.0, 6.0, 6.0, 6.0])
    # the 4.0 resets the streak; breach fires on the 3rd of the new run
    assert [len(e) for e in evs] == [0, 0, 0, 0, 0, 1, 0]
    ev = evs[5][0]
    assert ev.kind == "breach_start" and ev.metric == "p99" and ev.at == 5.0
    assert eng.is_breached("lat") and eng.breached() == [("lat", "p99")]


def test_hysteresis_band_holds_state_and_resets_streaks():
    eng = SLOEngine([
        SLOSpec("lat", "p99", target=5.0, clear=4.0, breach_for=2, clear_for=2)
    ])
    _eval_seq(eng, "p99", [6.0, 6.0])  # breach opens
    assert eng.is_breached("lat")
    # in-band values (4 < v <= 5) hold the breach forever
    _eval_seq(eng, "p99", [4.5, 4.8, 4.2, 4.9])
    assert eng.is_breached("lat")
    # one clearing eval is not enough; a band value resets the good streak
    evs = _eval_seq(eng, "p99", [3.0, 4.5, 3.0, 3.0])
    assert [len(e) for e in evs] == [0, 0, 0, 1]
    assert evs[-1][0].kind == "breach_end"
    assert not eng.is_breached("lat")


def test_none_values_are_skipped_without_touching_state():
    eng = SLOEngine([SLOSpec("lat", "p99", target=5.0, breach_for=2)])
    evs = _eval_seq(eng, "p99", [6.0, None, 6.0])
    assert [len(e) for e in evs] == [0, 0, 1]  # None neither resets nor counts


def test_wildcard_metric_tracks_each_concrete_key_separately():
    eng = SLOEngine([SLOSpec("fit", "canary_fitness.*", target=0.9, op=">=")])
    evs = eng.evaluate({"canary_fitness.a": 0.5, "canary_fitness.b": 0.95})
    assert [(e.kind, e.metric) for e in evs] == [
        ("breach_start", "canary_fitness.a")
    ]
    assert eng.is_breached("fit", "canary_fitness.a")
    assert not eng.is_breached("fit", "canary_fitness.b")


def test_burn_rate_is_the_violating_window_fraction():
    eng = SLOEngine([SLOSpec("lat", "p99", target=5.0, window=4)])
    _eval_seq(eng, "p99", [6.0, 3.0, 6.0, 6.0])
    assert eng.burn_rate("lat", "p99") == pytest.approx(0.75)
    assert eng.burn_rate("lat", "nope") == 0.0


def test_spec_validation():
    with pytest.raises(ValueError, match="op"):
        SLOSpec("x", "m", target=1.0, op="==")
    with pytest.raises(ValueError, match="looser"):
        SLOSpec("x", "m", target=5.0, clear=6.0)  # op <=
    with pytest.raises(ValueError, match="looser"):
        SLOSpec("x", "m", target=0.9, clear=0.8, op=">=")
    with pytest.raises(ValueError, match="breach_for"):
        SLOSpec("x", "m", target=1.0, breach_for=0)
    with pytest.raises(ValueError, match="duplicate"):
        SLOEngine([SLOSpec("a", "m", target=1.0), SLOSpec("a", "n", target=1.0)])


def test_fleet_slo_sample_flattens_snapshot():
    snap = {
        "decode_p50_ms": 1.0,
        "decode_p99_ms": 4.0,
        "excluded": ["i1"],
        "excluded_total": 2,
        "backpressure_flushes": 3,
        "instances": {
            "i0": {"cache": {"hit_rate": 0.5}, "flushes": 7},
            "i1": {"cache": {}, "flushes": 0},
        },
        "canary": {"embed": {"rolling_fitness": 0.97}},
    }
    s = fleet_slo_sample(snap)
    assert s["decode_p99_ms"] == 4.0
    assert s["excluded_total"] == 2
    assert s["instances"] == 2 and s["flushes_total"] == 7
    assert s["hit_rate.i0"] == 0.5 and s["hit_rate.i1"] is None
    assert s["canary_fitness.embed"] == 0.97
    assert fleet_slo_sample(snap, extra={"q": 1})["q"] == 1


# ---------------------------------------------------------------------------
# exposition + scrape server
# ---------------------------------------------------------------------------
def _registry():
    reg = obs.MetricsRegistry()
    reg.counter("decode_calls", instance="i0").inc(3)
    reg.gauge("canary_fitness", payload="e").set(0.75)
    h = reg.histogram("decode_ms", buckets=(1.0, 10.0))
    for v in (0.5, 2.0, 20.0):
        h.observe(v)
    return reg


def test_exposition_renders_live_registry_histograms():
    text = render_exposition(registry=_registry())
    assert '# TYPE decode_calls counter' in text
    assert 'decode_calls{instance="i0"} 3' in text
    assert 'canary_fitness{payload="e"} 0.75' in text
    # full cumulative histogram, not a summary
    assert 'decode_ms_bucket{le="1.0"} 1' in text
    assert 'decode_ms_bucket{le="10.0"} 2' in text
    assert 'decode_ms_bucket{le="+Inf"} 3' in text
    assert 'decode_ms_count 3' in text
    assert text.endswith("\n")


def test_exposition_renders_snapshot_and_fleet():
    snap = _registry().as_dict()
    fleet = {
        "fleet": {"hits": 5, "misses": 1, "hit_rate": 5 / 6},
        "backpressure_flushes": 0,
        "excluded": [],
        "excluded_total": 1,
        "decode_p99_ms": None,
        "canary": {"e": {"checks": 2, "breaches": 0, "rolling_fitness": 0.9}},
        "instances": {"i0": {"cache": {"hits": 5}, "flushes": 4}},
    }
    text = render_exposition(registry=snap, fleet=fleet)
    assert '# TYPE decode_ms summary' in text  # snapshot = quantile series
    assert 'decode_ms{quantile="0.5"}' in text
    assert 'repro_fleet_cache_hits 5' in text
    assert 'repro_fleet_excluded_total 1' in text
    assert "repro_fleet_decode_p99_ms" not in text  # None -> omitted
    assert 'repro_fleet_canary_fitness{payload="e"} 0.9' in text
    assert 'repro_fleet_instance_flushes{instance="i0"} 4' in text


def test_metrics_server_scrapes_and_404s():
    with MetricsServer(lambda: render_exposition(registry=_registry())) as srv:
        host, port = srv.address
        body = urllib.request.urlopen(
            f"http://{host}:{port}/metrics"
        ).read().decode()
        assert 'decode_calls{instance="i0"} 3' in body
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(f"http://{host}:{port}/other")
        assert e.value.code == 404


def test_serve_metrics_once_cli(tmp_path, capsys):
    snap = tmp_path / "fleet.json"
    snap.write_text(json.dumps({
        "fleet": {"hits": 1, "misses": 0},
        "instances": {},
    }))
    assert serve_metrics.main([str(snap), "--once"]) == 0
    assert "repro_fleet_cache_hits 1" in capsys.readouterr().out
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert serve_metrics.main([str(bad), "--once"]) == 1


# ---------------------------------------------------------------------------
# bounded fit log + events buffer
# ---------------------------------------------------------------------------
def test_event_log_rotates_owned_path(tmp_path):
    p = tmp_path / "fit.jsonl"
    log = obs.JsonlEventLog(str(p), max_bytes=512, backups=2)
    for k in range(64):
        log.emit("tick", step=k, pad="x" * 32)
    log.close()
    assert log.rotations > 0 and log.events_dropped == 0
    assert p.exists() and p.stat().st_size <= 512
    assert (tmp_path / "fit.jsonl.1").exists()
    assert not (tmp_path / "fit.jsonl.3").exists()  # backups honored
    # every surviving line is intact JSON, newest file has the tail
    recs = [json.loads(s) for s in p.read_text().splitlines()]
    assert recs[-1]["step"] == 63


def test_event_log_drops_when_sink_is_borrowed():
    buf = io.StringIO()
    log = obs.JsonlEventLog(buf, max_bytes=128)
    for k in range(32):
        log.emit("tick", step=k)
    assert log.events_dropped > 0
    assert log.bytes_written <= 128
    kept = [json.loads(s) for s in buf.getvalue().splitlines()]
    assert kept and kept[0]["step"] == 0  # oldest kept, newest dropped


def test_events_buffer_and_fit_log_forwarding():
    buf = io.StringIO()
    obs.set_fit_log(buf)
    try:
        obs.clear_events()
        obs.emit_event("quality_breach", payload="e", fitness=0.5)
        obs.emit_event("controller_decision", action="hold")
        assert [e["event"] for e in obs.events()] == [
            "quality_breach", "controller_decision",
        ]
        breaches = obs.events("quality_breach")
        assert len(breaches) == 1 and breaches[0]["payload"] == "e"
        assert breaches[0]["t"] > 0
        forwarded = [json.loads(s) for s in buf.getvalue().splitlines()]
        assert [r["event"] for r in forwarded] == [
            "quality_breach", "controller_decision",
        ]
        obs.clear_events()
        assert obs.events() == []
    finally:
        obs.set_fit_log(None)


# ---------------------------------------------------------------------------
# report --format json
# ---------------------------------------------------------------------------
def test_report_json_format(tmp_path, capsys):
    obs.enable_tracing()
    try:
        with obs.span("controller.step"):
            with obs.span("controller.scale_up", instance="s0"):
                pass
        trace = tmp_path / "trace.json"
        obs.export_chrome_trace(
            str(trace), metrics={"fleet": {"hits": 1, "misses": 0}}
        )
    finally:
        obs.disable_tracing()
    assert report.main([str(trace), "--format", "json", "--top", "2"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["spans"] == 2
    stages = {r["stage"] for r in doc["stages"]}
    assert stages == {"controller.step", "controller.scale_up"}
    slowest = {s["stage"]: s for s in doc["slowest"]}
    assert slowest["controller.scale_up"]["args"]["instance"] == "s0"
    assert "trace_id" not in slowest["controller.step"]["args"]
    assert doc["metrics"]["fleet"]["hits"] == 1
    # text mode still works on the same file
    assert report.main([str(trace), "--top", "2"]) == 0
