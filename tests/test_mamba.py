"""Mamba2 SSD: chunked parallel form == exact recurrence (state-space
duality), padding exactness, state handoff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.dist import sharding
from repro.models import mamba


def _cfg(**kw):
    base = dict(
        arch_id="t", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=64, ssm_state=8, ssm_expand=2, ssm_head_dim=16,
        ssm_groups=1, ssm_chunk=8, param_dtype="float32", compute_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, key):
    return sharding.materialize(key, mamba.mamba_specs(cfg), jnp.float32)


def _sequential_reference(p, x, cfg):
    """Decode the whole sequence one token at a time (ground truth)."""
    d = mamba.dims(cfg)
    bs = x.shape[0]
    state = {
        "conv": jnp.zeros((bs, cfg.ssm_conv - 1, d["conv_dim"])),
        "ssm": jnp.zeros((bs, d["n_heads"], cfg.ssm_head_dim, cfg.ssm_state)),
    }
    outs = []
    for t in range(x.shape[1]):
        y, state = mamba.mamba_forward(p, x[:, t : t + 1], cfg, state)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), state


@pytest.mark.parametrize("seq,groups", [(16, 1), (24, 2), (13, 1)])
def test_ssd_equals_recurrence(seq, groups):
    cfg = _cfg(ssm_groups=groups)
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model)) * 0.5
    y_par, st_par = mamba.mamba_forward(p, x, cfg, None)
    y_seq, st_seq = _sequential_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(st_par["ssm"]), np.asarray(st_seq["ssm"]), rtol=2e-4, atol=2e-4
    )
    np.testing.assert_allclose(
        np.asarray(st_par["conv"]), np.asarray(st_seq["conv"]), rtol=1e-5, atol=1e-5
    )


def test_prefill_then_decode_continues_exactly():
    cfg = _cfg()
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.d_model)) * 0.5
    # parallel over the first 16, then recurrent decode of the last 4
    y_par, state = mamba.mamba_forward(p, x[:, :16], cfg, None)
    outs = [y_par]
    for t in range(16, 20):
        y, state = mamba.mamba_forward(p, x[:, t : t + 1], cfg, state)
        outs.append(y)
    y_mixed = jnp.concatenate(outs, axis=1)
    y_full, _ = mamba.mamba_forward(p, x, cfg, None)
    np.testing.assert_allclose(np.asarray(y_mixed), np.asarray(y_full), rtol=3e-4, atol=3e-4)


def test_chunk_boundary_invariance():
    """Output must not depend on the chunk size."""
    p = _params(_cfg(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32)) * 0.5
    outs = []
    for q in (4, 8, 16, 32):
        cfg = _cfg(ssm_chunk=q)
        y, _ = mamba.mamba_forward(p, x, cfg, None)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)
