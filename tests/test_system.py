"""End-to-end system tests: training loop, serving engine, and the
TensorCodec <-> framework integrations."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model


def test_train_loop_loss_decreases(tmp_path):
    from repro.launch import train as train_launch

    losses = train_launch.main([
        "--arch", "musicgen-medium", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10",
        "--log-every", "100",
    ])
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_train_resume_continues(tmp_path):
    from repro.launch import train as train_launch

    d = str(tmp_path / "ck")
    train_launch.main([
        "--arch", "musicgen-medium", "--smoke", "--steps", "10",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "10",
        "--log-every", "100",
    ])
    losses = train_launch.main([
        "--arch", "musicgen-medium", "--smoke", "--steps", "14",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d, "--resume", "auto",
        "--log-every", "100",
    ])
    assert len(losses) == 4  # resumed at step 10, ran 10..13


def test_train_with_grad_compression():
    from repro.launch import train as train_launch

    losses = train_launch.main([
        "--arch", "musicgen-medium", "--smoke", "--steps", "20",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--grad-compress", "int8", "--log-every", "100",
    ])
    assert losses[-1] < losses[0]


def test_serve_engine_matches_manual_greedy():
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke("qwen1.5-4b")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=12)

    engine = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    engine.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    results = engine.run()
    got = results[0].tokens

    # manual greedy decode
    toks = jnp.asarray(prompt, jnp.int32)[None]
    want = []
    for _ in range(6):
        logits, _ = model.forward(params, cfg, tokens=toks)
        nxt = int(jnp.argmax(logits[0, -1, : cfg.vocab]))
        want.append(nxt)
        toks = jnp.concatenate([toks, jnp.asarray([[nxt]], jnp.int32)], axis=1)
    assert got == want, (got, want)


def test_serve_engine_batching_many_requests():
    from repro.serve.engine import Request, ServeEngine

    cfg = configs.get_smoke("musicgen-medium")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    engine = ServeEngine(cfg, params, batch_slots=3, max_len=48)
    for uid in range(7):
        engine.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, size=8),
                              max_new_tokens=4))
    results = engine.run()
    assert sorted(r.uid for r in results) == list(range(7))
    assert all(len(r.tokens) == 4 for r in results)


def test_checkpoint_codec_roundtrip():
    from repro.compress import checkpoint_codec as cc

    rng = np.random.default_rng(0)
    # a smooth weight-like matrix compresses; a tiny leaf stays raw
    u = rng.normal(size=(256, 8)) @ rng.normal(size=(8, 128))
    tree = {
        "embed": jnp.asarray(u, jnp.float32),
        "bias": jnp.asarray(rng.normal(size=(8,)), jnp.float32),
    }
    payload, stats = cc.compress_tree(
        tree, cc.CodecCheckpointConfig(min_elements=1024, min_fitness=0.7,
                                       epochs=40, rank=8, hidden=16)
    )
    assert payload["bias"]["kind"] == "raw"
    restored = cc.decompress_tree(payload, tree)
    np.testing.assert_array_equal(np.asarray(restored["bias"]), np.asarray(tree["bias"]))
    if payload["embed"]["kind"] == "nttd":
        rel = np.linalg.norm(restored["embed"] - u) / np.linalg.norm(u)
        assert rel < 0.35
        assert stats["ratio"] > 1.0


def test_nttd_embedding_lookup():
    from repro.models.nttd_embed import NTTDEmbedding

    rng = np.random.default_rng(0)
    # realistic embeddings: rows are smooth functions of a latent coordinate
    # (cluster structure), with arbitrary token-id assignment (shuffled) —
    # the reordering technique recovers the latent adjacency
    lat = np.linspace(0, 3, 128)
    basis = np.stack(
        [np.sin(lat * f + p) for f, p in [(1, 0), (2, 1), (3, 2), (0.5, 0.5)]], 1
    )
    table = (basis @ rng.normal(size=(4, 32))).astype(np.float32)
    table = table[rng.permutation(128)]
    emb = NTTDEmbedding.fit(table, rank=8, hidden=16, epochs=150)
    ids = jnp.asarray(rng.integers(0, 128, size=(2, 5)), jnp.int32)
    out = np.asarray(emb.lookup(ids))
    want = table[np.asarray(ids)]
    rel = np.linalg.norm(out - want) / np.linalg.norm(want)
    assert rel < 0.5, rel
    assert emb.payload_bytes() < emb.raw_bytes()
    # the ratio materializes at production vocab sizes: theta is
    # size-independent (Theorem 2); only the pi bits grow (N log N).
    # project the same R/h payload onto qwen1.5-4b's 151936 x 2560 table:
    from repro.core import nttd as nttd_lib

    theta_bytes = nttd_lib.count_params(emb.ct.params) * 4
    pi_bits = 151936 * 18 + 2560 * 12
    projected = theta_bytes + pi_bits // 8
    raw = 151936 * 2560 * 4
    assert raw / projected > 1000, raw / projected
