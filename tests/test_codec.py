"""End-to-end TensorCodec behaviour (paper Alg. 1 + §V claims, scaled)."""
import numpy as np
import pytest

from repro.core import codec


def _smooth(shape=(24, 20, 16)):
    g = np.meshgrid(*[np.linspace(0, 2, n) for n in shape], indexing="ij")
    return (np.sin(3 * g[0] + g[1]) * np.cos(g[2]) + 0.3 * g[1]).astype(np.float32)


@pytest.fixture(scope="module")
def smooth_run():
    x = _smooth()
    ct, log = codec.compress(
        x,
        codec.CodecConfig(
            rank=6, hidden=12, epochs=120, batch_size=2048, lr=1e-2,
            init_reorder=False, update_reorder=False, patience=15,
        ),
    )
    return x, ct, log


def test_fitness_on_smooth_tensor(smooth_run):
    x, ct, log = smooth_run
    assert ct.fitness(x) > 0.8


def test_fitness_history_trends_up(smooth_run):
    _, _, log = smooth_run
    hist = log.fitness_history
    assert hist[-1] > hist[0] + 0.2


def test_compression_ratio(smooth_run):
    # tiny test tensor, so the ratio is modest; real ratios are measured in
    # benchmarks/fig3 on the Table-II-sized replicas
    x, ct, _ = smooth_run
    assert ct.payload_bytes(4) < x.size * 4 / 3  # >3x vs fp32 entries


def test_decode_matches_to_dense(smooth_run):
    x, ct, _ = smooth_run
    rng = np.random.default_rng(0)
    idx = np.stack([rng.integers(0, n, 50) for n in x.shape], axis=1)
    dense = ct.to_dense()
    np.testing.assert_allclose(
        ct.decode(idx), dense[tuple(idx[:, j] for j in range(3))], rtol=1e-4, atol=1e-4
    )


def test_reordering_recovers_permuted_smooth():
    """Full TensorCodec on a permuted smooth tensor beats the no-reorder
    ablation (the paper's Fig. 4 ordering, scaled down)."""
    rng = np.random.default_rng(0)
    x = _smooth((20, 16, 12))
    xp = x[rng.permutation(20)][:, rng.permutation(16)][:, :, rng.permutation(12)]
    common = dict(rank=5, hidden=10, epochs=80, batch_size=2048, lr=1e-2, patience=12)
    full, _ = codec.compress(xp, codec.CodecConfig(**common))
    none, _ = codec.compress(
        xp, codec.CodecConfig(init_reorder=False, update_reorder=False, **common)
    )
    assert full.fitness(xp) > none.fitness(xp) + 0.05


def test_normalization_off_still_works():
    x = _smooth((12, 10, 8)) * 50 + 200  # far from zero mean
    ct, _ = codec.compress(
        x,
        codec.CodecConfig(
            rank=4, hidden=8, epochs=60, batch_size=1024, normalize=True,
            init_reorder=False, update_reorder=False,
        ),
    )
    assert ct.fitness(x) > 0.5


def test_payload_accounting_matches_theorem2():
    x = _smooth((12, 10, 8))
    ct, _ = codec.compress(
        x, codec.CodecConfig(rank=4, hidden=8, epochs=2, init_reorder=False,
                             update_reorder=False)
    )
    from repro.core import nttd

    n_params = nttd.count_params(ct.params)
    pi_bits = sum(n * int(np.ceil(np.log2(n))) for n in x.shape)
    assert ct.payload_bits() == n_params * 64 + pi_bits + 2 * 64
