"""Distributed tests on a forced 8-host-device mesh (subprocess — the main
test process must keep the real 1-device CPU view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_pjit_train_step_matches_single_device():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.dist import sharding
    from repro.models import model
    from repro.optim import optimizers
    from repro.train import step as step_lib

    cfg = configs.get_smoke('minicpm-2b')
    key = jax.random.PRNGKey(0)
    params = model.init_params(key, cfg)
    opt = optimizers.adamw(1e-3, max_grad_norm=1.0)
    toks = jax.random.randint(key, (8, 16), 0, cfg.vocab)
    batch = {'tokens': toks, 'labels': jnp.roll(toks, -1, 1)}

    # single device
    step1 = step_lib.make_train_step(cfg, opt)
    p1, o1, m1 = jax.jit(step1)(params, opt.init(params), batch)

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    rules = sharding.BASE_RULES
    ps = step_lib.param_shardings(mesh, cfg, rules)
    with sharding.sharding_ctx(mesh, rules):
        p_sh = jax.device_put(params, ps)
        o_sh = jax.jit(opt.init, out_shardings=step_lib.opt_shardings(mesh, cfg, rules))(p_sh)
        p2, o2, m2 = jax.jit(step_lib.make_train_step(cfg, opt))(p_sh, o_sh, batch)

    np.testing.assert_allclose(float(m1['loss']), float(m2['loss']), rtol=1e-4)
    l1 = jax.tree.leaves(p1); l2 = jax.tree.leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
    print('OK')
    """)


def test_elastic_checkpoint_reshard():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train import checkpoint as ckpt_lib

    tree = {'w': jnp.arange(64.0).reshape(8, 8), 's': jnp.float32(3.0)}
    d = tempfile.mkdtemp()
    mesh_a = jax.make_mesh((2, 4), ('data', 'model'))
    sh_a = {'w': NamedSharding(mesh_a, P('data', 'model')), 's': NamedSharding(mesh_a, P())}
    tree_a = jax.device_put(tree, sh_a)
    ck = ckpt_lib.Checkpointer(d, async_save=False)
    ck.save(1, tree_a)

    # restore onto a DIFFERENT mesh shape
    mesh_b = jax.make_mesh((4, 2), ('data', 'model'))
    sh_b = {'w': NamedSharding(mesh_b, P('model', 'data')), 's': NamedSharding(mesh_b, P())}
    restored, manifest = ck.restore(1, tree, sh_b)
    np.testing.assert_allclose(np.asarray(restored['w']), np.arange(64.0).reshape(8, 8))
    assert restored['w'].sharding == sh_b['w']
    print('OK')
    """)


def test_pipeline_parallel_forward_equivalence():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import pipeline_parallel as pp

    mesh = jax.make_mesh((8,), ('pod',))
    L, D = 8, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * (1.0 / np.sqrt(D))

    def fwd_block(params, x):
        # params: [L/S, D, D] — apply each layer in the stage
        def body(x, wi):
            return jax.nn.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, params)
        return x

    M, mb = 4, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))

    # reference: sequential
    ref = fwd_block(w, x.reshape(M * mb, D)).reshape(M, mb, D)

    stage_params = pp.split_stages(w, 8)
    out = pp.pipeline_forward(fwd_block, stage_params, x, mesh, axis='pod')
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    print('OK')
    """)


def test_codec_train_step_data_parallel():
    """The paper's own compression step runs data-parallel over entries."""
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import codec, nttd
    from repro.core.folding import make_folding_spec
    from repro.optim import optimizers

    spec = make_folding_spec((16, 12, 10))
    cfg = nttd.NTTDConfig(rank=4, hidden=8)
    params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg)
    opt = optimizers.adam(1e-2)
    ost = opt.init(params)
    step = codec._make_train_epoch(spec, cfg, opt)

    rng = np.random.default_rng(0)
    pos = np.stack([rng.integers(0, n, (4, 512)) for n in spec.shape], -1)
    vals = rng.normal(size=(4, 512)).astype(np.float32)

    p1, o1, l1 = step(params, ost, jnp.asarray(pos, jnp.int32), jnp.asarray(vals))

    mesh = jax.make_mesh((8,), ('data',))
    shp = NamedSharding(mesh, P(None, 'data'))
    p2, o2, l2 = jax.jit(step, in_shardings=(None, None, shp, shp))(
        params, ost, jnp.asarray(pos, jnp.int32), jnp.asarray(vals))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    print('OK')
    """)


@pytest.mark.slow
def test_dryrun_smoke_cell():
    """One reduced dry-run cell end-to-end in a subprocess (512 devices)."""
    run_sub("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'
    from repro.launch import dryrun
    res = dryrun.run_cell('mamba2-1.3b', 'decode_32k', 'single', verbose=False)
    assert res['status'] == 'ok', res
    assert res['roofline']['bound_s'] > 0
    assert res['flops_per_device'] > 0
    print('OK')
    """, devices=512)
