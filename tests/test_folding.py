"""Property sweeps for the TT-tensor folding index math (paper Eq. 4).

hypothesis is unavailable offline; properties are checked over seeded
randomized shape grids (same invariants, deterministic).
"""
import numpy as np
import pytest

from repro.core.folding import choose_factors, default_d_prime, make_folding_spec

RNG = np.random.default_rng(0)
SHAPES = [
    (8,), (5,), (7, 3), (16, 16), (12, 9, 30), (963, 144, 440)[:2],
    (40, 25, 30), (31, 17, 5), (8, 8, 8, 8), (13, 7, 11, 3), (183, 24, 57),
]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_fold_unfold_bijective(shape):
    spec = make_folding_spec(shape)
    n = int(np.prod(shape))
    take = min(n, 5000)
    flat = RNG.choice(n, size=take, replace=False)
    dims = np.array(shape)
    radix = np.concatenate([np.cumprod(dims[::-1])[::-1][1:], [1]])
    idx = (flat[:, None] // radix) % dims
    folded = spec.fold_indices(idx)
    # folded indices are in range
    assert (folded >= 0).all()
    assert (folded < np.array(spec.folded_shape)).all()
    back = spec.unfold_indices(folded)
    np.testing.assert_array_equal(back, idx)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_fold_injective(shape):
    """Distinct original entries never collide in the folded tensor."""
    spec = make_folding_spec(shape)
    n = int(np.prod(shape))
    take = min(n, 4000)
    flat = RNG.choice(n, size=take, replace=False)
    dims = np.array(shape)
    radix = np.concatenate([np.cumprod(dims[::-1])[::-1][1:], [1]])
    idx = (flat[:, None] // radix) % dims
    folded = spec.fold_indices(idx)
    fdims = np.array(spec.folded_shape)
    fradix = np.concatenate([np.cumprod(fdims[::-1])[::-1][1:], [1]])
    keys = (folded * fradix).sum(axis=1)
    assert len(np.unique(keys)) == take


def test_choose_factors_properties():
    for dim in [1, 2, 3, 5, 17, 144, 963, 1140, 5600, 122753]:
        for dp in [default_d_prime((dim,)), default_d_prime((dim,)) + 2]:
            f = choose_factors(dim, dp)
            assert len(f) == dp
            assert all(1 <= x <= 5 for x in f)
            prod = int(np.prod(f))
            assert prod >= dim
            # minimality-ish: halving any 2 would undershoot
            assert prod // 2 < dim or all(x != 2 for x in f)


def test_padding_is_bounded():
    """Folded size stays within a small factor of the input size."""
    for shape in SHAPES:
        spec = make_folding_spec(shape)
        assert spec.padded_entries < 8 * spec.n_entries


def test_dprime_exceeds_order():
    for shape in SHAPES:
        spec = make_folding_spec(shape)
        assert spec.d_prime > len(shape)  # paper: d' > d
