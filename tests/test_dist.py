"""Unit tests for repro.dist: rule resolution (incl. missing-axis and
divisibility fallback to replication), error-feedback compressor mass
conservation, and pipeline stage splitting invariants."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import grad_compress, pipeline_parallel as pp, sharding

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


# ------------------------------------------------------------------ ParamSpec
def test_paramspec_rank_mismatch_rejected():
    with pytest.raises(ValueError):
        sharding.ParamSpec((2, 3), ("heads",))


def test_paramspec_counts_visible_to_tree():
    specs = {"a": sharding.ParamSpec((2, 3), ("heads", "mlp"))}
    leaves = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, sharding.ParamSpec)
    )
    assert len(leaves) == 1 and leaves[0].shape == (2, 3)


# ------------------------------------------------------------ rule resolution
def test_logical_pspec_missing_mesh_axis_falls_back():
    mesh = jax.make_mesh((1,), ("data",))
    # 'pod' and 'model' don't exist on this mesh: filtered out / replicated
    rules = {"batch": ("pod", "data"), "heads": "model", "mlp": None}
    spec = sharding.logical_pspec(("batch", "heads", "mlp"), rules, mesh)
    assert spec == P("data", None, None)


def test_logical_pspec_unknown_logical_axis_replicates():
    mesh = jax.make_mesh((1,), ("data",))
    spec = sharding.logical_pspec(("never_named", None), {}, mesh)
    assert spec == P(None, None)


def test_logical_pspec_first_dim_wins_on_axis_reuse():
    mesh = jax.make_mesh((1,), ("data",))
    rules = {"embed": "data", "vocab": "data"}
    spec = sharding.logical_pspec(("embed", "vocab"), rules, mesh)
    assert spec == P("data", None)


def test_tree_shardings_divisibility_and_rules_on_real_mesh():
    run_sub("""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    rules = dict(sharding.BASE_RULES)
    specs = {
        # 6 % 4 != 0 -> heads dim replicated; 120 % 4 == 0 -> mlp sharded
        'wq': sharding.ParamSpec((48, 6, 8), ('ffn_in', 'heads', 'head_dim')),
        'w_gate': sharding.ParamSpec((48, 120), ('ffn_in', 'mlp')),
        # 'pod' absent: batch resolves to ('data',) alone; 8 % 2 == 0
        'act': sharding.ParamSpec((8, 16, 48), ('batch', 'seq', 'act_embed')),
        # unknown axis -> replicated
        'odd': sharding.ParamSpec((7,), ('no_such_axis',)),
    }
    sh = sharding.tree_shardings(mesh, specs, rules)
    assert sh['wq'].spec == P(None, None, None), sh['wq'].spec
    assert sh['w_gate'].spec == P(None, 'model'), sh['w_gate'].spec
    assert sh['act'].spec == P('data', None, None), sh['act'].spec
    assert sh['odd'].spec == P(None), sh['odd'].spec
    print('OK')
    """)


def test_shard_is_identity_outside_ctx():
    x = jnp.ones((2, 3))
    assert sharding.shard(x, "batch", "act_embed") is x


# ----------------------------------------------------------- materialization
def test_materialize_init_kinds_and_determinism():
    specs = {
        "w": sharding.ParamSpec((64, 32), ("ffn_in", "mlp")),
        "norm": sharding.ParamSpec((32,), ("act_embed",), init="ones"),
        "b": sharding.ParamSpec((32,), ("mlp",), init="zeros"),
        "emb": sharding.ParamSpec((128, 64), ("vocab", "embed"), init="embed"),
        "cache": sharding.ParamSpec(
            (2, 4), ("batch", "kv_seq"), init="zeros", dtype=jnp.bfloat16
        ),
    }
    key = jax.random.PRNGKey(0)
    p = sharding.materialize(key, specs, jnp.float32)
    np.testing.assert_array_equal(np.asarray(p["norm"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p["b"]), 0.0)
    assert p["cache"].dtype == jnp.bfloat16
    # fan-in scaling: std ~ 1/sqrt(64)
    assert 0.5 / 8 < float(jnp.std(p["w"])) < 2.0 / 8
    assert 0.5 / 8 < float(jnp.std(p["emb"])) < 2.0 / 8
    # same key -> identical tree; sibling leaves decorrelated
    p2 = sharding.materialize(key, specs, jnp.float32)
    np.testing.assert_array_equal(np.asarray(p["w"]), np.asarray(p2["w"]))
    assert not np.allclose(
        np.asarray(p["w"][:, :32]).ravel()[:64], np.asarray(p["emb"]).ravel()[:64]
    )


def test_tree_abstract_shapes_and_dtype_override():
    specs = {
        "w": sharding.ParamSpec((4, 8), ("ffn_in", "mlp")),
        "s": sharding.ParamSpec((2,), ("batch",), dtype=jnp.int32),
    }
    ab = sharding.tree_abstract(specs, jnp.bfloat16)
    assert ab["w"].shape == (4, 8) and ab["w"].dtype == jnp.bfloat16
    assert ab["s"].dtype == jnp.int32


# ------------------------------------------------------------ grad compression
def test_int8_error_feedback_conserves_mass_exactly():
    comp = grad_compress.ErrorFeedbackInt8()
    grads = {"w": jnp.asarray([1.0, -3.0, 0.5, 100.0])}
    state = comp.init(grads)
    g1, state = comp.transform(grads, state)
    # decompressed + residual == original, to the bit
    np.testing.assert_allclose(
        np.asarray(g1["w"] + state["w"]), np.asarray(grads["w"]), rtol=0, atol=0
    )
    # quantization error bounded by half a quantization step
    step = float(jnp.abs(grads["w"]).max()) / 127.0
    assert float(jnp.abs(state["w"]).max()) <= 0.5 * step + 1e-7


def test_int8_zero_gradients_stay_zero():
    comp = grad_compress.ErrorFeedbackInt8()
    grads = {"w": jnp.zeros((5,))}
    g, state = comp.transform(grads, comp.init(grads))
    np.testing.assert_array_equal(np.asarray(g["w"]), 0.0)
    np.testing.assert_array_equal(np.asarray(state["w"]), 0.0)


def test_topk_keeps_exact_fraction_and_conserves_mass():
    comp = grad_compress.TopK(fraction=0.25)
    # distinct magnitudes: the k-th-value threshold keeps exactly k entries
    grads = {"w": (jnp.arange(16.0) + 1.0) * jnp.where(jnp.arange(16) % 2 == 0, 1, -1)}
    state = comp.init(grads)
    g1, state = comp.transform(grads, state)
    assert int(jnp.sum(g1["w"] != 0)) == 4
    np.testing.assert_allclose(
        np.asarray(g1["w"] + state["w"]), np.asarray(grads["w"]), rtol=0, atol=0
    )


def test_topk_fraction_validated():
    with pytest.raises(ValueError):
        grad_compress.TopK(0.0)
    with pytest.raises(ValueError):
        grad_compress.TopK(1.5)


# --------------------------------------------------------- pipeline parallel
def test_split_stages_shape_invariants():
    params = {
        "w": jnp.arange(8 * 4 * 4.0).reshape(8, 4, 4),
        "b": jnp.arange(8.0),
    }
    staged = pp.split_stages(params, 4)
    assert staged["w"].shape == (4, 2, 4, 4)
    assert staged["b"].shape == (4, 2)
    # concatenating the stages back recovers the original layer order
    np.testing.assert_array_equal(
        np.asarray(staged["w"].reshape(8, 4, 4)), np.asarray(params["w"])
    )
    with pytest.raises(ValueError):
        pp.split_stages(params, 3)
