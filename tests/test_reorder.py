"""Reordering tests: TSP init (Eq. 6) and Alg. 3 swap refinement."""
import jax
import numpy as np

from repro.core import codec, nttd, reorder
from repro.core.folding import make_folding_spec
from repro.optim import optimizers


def _smooth_permuted(shape=(24, 18, 12), seed=0):
    rng = np.random.default_rng(seed)
    grids = np.meshgrid(*[np.linspace(0, 2, n) for n in shape], indexing="ij")
    x = np.sin(grids[0] * 3) + grids[1] ** 2 - np.cos(grids[2])
    x = (x + 0.05 * rng.normal(size=shape)).astype(np.float32)
    perms = [rng.permutation(n) for n in shape]
    xp = x[perms[0]][:, perms[1]][:, :, perms[2]]
    return xp


def test_tsp_init_lowers_eq6_objective():
    x = _smooth_permuted()
    for k in range(x.ndim):
        ident = np.arange(x.shape[k])
        perm = reorder.tsp_order_mode(x, k)
        assert sorted(perm) == sorted(ident)  # valid permutation
        obj_ident = reorder.order_objective(x, k, ident)
        obj_tsp = reorder.order_objective(x, k, perm)
        assert obj_tsp < obj_ident, (k, obj_tsp, obj_ident)


def test_tsp_recovers_smooth_neighborhoods():
    """On a tensor whose rows are a shuffled smooth curve, the TSP order
    must place original neighbors near each other."""
    rng = np.random.default_rng(1)
    n = 32
    base = np.stack([np.sin(np.linspace(0, 3, n) + p) for p in np.linspace(0, 1, 64)], 1)
    perm = rng.permutation(n)
    x = base[perm].astype(np.float32)
    order = reorder.tsp_order_mode(x[:, :, None], 0)
    recovered = perm[order]  # positions in the original smooth sequence
    jumps = np.abs(np.diff(recovered))
    assert np.median(jumps) <= 2


def test_alg3_exact_never_increases_loss():
    x = _smooth_permuted((16, 12, 10))
    spec = make_folding_spec(x.shape)
    cfg = nttd.NTTDConfig(rank=4, hidden=8)
    params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg)
    rng = np.random.default_rng(0)
    pi = reorder.identity_orders(x.shape)

    # fit a little so the model has signal
    opt = optimizers.adam(5e-3)
    ost = opt.init(params)
    epoch = codec._make_train_epoch(spec, cfg, opt)
    dims = np.array(x.shape)
    n = x.size
    radix = np.concatenate([np.cumprod(dims[::-1])[::-1][1:], [1]])
    import jax.numpy as jnp

    for _ in range(10):
        flat = rng.permutation(n)
        pos = (flat[:, None] // radix) % dims
        vals = x[tuple(pi[j][pos[:, j]] for j in range(3))]
        params, ost, _ = epoch(
            params, ost,
            jnp.asarray(pos.reshape(4, -1, 3), jnp.int32),
            jnp.asarray(vals.reshape(4, -1)),
        )

    def true_loss(pi_):
        flat = np.arange(n)
        pos = (flat[:, None] // radix) % dims
        vals = x[tuple(pi_[j][pos[:, j]] for j in range(3))]
        preds = np.asarray(
            nttd.apply_at_positions(params, jnp.asarray(pos, jnp.int32), spec, cfg)
        )
        return float(((preds - vals) ** 2).sum())

    before = true_loss(pi)
    pi2, stats = reorder.update_orders(
        x, params, pi, spec, cfg, rng, samples_per_slice=10**9  # exact
    )
    after = true_loss(pi2)
    assert after <= before + 1e-5
    # bookkeeping consistent: accepted deltas sum to the loss change
    total_delta = sum(s.delta_sum for s in stats)
    np.testing.assert_allclose(after - before, total_delta, rtol=1e-3, atol=1e-2)


def test_pairs_are_disjoint():
    rng = np.random.default_rng(2)
    proj = {i: float(rng.normal()) for i in rng.choice(64, size=32, replace=False)}
    pairs = reorder._build_pairs(proj, 64, rng)
    seen = set()
    for a, b in pairs:
        assert a != b
        assert a not in seen and b not in seen
        seen.update((a, b))
