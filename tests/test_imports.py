"""Every module under src/repro must import cleanly.

Guards against missing-submodule seed bugs (the repro.dist hole) landing
silently: a module that only a launcher or benchmark imports would
otherwise break nothing until someone runs it.  The walk happens in a
subprocess because launch.dryrun / launch.dryrun_codec set XLA device
flags at import time and the main test process must keep the real
single-device CPU view (see conftest.py).
"""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

WALK_AND_IMPORT = """
import importlib
import os
import sys

root = sys.argv[1]
mods = []
for dirpath, dirnames, filenames in os.walk(os.path.join(root, "repro")):
    dirnames[:] = [d for d in dirnames if d != "__pycache__"]
    for f in sorted(filenames):
        if not f.endswith(".py"):
            continue
        rel = os.path.relpath(os.path.join(dirpath, f), root)
        mod = rel[:-3].replace(os.sep, ".")
        if mod.endswith(".__init__"):
            mod = mod[: -len(".__init__")]
        mods.append(mod)

failures = []
for mod in sorted(mods):
    try:
        importlib.import_module(mod)
    except Exception as e:  # noqa: BLE001 — report every broken module
        failures.append(f"{mod}: {type(e).__name__}: {e}")

assert not failures, "unimportable modules:\\n" + "\\n".join(failures)
# the subsystem this repo once shipped without
for expected in ("repro.dist.sharding", "repro.dist.grad_compress",
                 "repro.dist.pipeline_parallel"):
    assert expected in mods, f"missing module: {expected}"
print(f"imported {len(mods)} modules")
"""


def test_all_repro_modules_import():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", WALK_AND_IMPORT, SRC],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "imported" in out.stdout
