"""Serializer round-trip and bit-packing tests."""
import numpy as np

from repro.core import codec, serialization


def test_pack_unpack_permutation_exact():
    rng = np.random.default_rng(0)
    for n in [1, 2, 3, 7, 8, 9, 63, 64, 65, 1000]:
        perm = rng.permutation(n)
        blob = serialization.pack_permutation(perm)
        back = serialization.unpack_permutation(blob, n)
        np.testing.assert_array_equal(perm, back)
        if n > 1:
            bits = max(int(np.ceil(np.log2(n))), 1)
            assert len(blob) == (n * bits + 7) // 8  # paper's size convention


def _tiny_ct():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(14, 11, 9)).astype(np.float32)
    ct, _ = codec.compress(
        x, codec.CodecConfig(rank=4, hidden=8, epochs=3, batch_size=512)
    )
    return x, ct


def test_file_roundtrip_bit_exact_fp32():
    x, ct = _tiny_ct()
    blob = serialization.save_bytes(ct, np.float32)
    ct2 = serialization.load_bytes(blob)
    for a, b in zip(ct.pi, ct2.pi):
        np.testing.assert_array_equal(a, b)
    idx = np.stack([np.arange(5) % n for n in x.shape], axis=1)
    np.testing.assert_allclose(ct.decode(idx), ct2.decode(idx), rtol=1e-6, atol=1e-6)
    assert ct2.norm_mean == ct.norm_mean and ct2.norm_std == ct.norm_std


def test_fp16_roundtrip_close():
    x, ct = _tiny_ct()
    blob16 = serialization.save_bytes(ct, np.float16)
    blob32 = serialization.save_bytes(ct, np.float32)
    assert len(blob16) < len(blob32)
    ct2 = serialization.load_bytes(blob16)
    idx = np.stack([np.arange(7) % n for n in x.shape], axis=1)
    np.testing.assert_allclose(ct.decode(idx), ct2.decode(idx), rtol=0.05, atol=0.05)


def test_file_io(tmp_path):
    x, ct = _tiny_ct()
    path = str(tmp_path / "t.tcdc")
    n = serialization.save_file(path, ct)
    import os

    assert os.path.getsize(path) == n
    ct2 = serialization.load_file(path)
    np.testing.assert_allclose(ct.to_dense(), ct2.to_dense(), rtol=1e-6, atol=1e-6)
