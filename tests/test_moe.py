"""MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import moe


def _cfg(**kw):
    base = dict(
        arch_id="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=64, moe_experts=4, moe_top_k=2,
        param_dtype="float32", compute_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, key):
    from repro.dist import sharding

    return sharding.materialize(key, moe.moe_specs(cfg), jnp.float32)


def test_moe_matches_dense_sum_when_no_drops():
    """With capacity >= tokens, MoE output == explicit per-token expert mix."""
    cfg = _cfg(moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    p = _params(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe.moe_ffn(p, x, cfg)

    # dense reference: route every token through its top-k experts
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gv, gi = jax.lax.top_k(probs, cfg.moe_top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for b in range(2):
        for s in range(8):
            acc = jnp.zeros((cfg.d_model,))
            for k in range(cfg.moe_top_k):
                e = int(gi[b, s, k])
                h = jax.nn.silu(x[b, s] @ p["w_gate"][e]) * (x[b, s] @ p["w_up"][e])
                acc = acc + gv[b, s, k] * (h @ p["w_down"][e])
            want = want.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_capacity_drops_bounded():
    """With a tight capacity, output norm shrinks but stays finite, and no
    token receives weight from an expert it wasn't routed to."""
    cfg = _cfg(moe_capacity_factor=0.5)
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe.moe_ffn(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_group_capacity_decode_exact():
    assert moe.group_capacity(1, _cfg()) == 2  # == top_k, zero drops


def test_aux_loss_uniform_router_is_one():
    """Perfectly uniform routing gives aux ~= 1 (Switch normalization)."""
    cfg = _cfg(moe_experts=4, moe_top_k=1)
    p = _params(cfg, jax.random.PRNGKey(0))
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    _, aux = moe.moe_ffn(p, x, cfg)
    # me = 1/E exactly; ce depends on top-1 tie-breaking; aux = E*sum(me*ce) = 1
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)
