"""Controller decision logic over recorded metric fixtures (pure
ScalingPolicy drills: sustained-breach scale-up, hysteresis hold, idle
scale-down, flap guard), the metrics roll-up fields the policy consumes
(monotonic ``collected_at``, cumulative ``excluded_total``), and a
FleetController integration pass driving real ``rebalance`` calls."""
import numpy as np
import pytest

from repro import obs
from repro.codecs import get_codec
from repro.fleet import (
    ControllerConfig,
    FleetController,
    FleetFrontend,
    ScalingPolicy,
    TransportError,
    collect,
    rebalance,
)
from repro.stream import write_chunked

CFG = ControllerConfig(
    p99_target_ms=5.0, p99_clear_ms=4.0,
    breach_evals=3, clear_evals=2,
    idle_flushes_per_eval=1.0, idle_evals=3, cooldown_evals=2,
    min_instances=1, max_instances=4,
)


def _sample(p99, flushes, instances=2, **extra):
    return {"decode_p99_ms": p99, "flushes_total": flushes,
            "instances": instances, **extra}


def _drill(policy, rows):
    return [policy.observe(s, now=float(t)).action for t, s in enumerate(rows)]


# ---------------------------------------------------------------------------
# recorded-fixture policy drills
# ---------------------------------------------------------------------------
def test_scale_up_on_sustained_breach_exactly_at_threshold():
    # breach_evals=3: two violating evals hold, the third scales up
    rows = [_sample(9.0, 10 * (t + 1)) for t in range(5)]
    actions = _drill(ScalingPolicy(CFG), rows)
    assert actions == ["hold", "hold", "scale_up", "hold", "hold"]  # cooldown=2


def test_spike_resets_the_breach_streak():
    p = ScalingPolicy(CFG)
    rows = [
        _sample(9.0, 10), _sample(9.0, 20),
        _sample(3.0, 30),                      # clears -> streak resets
        _sample(9.0, 40), _sample(9.0, 50), _sample(9.0, 60),
    ]
    assert _drill(p, rows) == [
        "hold", "hold", "hold", "hold", "hold", "scale_up",
    ]


def test_hold_inside_hysteresis_band():
    # values in (clear=4, target=5] never accumulate a breach streak
    rows = [_sample(v, 10 * (t + 1))
            for t, v in enumerate([4.5, 4.9, 4.2, 4.8, 4.6, 4.9, 4.4, 4.7])]
    assert _drill(ScalingPolicy(CFG), rows) == ["hold"] * 8


def test_scale_down_on_idle_and_min_floor():
    p = ScalingPolicy(CFG)
    rows = [_sample(2.0, 100)] + [_sample(2.0, 100)] * 3  # flushes frozen
    # first eval sets the baseline; idle_evals=3 later we scale down
    assert _drill(p, rows) == ["hold", "hold", "hold", "scale_down"]
    # at the floor the same signal holds forever
    floor = [_sample(2.0, 100, instances=1)] * 8
    assert _drill(ScalingPolicy(CFG), floor)[1:] == ["hold"] * 7


def test_stale_latency_cannot_pin_a_breach_while_idle():
    p = ScalingPolicy(CFG)
    # live traffic opens a breach...
    _drill(p, [_sample(9.0, 10 * (t + 1)) for t in range(3)])
    # ...then traffic stops but the window percentile stays frozen at 9ms.
    # The policy blanks the stale latency: no further scale_up, and the
    # idle streak wins through to scale_down.
    rows = [_sample(9.0, 30)] * 6
    actions = _drill(p, rows)
    assert "scale_up" not in actions
    assert "scale_down" in actions


def test_flap_guard_no_oscillation_on_noisy_signal():
    # noisy alternation around the target with live traffic: breach
    # streaks never reach 3, idle streaks never reach 3, and any action
    # is followed by >= cooldown_evals holds
    rng = np.random.default_rng(0)
    p = ScalingPolicy(CFG)
    actions = []
    flushes = 0
    for t in range(60):
        flushes += int(rng.integers(1, 5))
        v = float(rng.choice([3.0, 4.5, 6.0]))
        actions.append(p.observe(_sample(v, flushes), now=float(t)).action)
    changes = [a for a in actions if a != "hold"]
    for i, a in enumerate(actions):
        if a != "hold":
            assert actions[i + 1: i + 1 + CFG.cooldown_evals] == (
                ["hold"] * min(CFG.cooldown_evals, len(actions) - i - 1)
            )
    # no add/remove ping-pong: never a scale_down right after a scale_up
    for prev, cur in zip(changes, changes[1:]):
        assert not (prev == "scale_up" and cur == "scale_down")


def test_max_instances_caps_scale_up():
    p = ScalingPolicy(CFG)
    rows = [_sample(9.0, 10 * (t + 1), instances=4) for t in range(6)]
    actions = _drill(p, rows)
    assert "scale_up" not in actions
    d = p.observe(_sample(9.0, 999, instances=4), now=9.0)
    assert d.action == "hold" and "max_instances" in d.reason


def test_quality_objective_surfaces_events_without_scaling():
    cfg = ControllerConfig(p99_target_ms=5.0, min_fitness=0.9,
                           breach_evals=1, clear_evals=1)
    p = ScalingPolicy(cfg)
    d = p.observe(_sample(1.0, 10, **{"canary_fitness.e": 0.5}), now=0.0)
    assert d.action == "hold"
    assert [(e.kind, e.slo, e.metric) for e in d.events] == [
        ("breach_start", "quality", "canary_fitness.e")
    ]


def test_config_validation():
    with pytest.raises(ValueError, match="p99_target_ms"):
        ControllerConfig(p99_target_ms=0.0)
    with pytest.raises(ValueError, match="min_instances"):
        ControllerConfig(p99_target_ms=1.0, min_instances=5, max_instances=2)
    assert ControllerConfig(p99_target_ms=10.0).clear_ms == pytest.approx(8.0)


# ---------------------------------------------------------------------------
# metrics roll-up fields the policy consumes
# ---------------------------------------------------------------------------
@pytest.fixture()
def payload(tmp_path):
    x = np.random.default_rng(0).random((16, 16, 8)).astype(np.float32)
    enc = get_codec("ttd").fit(x, max_rank=4)
    path = str(tmp_path / "p.tcdc")
    write_chunked(path, enc, chunk_bytes=1024)
    return path


def _query(n=50, seed=1):
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(0, s, n) for s in (16, 16, 8)], axis=1)


def test_collect_collected_at_monotonic_and_excluded_total(payload):
    fleet = FleetFrontend(3, cache_bytes=1 << 22)
    try:
        fleet.load_stream("e", payload, tile_entries=256)
        fleet.decode_at("e", _query())
        m1 = collect(fleet)
        assert m1.excluded_total == 0 and m1.collected_at > 0
        assert m1.decode_p99_ms is None or m1.decode_p99_ms >= 0
        # kill one member's stats path -> excluded on next collect
        victim = sorted(fleet.transports)[-1]

        def boom(*a, **kw):
            raise TransportError("stats down")

        fleet.transports[victim].stats = boom
        m2 = collect(fleet)
        assert victim in m2.excluded and m2.excluded_total == 1
        assert m2.collected_at > m1.collected_at
        # retiring the dead member clears `excluded` but the cumulative
        # counter keeps the history
        rebalance(fleet, remove=[victim], warm=False)
        m3 = collect(fleet)
        assert m3.excluded == [] and m3.excluded_total == 1
        assert m3.collected_at > m2.collected_at
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# FleetController integration: recorded samples -> real rebalance
# ---------------------------------------------------------------------------
def test_controller_steps_drive_real_rebalance(payload):
    fleet = FleetFrontend(2, cache_bytes=1 << 22)
    cfg = ControllerConfig(
        p99_target_ms=5.0, breach_evals=2, clear_evals=1,
        idle_evals=2, cooldown_evals=1, min_instances=2, max_instances=3,
    )
    ctl = FleetController(fleet, cfg)
    try:
        fleet.load_stream("e", payload, tile_entries=256)
        before = fleet.decode_at("e", _query())
        obs.clear_events()
        # sustained breach with live traffic -> admit standby s0
        ctl.step(_sample(9.0, 10, instances=2))
        d = ctl.step(_sample(9.0, 20, instances=2))
        assert d.action == "scale_up"
        assert "s0" in fleet.transports and len(fleet.transports) == 3
        assert ctl.admitted == ["s0"]
        # answers still bit-identical after the ring change
        assert np.array_equal(fleet.decode_at("e", _query()), before)
        ctl.step(_sample(3.0, 30, instances=3))     # cooldown tick
        ctl.step(_sample(3.0, 40, instances=3))     # baseline refresh
        ctl.step(_sample(3.0, 40, instances=3))     # idle 1
        d = ctl.step(_sample(3.0, 40, instances=3))  # idle 2 -> retire s0
        assert d.action == "scale_down"
        assert "s0" not in fleet.transports and ctl.admitted == []
        assert np.array_equal(fleet.decode_at("e", _query()), before)
        assert not fleet.failed
        acts = [e["action"] for e in obs.events("controller_decision")]
        assert acts.count("scale_up") == 1 and acts.count("scale_down") == 1
        assert [d2.action for d2 in ctl.decisions] == acts
    finally:
        fleet.close()


def test_controller_sample_comes_from_collect(payload):
    fleet = FleetFrontend(2, cache_bytes=1 << 22)
    try:
        fleet.load_stream("e", payload, tile_entries=256)
        fleet.decode_at("e", _query())
        ctl = FleetController(fleet, ControllerConfig(p99_target_ms=1e9))
        s = ctl.sample()
        assert s["instances"] == 2 and s["flushes_total"] >= 1
        assert ctl.step().action == "hold"
    finally:
        fleet.close()


def test_controller_victim_prefers_dead_then_lifo(payload):
    fleet = FleetFrontend(2, cache_bytes=1 << 22)
    cfg = ControllerConfig(p99_target_ms=5.0, min_instances=1, max_instances=4)
    ctl = FleetController(fleet, cfg)
    try:
        fleet.load_stream("e", payload, tile_entries=256)
        rebalance(fleet, add=["s0"])
        ctl.admitted.append("s0")
        assert ctl._victim() == "s0"          # LIFO: newest admitted first
        victim = sorted(fleet.transports)[0]
        fleet.exclude(victim, TransportError("dead"))
        assert ctl._victim() == victim        # dead member outranks LIFO
    finally:
        fleet.close()
