"""Quickstart: compress a tensor with TensorCodec, compare with TT-SVD.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import codec, serialization, ttd
from repro.data import synthetic_tensors as st


def main():
    # a synthetic "stock"-like tensor (smooth random walks, shuffled)
    x = st.load("stock", mini=True)
    print(f"input tensor {x.shape} = {x.size} entries ({x.size * 8 / 1e6:.1f} MB fp64)")

    ct, log = codec.compress(
        x,
        codec.CodecConfig(rank=6, hidden=12, epochs=60, batch_size=8192,
                          lr=1e-2, patience=8, verbose=False),
    )
    fit = ct.fitness(x)
    payload = ct.payload_bytes()
    print(f"TensorCodec: fitness={fit:.4f} payload={payload/1e3:.1f} KB "
          f"({x.size * 8 / payload:.0f}x compression) in {log.seconds_train:.0f}s")

    # TT-SVD at the same byte budget (paper's matched-size protocol)
    r = ttd.tt_rank_for_budget(x.shape, payload // 8)
    t = ttd.tt_svd(x, max_rank=max(r, 1))
    print(f"TT-SVD same budget: fitness={t.fitness(x):.4f} (rank {max(r,1)})")

    # real serialization round trip
    blob = serialization.save_bytes(ct, np.float32)
    ct2 = serialization.load_bytes(blob)
    idx = np.array([[0, 0, 0], [3, 5, 7]])
    print(f"serialized {len(blob)/1e3:.1f} KB; decode after round-trip: "
          f"{ct2.decode(idx).round(3)} vs original {x[0,0,0]:.3f}, {x[3,5,7]:.3f}")


if __name__ == "__main__":
    main()
