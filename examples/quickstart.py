"""Quickstart: compress a tensor with TensorCodec via the unified codec
API, compare against every other registered codec at the same budget, and
serve entry queries from the serialized payload.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.codecs import available, get_codec, load_bytes, save_bytes
from repro.data import synthetic_tensors as st
from repro.serve.codec_service import CodecService


def main():
    # a synthetic "stock"-like tensor (smooth random walks, shuffled)
    x = st.load("stock", mini=True)
    print(f"input tensor {x.shape} = {x.size} entries ({x.size * 8 / 1e6:.1f} MB fp64)")

    enc = get_codec("nttd").fit(
        x, rank=6, hidden=12, epochs=60, batch_size=8192, lr=1e-2, patience=8,
    )
    fit = enc.fitness(x)
    payload = enc.payload_bytes()
    print(f"TensorCodec: fitness={fit:.4f} payload={payload/1e3:.1f} KB "
          f"({x.size * 8 / payload:.0f}x compression) in "
          f"{enc.log.seconds_train:.0f}s")

    # every other registered codec at the same byte budget (paper protocol)
    for name in available():
        if name == "nttd":
            continue
        try:
            rival = get_codec(name).fit(x, payload)
        except ValueError as e:  # codec cannot meet this budget
            print(f"{name} same budget: skipped ({e})")
            continue
        print(f"{name} same budget: fitness={rival.fitness(x):.4f} "
              f"payload={rival.payload_bytes()/1e3:.1f} KB")

    # container round trip + served entry queries
    blob = save_bytes(enc)
    enc2 = load_bytes(blob)
    idx = np.array([[0, 0, 0], [3, 5, 7]])
    print(f"serialized {len(blob)/1e3:.1f} KB; decode after round-trip: "
          f"{enc2.decode_at(idx).round(3)} vs original {x[0,0,0]:.3f}, {x[3,5,7]:.3f}")

    svc = CodecService()
    svc.load("stock", blob)
    t0 = svc.submit("stock", idx)
    t1 = svc.submit("stock", idx[::-1])
    out = svc.flush()
    print(f"codec service ({svc.info('stock').codec}): coalesced 2 requests -> "
          f"{out[t0].round(3)}, {out[t1].round(3)}")

    # --- fleet: 3 instances serving one chunked payload as one service ---
    import tempfile

    from repro.fleet import FleetFrontend, collect, rebalance
    from repro.stream import write_chunked

    path = os.path.join(tempfile.mkdtemp(), "stock.tcdc")
    write_chunked(path, enc, chunk_bytes=2048)  # chunk index + entry ranges
    fleet = FleetFrontend(3, cache_bytes=1 << 24)
    fleet.load_stream("stock", path, tile_entries=1024)
    rng = np.random.default_rng(0)
    big = np.stack([rng.integers(0, s, 4096) for s in x.shape], axis=1)
    served = fleet.decode_at("stock", big)       # split by owner, reassembled
    assert np.array_equal(served, svc.decode_at("stock", big))
    m = collect(fleet)
    shards = {i: s.cache.resident_bytes for i, s in m.instances.items()}
    print(f"fleet (3 instances): bit-identical to one instance; "
          f"resident bytes per instance {shards}")

    pending = fleet.submit("stock", big)         # in flight during rebalance
    report = rebalance(fleet, remove=["i2"])     # drain -> move chunks -> evict
    out = fleet.flush()
    assert not fleet.failed and np.array_equal(out[pending], served)
    print(f"rebalance 3->2: {report.total_moved} chunks/tiles moved, "
          f"{sum(report.tiles_warmed.values())} tiles handed off warm, "
          f"0 failed tickets")


if __name__ == "__main__":
    main()
