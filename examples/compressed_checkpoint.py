"""TensorCodec as checkpoint codec: train a small LM a few steps, then ship
its checkpoint through the NTTD compressor and measure size/quality.

    PYTHONPATH=src python examples/compressed_checkpoint.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import configs
from repro.compress import checkpoint_codec as cc
from repro.data.pipeline import PipelineConfig, SyntheticSource
from repro.models import model
from repro.optim import optimizers
from repro.train import step as step_lib


def main():
    cfg = configs.get_smoke("musicgen-medium")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    opt = optimizers.adamw(3e-3)
    ost = opt.init(params)
    step = jax.jit(step_lib.make_train_step(cfg, opt))
    src = SyntheticSource(PipelineConfig(batch_size=8, seq_len=64, vocab=cfg.vocab))
    for i in range(20):
        b = src.batch_at(i)
        labels = b["labels"]
        batch = {
            "embeds": jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(1), i), (8, 64, cfg.d_model)
            ) * 0.1,
            "labels": jnp.asarray(labels),
        }
        params, ost, m = step(params, ost, batch)
    print(f"trained 20 steps, loss {float(m['loss']):.3f}")

    payload, stats = cc.compress_tree(
        params,
        cc.CodecCheckpointConfig(min_elements=4096, min_fitness=0.6,
                                 rank=8, hidden=16, epochs=25),
    )
    print(f"checkpoint: {stats['raw_bytes']/1e6:.1f} MB raw -> "
          f"{stats['compressed_bytes']/1e6:.2f} MB "
          f"({stats['ratio']:.1f}x), {stats['leaves_codec']} leaves NTTD-coded, "
          f"{stats['leaves_raw']} raw")

    restored = cc.decompress_tree(payload, params)
    b = src.batch_at(99)
    batch = {
        "embeds": jax.random.normal(jax.random.PRNGKey(7), (8, 64, cfg.d_model)) * 0.1,
        "labels": jnp.asarray(b["labels"]),
    }
    loss_orig, _ = model.loss_fn(params, cfg, batch)
    loss_rest, _ = model.loss_fn(
        jax.tree.map(jnp.asarray, restored), cfg, batch
    )
    print(f"eval loss: original {float(loss_orig):.4f} vs decompressed "
          f"{float(loss_rest):.4f} (lossy-codec delta "
          f"{float(loss_rest - loss_orig):+.4f})")


if __name__ == "__main__":
    main()
