"""End-to-end driver: serve a small LM with batched requests through the
continuous-batching engine (the paper-assigned serving path).

    PYTHONPATH=src python examples/serve_llm.py
"""
import sys

sys.path.insert(0, "src")

import time

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = configs.get_smoke("qwen1.5-4b")
    print(f"serving {cfg.arch_id}: {cfg.n_layers}L d{cfg.d_model} vocab {cfg.vocab}")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, batch_slots=4, max_len=96)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(12):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, size=rng.integers(8, 24)),
            max_new_tokens=12,
        ))
    results = engine.run()
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    for r in sorted(results, key=lambda r: r.uid)[:3]:
        print(f"  req {r.uid}: generated {r.tokens}")
    print(f"{len(results)} requests, {total} tokens, {dt:.1f}s "
          f"({total / dt:.1f} tok/s on 1 CPU core)")


if __name__ == "__main__":
    main()
