"""End-to-end training driver: a ~10M-param dense LM for a few hundred
steps on synthetic data with the full production loop (WSD schedule,
clipping, async checkpointing, auto-resume).

(The assignment's ~100M-for-hundreds-of-steps variant needs more than one
CPU core; on TPU this same driver scales by pointing --mesh at the pod.)

    PYTHONPATH=src python examples/train_lm.py
"""
import sys

sys.path.insert(0, "src")

import tempfile

from repro.launch import train as train_launch


def main():
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    losses = train_launch.main([
        "--arch", "minicpm-2b", "--smoke",
        "--steps", "200", "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--schedule", "wsd",
        "--ckpt-dir", ckpt_dir, "--ckpt-every", "100",
        "--log-every", "25",
    ])
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
