#!/usr/bin/env python3
"""Gate the BENCH_*.json trajectory against a committed baseline.

The smokes (``fig5_compress_scaling --stream --smoke``, ``fleet_bench
--smoke [--procs N]``) write per-PR performance records; this script
fails CI when a headline metric regresses more than ``--tolerance``
(default 30%) against ``benchmarks/results/baseline.json``:

- ``stream.entries_per_sec``  (higher is better; BENCH_stream.json)
- ``fleet.entries_per_sec``   (higher is better; BENCH_fleet.json)
- ``fleet.p99_ms``            (lower is better;  BENCH_fleet.json)
- ``fleet.fused_cold_prefetch_eps`` (higher is better; the fused-decode
                              cold-pass cell with prefetch on)
- ``fleet_procs.entries_per_sec`` / ``fleet_procs.p99_ms``
                              (BENCH_fleet_procs.json, the multi-process cell)
- ``kernels.decode_tile_entries_per_sec`` / ``kernels.decode_tile_fused_speedup``
                              (BENCH_kernels.json, the fused decode roofline)
- ``fig10.bytes_ratio`` / ``fig10.chain_fitness``
                              (BENCH_fig10.json, the deterministic TT cell of
                              the versioned-payload benchmark: independent
                              bytes-per-version over delta-chain bytes, and
                              the chain's reconstruction fitness)
- ``obs.traced_overhead_pct`` (BENCH_obs.json, the tracing-overhead cell —
                              gated against an ABSOLUTE 10%% ceiling, not the
                              baseline: the honest value hovers near zero, so
                              a relative tolerance would gate noise)
- ``obs.canary_overhead_pct`` (BENCH_obs.json, the online-fitness-canary
                              cell — same absolute 10%% ceiling, same
                              rationale)
- ``repair.time_to_repair_s`` / ``repair.refit_entries_per_sec``
                              (BENCH_repair.json, the read-repair drill:
                              worst repair wall-time across the drill's
                              corruption + quality phases, and the online
                              re-compression throughput of the quality
                              refit — both gated against ABSOLUTE bounds:
                              the refit is a seconds-scale SGD loop whose
                              wall-clock swings ~2x with machine load, so
                              a relative tolerance would gate noise; the
                              bounds catch order-of-magnitude regressions
                              like an undertrained config that loops)

When a metric fails the gate, the offending cell's baseline vs measured
value is also appended to the GitHub job summary
(``$GITHUB_STEP_SUMMARY``) so a red run names the regression without
opening the log.

Metrics whose BENCH file is absent are skipped unless named in
``--require`` (CI's tier1 job requires stream+fleet+kernels, the
multi-process smoke job requires fleet_procs — each job gates only what
it produced); a metric whose rows are missing from an older BENCH file
is skipped too.  ``--update`` reseeds the baseline from the current
BENCH files.

    python scripts/check_bench.py --require stream --require fleet
    python scripts/check_bench.py --update
"""
from __future__ import annotations

import argparse
import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "benchmarks", "results")
BASELINE = os.path.join(RESULTS, "baseline.json")

def _warm(runs):
    """The untagged default-pass rows (fused/cold cells carry a "pass")."""
    return [r for r in runs if r.get("pass") is None]


def _fused_cold_prefetch(runs):
    return [
        r for r in runs
        if r.get("pass") == "cold" and r.get("prefetch") is True
    ]


#: group -> (bench file, {metric: (extractor over runs, higher_is_better)
#: or (extractor, higher_is_better, absolute_bound)}).  A 3-tuple gates
#: against the fixed bound instead of the baseline (ceiling when lower is
#: better, floor when higher is).  An extractor raising ValueError/KeyError
#: means "rows absent in this BENCH file" (older format) — the metric is
#: skipped, not failed
GROUPS = {
    "stream": (
        "BENCH_stream.json",
        {"entries_per_sec": (lambda runs: max(r["entries_per_sec"] for r in runs), True)},
    ),
    "fleet": (
        "BENCH_fleet.json",
        {
            "entries_per_sec": (
                lambda runs: max(r["entries_per_sec"] for r in _warm(runs)), True
            ),
            "p99_ms": (
                lambda runs: min(
                    r["p99_ms"] for r in _warm(runs) if r["p99_ms"] is not None
                ),
                False,
            ),
            "fused_cold_prefetch_eps": (
                lambda runs: max(
                    r["entries_per_sec"] for r in _fused_cold_prefetch(runs)
                ),
                True,
            ),
        },
    ),
    "fleet_procs": (
        "BENCH_fleet_procs.json",
        {
            "entries_per_sec": (
                lambda runs: max(r["entries_per_sec"] for r in _warm(runs)), True
            ),
            "p99_ms": (
                lambda runs: min(
                    r["p99_ms"] for r in _warm(runs) if r["p99_ms"] is not None
                ),
                False,
            ),
        },
    ),
    "fig10": (
        "BENCH_fig10.json",
        {
            "bytes_ratio": (
                lambda runs: max(
                    r["bytes_ratio"] for r in runs if r["codec"] == "ttd"
                ),
                True,
            ),
            "chain_fitness": (
                lambda runs: max(
                    r["chain_fitness_mean"] for r in runs if r["codec"] == "ttd"
                ),
                True,
            ),
        },
    ),
    "obs": (
        "BENCH_obs.json",
        {
            "traced_overhead_pct": (
                lambda runs: max(r["traced_overhead_pct"] for r in runs),
                False,
                10.0,
            ),
            "canary_overhead_pct": (
                lambda runs: max(r["canary_overhead_pct"] for r in runs),
                False,
                10.0,
            ),
        },
    ),
    "repair": (
        "BENCH_repair.json",
        {
            "time_to_repair_s": (
                lambda runs: max(r["time_to_repair_s"] for r in runs),
                False,
                30.0,
            ),
            "refit_entries_per_sec": (
                lambda runs: max(
                    r["refit_entries_per_sec"] for r in runs
                    if r.get("refit_entries_per_sec") is not None
                ),
                True,
                150.0,
            ),
        },
    ),
    "kernels": (
        "BENCH_kernels.json",
        {
            "decode_tile_entries_per_sec": (
                lambda runs: max(r["fused_entries_per_sec"] for r in runs), True
            ),
            "decode_tile_fused_speedup": (
                lambda runs: max(r["fused_speedup"] for r in runs), True
            ),
        },
    ),
}


def current_metrics() -> dict[str, dict[str, float]]:
    out: dict[str, dict[str, float]] = {}
    for group, (fname, metrics) in GROUPS.items():
        path = os.path.join(RESULTS, fname)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            runs = json.load(f)["runs"]
        vals: dict[str, float] = {}
        for name, spec in metrics.items():
            try:
                vals[name] = round(float(spec[0](runs)), 4)
            except (ValueError, KeyError):  # rows absent (older BENCH file)
                continue
        out[group] = vals
    return out


def _write_step_summary(failures: list[dict], tolerance: float) -> None:
    """Append the offending cells (baseline vs measured) to the GitHub
    job summary so a red gate is readable without opening the log."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    lines = [
        "### Bench gate failed",
        "",
        f"{len(failures)} metric(s) out of bounds "
        f"(tolerance {tolerance:.0%}, or an absolute budget):",
        "",
        "| cell | baseline | measured | bound |",
        "| --- | --- | --- | --- |",
    ]
    for f in failures:
        lines.append(
            f"| `{f['cell']}` | {f['baseline']} | {f['measured']} "
            f"| `{f['bound']}` |"
        )
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--baseline", default=BASELINE)
    parser.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_TOLERANCE", "0.30")),
        help="allowed fractional regression (default 0.30 = 30%%)",
    )
    parser.add_argument(
        "--require", action="append", default=[], choices=sorted(GROUPS),
        help="fail if this group's BENCH file is missing (repeatable)",
    )
    parser.add_argument(
        "--update", action="store_true",
        help="reseed the baseline from the current BENCH files",
    )
    args = parser.parse_args(argv)

    current = current_metrics()
    missing = [g for g in args.require if g not in current]
    if missing:
        print(f"check_bench: required BENCH files missing for: {', '.join(missing)}")
        return 1

    if args.update:
        baseline = {}
        if os.path.exists(args.baseline):
            with open(args.baseline) as f:
                baseline = json.load(f)
        baseline.update(current)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"check_bench: baseline updated -> {os.path.relpath(args.baseline)}")
        for group, metrics in sorted(current.items()):
            for name, value in sorted(metrics.items()):
                print(f"  {group}.{name} = {value}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"check_bench: no baseline at {args.baseline} (seed with --update)")
        return 1
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures: list[dict] = []
    checked = 0
    for group, metrics in sorted(current.items()):
        base_group = baseline.get(group, {})
        for name, value in sorted(metrics.items()):
            spec = GROUPS[group][1][name]
            higher_better = spec[1]
            if len(spec) > 2:  # fixed absolute bound, baseline-independent
                limit = spec[2]
                ok = value >= limit if higher_better else value <= limit
                bound = f"{'>=' if higher_better else '<='} {limit:.1f} absolute"
                checked += 1
                status = "ok" if ok else "OVER BUDGET"
                print(f"  {group}.{name:<16} = {value:>12.1f}  ({bound}) {status}")
                if not ok:
                    failures.append({
                        "cell": f"{group}.{name}", "measured": value,
                        "baseline": f"{limit} (absolute)", "bound": bound,
                    })
                continue
            base = base_group.get(name)
            if base is None:
                print(f"  {group}.{name:<16} = {value:>12.1f}  (no baseline, skipped)")
                continue
            if higher_better:
                floor = base * (1 - args.tolerance)
                ok = value >= floor
                bound = f">= {floor:.1f}"
            else:
                ceil = base * (1 + args.tolerance)
                ok = value <= ceil
                bound = f"<= {ceil:.3f}"
            checked += 1
            status = "ok" if ok else "REGRESSION"
            print(
                f"  {group}.{name:<16} = {value:>12.1f}  "
                f"(baseline {base:.1f}, {bound}) {status}"
            )
            if not ok:
                failures.append({
                    "cell": f"{group}.{name}", "measured": value,
                    "baseline": base, "bound": bound,
                })
    if not checked:
        print("check_bench: nothing to check (no BENCH files found)")
        return 1
    if failures:
        names = [f["cell"] for f in failures]
        print(
            f"check_bench: {len(failures)} metric(s) out of bounds "
            f"(regressed > {args.tolerance:.0%} or over an absolute budget): "
            f"{', '.join(names)}"
        )
        _write_step_summary(failures, args.tolerance)
        return 1
    print(f"check_bench: {checked} metric(s) within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
