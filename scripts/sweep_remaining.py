"""Finish the dry-run sweep for the remaining architectures."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch import dryrun  # noqa: E402

cells = []
for arch in ["mamba2-1.3b", "musicgen-medium", "internvl2-76b", "jamba-1.5-large-398b"]:
    for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
        for mesh in ["single", "multi"]:
            cells.append((arch, shape, mesh))

for arch, shape, mesh in cells:
    path = dryrun.cell_path(arch, shape, mesh, "auto")
    if os.path.exists(path):
        print(f"skip done {arch} {shape} {mesh}", flush=True)
        continue
    try:
        res = dryrun.run_cell(arch, shape, mesh, "auto", remat="full")
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        res = {"arch": arch, "shape": shape, "mesh": mesh, "rules": "auto",
               "status": "error", "error": f"{type(e).__name__}: {e}"}
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print("WROTE", path, flush=True)
print("SWEEP2 DONE")
