"""Perf-B hillclimb: llama4-maverick train_4k single (worst roofline
fraction AND most collective-bound).  Each iteration recompiles the cell
with one change and reports the three terms + per-dtype collective
attribution."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402
from repro.dist import sharding  # noqa: E402
from repro.launch import dryrun, mesh as mesh_lib  # noqa: E402
from repro.models import model  # noqa: E402
from repro.optim import optimizers  # noqa: E402
from repro.train import step as step_lib  # noqa: E402

ARCH = "llama4-maverick-400b-a17b"
SHAPE = "train_4k"


def measure(tag: str, cfg_override=None, rules_override=None, depths=(2, 4)):
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    shape = SHAPES[SHAPE]
    base_cfg = configs.get(ARCH)
    cfg = cfg_override(base_cfg) if cfg_override else dataclasses.replace(
        base_cfg, remat="full"
    )
    rules = step_lib.effective_rules(mesh, shape, sharding.FSDP_RULES, cfg)
    if rules_override:
        rules = rules_override(rules)

    def lower(depth):
        c = dataclasses.replace(cfg, n_layers=cfg.block_size * depth,
                                scan_layers=False) if depth else cfg
        ab_params = model.abstract_params(c)
        ps = sharding.tree_shardings(mesh, model.param_specs(c), rules)
        batch_spec = step_lib.input_specs(c, shape)
        bs = step_lib.batch_shardings(mesh, c, batch_spec, rules)
        opt = optimizers.adamw(1e-4, weight_decay=0.1, max_grad_norm=1.0)
        fn = step_lib.make_train_step(c, opt)
        ab_opt = jax.eval_shape(opt.init, ab_params)
        os_ = step_lib.opt_shardings(mesh, c, rules)
        with sharding.sharding_ctx(mesh, rules):
            return jax.jit(fn, in_shardings=(ps, os_, bs),
                           donate_argnums=(0, 1)).lower(ab_params, ab_opt, batch_spec)

    t0 = time.time()
    # memory from the scanned full program
    mem = lower(None).compile().memory_analysis()

    def costs(depth):
        comp = lower(depth).compile()
        cost = dryrun.cost_dict(comp)
        coll = dryrun.collective_bytes_per_device(comp.as_text(), by_dtype=True)
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    d1, d2 = depths
    f1, b1, c1 = costs(d1)
    f2, b2, c2 = costs(d2)
    nb = cfg.n_blocks
    ex = lambda v1, v2: v1 + (nb - d1) * (v2 - v1) / (d2 - d1)  # noqa: E731
    flops = ex(f1, f2)
    bytes_ = ex(b1, b2)
    coll = {k: ex(c1.get(k, 0.0), c2.get(k, 0.0)) for k in set(c1) | set(c2)}
    terms = dict(
        compute_s=flops / mesh_lib.PEAK_FLOPS_BF16,
        memory_s=bytes_ / mesh_lib.HBM_BW,
        collective_s=coll["total"] / mesh_lib.ICI_BW,
    )
    mf = dryrun.model_flops(cfg, shape)
    ideal = max((mf / 256) / mesh_lib.PEAK_FLOPS_BF16,
                mem.argument_size_in_bytes / mesh_lib.HBM_BW)
    frac = ideal / max(terms.values())
    print(f"== {tag} ({time.time()-t0:.0f}s) ==")
    print("  terms: " + " ".join(f"{k}={v:.3f}" for k, v in terms.items())
          + f" fraction={frac:.4f}")
    print(f"  temp={mem.temp_size_in_bytes/1e9:.0f}GB args={mem.argument_size_in_bytes/1e9:.0f}GB")
    bd = {k: v for k, v in sorted(coll.items()) if ":" in k and v > 1e9}
    print("  coll by dtype: " + " ".join(f"{k}={v:.2e}" for k, v in bd.items()))
    return dict(tag=tag, terms=terms, fraction=frac, coll=coll,
                temp=mem.temp_size_in_bytes, flops=flops, bytes=bytes_)


if __name__ == "__main__":
    results = []
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "b1"):
        results.append(measure("B.1-baseline-fsdp-rematfull"))
    if which in ("all", "b2"):
        results.append(measure(
            "B.2-remat-dots",
            cfg_override=lambda c: dataclasses.replace(c, remat="dots"),
        ))
    if which in ("all", "b3"):
        # experts already on 'model' via fallback; keep expert_mlp unsharded
        # over data so expert weights gather only over 'data' on d_model
        results.append(measure(
            "B.3-capacity-1.0",
            cfg_override=lambda c: dataclasses.replace(
                c, remat="full", moe_capacity_factor=1.0),
        ))
    if which in ("all", "b4"):
        # expert parallelism: experts stationary (sharded data x model via
        # expert_mlp), tokens all-to-all through the dispatch constraint
        def ep_rules(rules):
            rules = dict(rules)
            # only the EP-specific keys; keep cell adjustments (CP/SP) intact
            rules["experts"] = ("data",)
            rules["expert_in"] = None
            rules["moe_group"] = None
            return rules

        results.append(measure("B.4-expert-parallel", rules_override=ep_rules))
    with open("/tmp/hillclimb_b.json", "w") as f:
        json.dump(results, f, indent=2, default=float)
