#!/usr/bin/env python3
"""End-to-end read-repair drill over a REAL 3-worker socket fleet.

The CI acceptance cell for replica-aware read repair: three
``repro.fleet.worker`` OS processes serve a chunked payload (TCDQ
held-out block, canaries fully on, replication=2) through two injected
faults:

1. **corruption** — worker ``w0`` starts with ``--debug-corrupt-chunk``
   flipping chunk 1's CRC.  Drill traffic must keep answering
   bit-identically to a single resident ``CodecService`` with ZERO
   failed tickets (the frontend fails the sub-batch over to surviving
   replicas and quarantines the chunk); the :class:`RepairController`
   then restores the chunk byte-exactly from a donor replica and swaps
   the epoch, after which the quarantine is clear fleet-wide.
2. **quality** — a deterministic fitness regression is injected into
   chunk 2's entry range on every replica (the ``inject_fault`` wire
   verb — the same surface the ``--debug-fitness-noise`` flag feeds).
   The canary must breach, the controller must re-compress the range
   online (NTTD stream refit seeded from the served decode + held-out
   truth) and land it as a patch overlay, and the post-repair canary
   must CLEAR the SLO — while every entry outside the range stays
   bit-identical throughout.

Artifacts: ``benchmarks/results/BENCH_repair.json`` (the
``repair.time_to_repair_s`` / ``repair.refit_entries_per_sec`` bench
cells) and ``benchmarks/results/repair_trace.json`` (Chrome trace with
the ``repair.*`` spans, uploaded next to ``obs_trace.json``).

    PYTHONPATH=src python scripts/repair_drill.py
"""
import json
import os
import sys
import tempfile

import numpy as np

from repro import obs
from repro.codecs import container, get_codec
from repro.fleet import (
    FleetFrontend,
    RepairController,
    SocketTransport,
    collect,
)
from repro.obs.report import load_trace, report_dict
from repro.serve.codec_service import CodecService
from repro.stream import sample_heldout, write_chunked

SHAPE = (16, 12, 8)
CANARY_MIN_FITNESS = 0.95
NOISE_SIGMA = 0.4
RESULTS = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "benchmarks", "results"
)


def _payload(tmp: str) -> tuple[str, np.ndarray]:
    # genuinely low-TT-rank truth (separable harmonics): the base fit must
    # be near-exact so the only fitness regressions are the injected ones
    i, j, k = np.meshgrid(*[np.arange(s) for s in SHAPE], indexing="ij")
    x = (
        np.sin(0.3 * i) * np.cos(0.2 * j) * np.sin(0.15 * k)
        + 0.5 * np.cos(0.1 * i) * np.sin(0.25 * j) * np.cos(0.3 * k)
    ).astype(np.float32)
    enc = get_codec("ttd").fit(x, max_rank=4)
    path = f"{tmp}/repair_drill.tcdc"
    write_chunked(path, enc, chunk_bytes=1024,
                  heldout=sample_heldout(x, 128, seed=3))
    return path, x


def _batches(n=6, per=400):
    rng = np.random.default_rng(2)
    return [
        np.stack([rng.integers(0, s, per) for s in SHAPE], axis=1)
        for _ in range(n)
    ]


def _factory(iid: str):
    # w0 carries the CRC-flip fault from birth (the CLI flag path);
    # the quality fault is injected later over the wire
    return SocketTransport.spawn(
        iid,
        timeout=60.0,
        canary_fraction=1.0,
        canary_min_fitness=CANARY_MIN_FITNESS,
        debug_corrupt_chunk=["e:1"] if iid == "w0" else None,
    )


def main() -> int:
    with tempfile.TemporaryDirectory() as tmp:
        path, x = _payload(tmp)
        _, chunks, _ = container.container_index(path)
        assert len(chunks) >= 3, f"drill needs >= 3 chunks, got {len(chunks)}"
        batches = _batches()
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        reference = [single.decode_at("e", idx) for idx in batches]

        obs.enable_tracing()
        obs.clear_events()
        fleet = FleetFrontend(
            ["w0", "w1", "w2"], transport_factory=_factory, replication=2
        )
        ctl = RepairController(fleet)
        try:
            fleet.load_stream("e", path, tile_entries=256)

            def serve_round(check_mask=None):
                """One traffic wave; every answer checked against the
                resident reference (optionally on a sub-mask of entries)
                and zero tickets may fail."""
                for k, idx in enumerate(batches):
                    out = fleet.decode_at("e", idx)
                    keep = (
                        slice(None) if check_mask is None else check_mask(idx)
                    )
                    assert np.array_equal(out[keep], reference[k][keep]), (
                        f"answer {k} diverged from the resident reference"
                    )
                assert not fleet.failed, f"failed tickets: {fleet.failed}"

            # ---- phase 1: CRC-flipped chunk on w0 ------------------------
            serve_round()  # bit-identical THROUGH the corruption
            tickets = ctl.poll()
            corrupt = [t for t in tickets if t.kind == "corruption"]
            assert corrupt, f"no corruption ticket from poll: {tickets}"
            assert corrupt[0].chunk == 1 and corrupt[0].payload == "e"
            # (chunk_quarantined fires inside the worker process; its
            # frontend-visible form is the quarantine entry poll() read)
            assert obs.events("decode_failover"), "no failover event"
            reports = ctl.run()
            assert all(r.ok for r in reports), [r.error for r in reports]
            restore = next(r for r in reports if r.kind == "corruption")
            serve_round()  # bit-identical AFTER the repair
            assert not ctl.poll(), "tickets remain after corruption repair"
            assert not collect(fleet).as_dict().get("quarantine"), (
                "quarantine survived the repair"
            )

            # ---- phase 2: fitness regression in chunk 2's range ----------
            lo, hi = int(chunks[2].entry_start), int(chunks[2].entry_stop)
            for iid, t in fleet.transports.items():
                t.inject_fault("e", {
                    "kind": "fitness_noise", "entry_start": lo,
                    "entry_stop": hi, "sigma": NOISE_SIGMA, "seed": 5,
                })

            def untouched(idx):
                flat = np.ravel_multi_index(tuple(idx.T), SHAPE)
                return (flat < lo) | (flat >= hi)

            quality = []
            for _ in range(8):  # canary sampling is per-call deterministic
                serve_round(check_mask=untouched)
                quality = [t for t in ctl.poll() if t.kind == "quality"]
                if quality:
                    break
            assert quality, "canary never fired on the injected regression"
            # (quality_breach is emitted worker-side; last_breach in the
            # polled canary stats is its wire-visible form)
            assert (quality[0].entry_start, quality[0].entry_stop) == (lo, hi)
            reports = ctl.run()
            refit = next(r for r in reports if r.kind == "quality")
            assert refit.ok, refit.error
            assert refit.fitness_after > refit.fitness_before, (
                refit.fitness_before, refit.fitness_after,
            )
            serve_round(check_mask=untouched)  # untouched ranges still exact

            # post-repair canary must clear the SLO on every live member
            cleared = False
            for _ in range(8):
                serve_round(check_mask=untouched)
                states = [
                    t.stats()["canary"].get("e", {})
                    for iid, t in fleet.transports.items()
                    if iid not in fleet.excluded
                ]
                checked = [s for s in states if s.get("checks", 0) > 0]
                if checked and all(
                    s.get("breaches", 0) == 0
                    and s.get("last_fitness", 0.0) >= CANARY_MIN_FITNESS
                    for s in checked
                ):
                    cleared = True
                    break
            assert cleared, f"post-repair canary did not clear: {states}"
            final_metrics = collect(fleet).as_dict()
        finally:
            fleet.close()
            obs.disable_tracing()

        # ---- artifacts -------------------------------------------------
        os.makedirs(RESULTS, exist_ok=True)
        trace = os.path.join(RESULTS, "repair_trace.json")
        obs.export_chrome_trace(trace, metrics=final_metrics)
        doc = report_dict(load_trace(trace), top=5)
        stages = {r["stage"] for r in doc["stages"]}
        for want in ("repair.corruption", "repair.quality"):
            assert want in stages, f"missing {want} span in {sorted(stages)}"

        bench = os.path.join(RESULTS, "BENCH_repair.json")
        runs = [
            {
                "kind": "corruption",
                "time_to_repair_s": round(restore.elapsed_s, 4),
                "chunks_restored": restore.chunks_restored,
                "donor": restore.donors.get(1),
            },
            {
                "kind": "quality",
                "time_to_repair_s": round(refit.elapsed_s, 4),
                "refit_entries_per_sec": round(refit.refit_entries_per_sec, 1),
                "fitness_before": round(refit.fitness_before, 6),
                "fitness_after": round(refit.fitness_after, 6),
            },
        ]
        with open(bench, "w") as f:
            json.dump({"bench": "repair_drill", "shape": SHAPE, "runs": runs}, f,
                      indent=2)
            f.write("\n")

        obs.get_recorder().clear()
        print(
            "repair drill OK: chunk restored from donor="
            f"{restore.donors.get(1)} in {restore.elapsed_s:.3f}s; "
            f"refit fitness {refit.fitness_before:.4f}->"
            f"{refit.fitness_after:.4f} in {refit.elapsed_s:.3f}s "
            f"({refit.refit_entries_per_sec:.0f} entries/s); "
            "failed_tickets=0 bit_identical=True slo_cleared=True"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
