#!/usr/bin/env bash
# Reproduce CI (tier-1) locally:
#
#     scripts/run_tests.sh            # full tier-1 suite
#     scripts/run_tests.sh -m 'not slow'   # skip the dry-run compile cells
#
# Phase 1 runs everything except the SPMD suite with the REAL single-device
# CPU view (tests/conftest.py requires it for smoke tests and benches).
# Phase 2 runs tests/test_spmd.py under a forced 8-device host platform —
# its subprocess tests force their own device count either way, but the
# explicit flag means a bare `pytest tests/test_spmd.py -k <case>` rerun of
# a failure behaves the same as CI.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Fast registry smoke: a broken codec adapter fails here, before pytest
# collection ever starts.
python - <<'PY'
from repro.codecs import available, get_codec

expected = {"cpd", "nttd", "szlite", "tensor_ring", "ttd", "tucker"}
names = set(available())
missing = expected - names
assert not missing, f"codec registry missing {sorted(missing)} (have {sorted(names)})"
for name in sorted(names):
    codec = get_codec(name)
    assert codec.encoded_cls.codec_name == name, name
print(f"codec registry OK: {', '.join(sorted(names))}")
PY

# Custom selections run as a single pass-through invocation (the SPMD
# subprocess tests force their own device count regardless), so paths
# never run twice and keep the single-device main-process view.
if [ "$#" -gt 0 ]; then
    exec python -m pytest -x -q "$@"
fi

python -m pytest -x -q --ignore=tests/test_spmd.py

XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_spmd.py

# Streaming smoke: synthetic SlabSource -> fit_stream -> chunked container
# -> CodecService.load_stream -> decode_at round-trip, and a CI-sized
# entries/sec baseline written to benchmarks/results/BENCH_stream.json so
# the streaming-throughput trajectory is tracked from PR to PR.
python -m benchmarks.fig5_compress_scaling --stream --smoke
test -s benchmarks/results/BENCH_stream.json
echo "streaming smoke OK: $(tr -d '\n' < benchmarks/results/BENCH_stream.json | head -c 200)"

# Fleet smoke: a 3-instance fleet over the checked-in chunked payload —
# every batch verified bit-identical against a single resident
# CodecService, plus a live 3->2 rebalance mid-query-stream with zero
# failed tickets.  BENCH_fleet.json tracks throughput/p99/hit rates.
python -m benchmarks.fleet_bench --smoke
test -s benchmarks/results/BENCH_fleet.json
echo "fleet smoke OK: $(tr -d '\n' < benchmarks/results/BENCH_fleet.json | head -c 200)"
