#!/usr/bin/env bash
# Reproduce CI (tier-1) locally.  CI runs these same phases as separate
# named workflow steps so a failure is attributable to one phase:
#
#     scripts/run_tests.sh                  # every phase, in CI order
#     scripts/run_tests.sh registry         # codec registry smoke only
#     scripts/run_tests.sh pytest           # main suite (everything but SPMD)
#     scripts/run_tests.sh spmd             # SPMD suite (8 host devices)
#     scripts/run_tests.sh stream-smoke     # streaming fit -> BENCH_stream.json
#     scripts/run_tests.sh fleet-smoke      # 3-instance in-process fleet
#     scripts/run_tests.sh fleet-procs-smoke  # 3 OS-process workers (sockets)
#     scripts/run_tests.sh kernels          # kernel tests + fused-decode roofline
#     scripts/run_tests.sh temporal         # versioned payloads + fig10 smoke
#     scripts/run_tests.sh obs              # tracing/metrics suite + traced fleet smoke
#     scripts/run_tests.sh slo              # SLO/canary/controller suites + autoscale drill
#     scripts/run_tests.sh repair           # read-repair suite + fault-injection drill
#     scripts/run_tests.sh bench-gate       # BENCH_*.json vs committed baseline
#     scripts/run_tests.sh -m 'not slow'    # pytest passthrough (custom select)
#
# Phase `pytest` runs everything except the SPMD suite with the REAL
# single-device CPU view (tests/conftest.py requires it for smoke tests and
# benches).  Phase `spmd` runs tests/test_spmd.py under a forced 8-device
# host platform — its subprocess tests force their own device count either
# way, but the explicit flag means a bare `pytest tests/test_spmd.py -k
# <case>` rerun of a failure behaves the same as CI.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

phase_registry() {
    # Fast registry smoke: a broken codec adapter fails here, before pytest
    # collection ever starts.
    python - <<'PY'
from repro.codecs import available, get_codec

expected = {"cpd", "nttd", "szlite", "tensor_ring", "ttd", "tucker"}
names = set(available())
missing = expected - names
assert not missing, f"codec registry missing {sorted(missing)} (have {sorted(names)})"
for name in sorted(names):
    codec = get_codec(name)
    assert codec.encoded_cls.codec_name == name, name
print(f"codec registry OK: {', '.join(sorted(names))}")
PY
}

phase_pytest() {
    python -m pytest -x -q --ignore=tests/test_spmd.py
}

phase_spmd() {
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        python -m pytest -x -q tests/test_spmd.py
}

phase_stream_smoke() {
    # Streaming smoke: synthetic SlabSource -> fit_stream -> chunked container
    # -> CodecService.load_stream -> decode_at round-trip, and a CI-sized
    # entries/sec baseline written to benchmarks/results/BENCH_stream.json so
    # the streaming-throughput trajectory is tracked from PR to PR.
    python -m benchmarks.fig5_compress_scaling --stream --smoke
    test -s benchmarks/results/BENCH_stream.json
    echo "streaming smoke OK: $(tr -d '\n' < benchmarks/results/BENCH_stream.json | head -c 200)"
}

phase_fleet_smoke() {
    # Fleet smoke: a 3-instance fleet over the checked-in chunked payload —
    # every batch verified bit-identical against a single resident
    # CodecService, plus a live 3->2 rebalance mid-query-stream with zero
    # failed tickets.  BENCH_fleet.json tracks throughput/p99/hit rates.
    python -m benchmarks.fleet_bench --smoke
    test -s benchmarks/results/BENCH_fleet.json
    echo "fleet smoke OK: $(tr -d '\n' < benchmarks/results/BENCH_fleet.json | head -c 200)"
}

phase_fleet_procs_smoke() {
    # Multi-process fleet smoke: the same protocol over 3 real OS-process
    # workers (repro.fleet.worker behind SocketTransport) — bit-identical to
    # a single resident instance, including a live rebalance that terminates
    # one worker with zero failed tickets.
    python -m benchmarks.fleet_bench --smoke --procs 3
    test -s benchmarks/results/BENCH_fleet_procs.json
    echo "fleet procs smoke OK: $(tr -d '\n' < benchmarks/results/BENCH_fleet_procs.json | head -c 200)"
}

phase_kernels() {
    # Kernel backends: the pytest sweeps (decode-tile interpret-vs-oracle
    # bit-parity, attention/LSTM backends) plus the fused-decode roofline
    # smoke, which writes BENCH_kernels.json for the bench gate — the
    # fused path must hold its entries/sec and its speedup over the
    # eager multi-launch serving path from PR to PR.
    python -m pytest -x -q tests/test_kernels.py
    python -m benchmarks.kernels_bench --smoke
    test -s benchmarks/results/BENCH_kernels.json
    echo "kernels OK: $(tr -d '\n' < benchmarks/results/BENCH_kernels.json | head -c 200)"
}

phase_temporal() {
    # Versioned payloads: the v4 delta-container suite (writer discipline,
    # store round-trips, single-vs-fleet bit-identity) plus the golden
    # backward-compat matrix (legacy v2 / monolithic v3 / chunked v3 / v4
    # fixtures must keep decoding to their frozen values), then the fig10
    # smoke — delta chains must need >= 3x fewer bytes per version than
    # independent fits at matched fitness (BENCH_fig10.json joins the gate).
    python -m pytest -x -q tests/test_temporal.py tests/test_golden.py
    python -m benchmarks.fig10_temporal --smoke
    test -s benchmarks/results/BENCH_fig10.json
    echo "temporal OK: $(tr -d '\n' < benchmarks/results/BENCH_fig10.json | head -c 200)"
}

phase_obs() {
    # Observability: the repro.obs suite (ring recorder, metrics, export,
    # report CLI, cross-process stitching) plus the traced 3-instance fleet
    # smoke — answers must be bit-identical traced vs untraced and the
    # tracing overhead must hold the <=10% budget (obs.traced_overhead_pct
    # in the bench gate).  results/obs_trace.json is the CI trace artifact
    # (Chrome trace-event format, loadable in Perfetto).
    python -m pytest -x -q tests/test_obs.py
    python -m benchmarks.obs_bench --smoke
    test -s benchmarks/results/obs_trace.json
    test -s benchmarks/results/BENCH_obs.json
    python -m repro.obs.report benchmarks/results/obs_trace.json
    echo "obs OK: $(tr -d '\n' < benchmarks/results/BENCH_obs.json | head -c 200)"
}

phase_slo() {
    # The closed observability loop: SLO engine + canary + controller unit
    # suites, then the end-to-end autoscale drill — a REAL 3-worker socket
    # fleet with an injected per-flush latency fault must breach the p99
    # objective, admit a sleep-free standby, go idle, and retire it again,
    # with every answer bit-identical to a resident CodecService, zero
    # failed tickets, and the controller decisions visible as spans/events.
    python -m pytest -x -q tests/test_slo.py tests/test_canary.py tests/test_controller.py
    python scripts/slo_smoke.py
}

phase_repair() {
    # Replica-aware read repair: the unit/integration suite, then the
    # end-to-end fault-injection drill — a REAL 3-worker socket fleet
    # (replication=2) serves through a CRC-flipped chunk and an injected
    # fitness regression with zero failed tickets and bit-identical
    # untouched answers; the RepairController restores the chunk from a
    # donor replica and re-compresses the breached range online until the
    # canary clears the SLO.  BENCH_repair.json carries the
    # time-to-repair / refit-throughput bench cells and
    # repair_trace.json is the CI trace artifact.
    python -m pytest -x -q tests/test_repair.py
    python scripts/repair_drill.py
    test -s benchmarks/results/BENCH_repair.json
    test -s benchmarks/results/repair_trace.json
    echo "repair OK: $(tr -d '\n' < benchmarks/results/BENCH_repair.json | head -c 200)"
}

phase_bench_gate() {
    # Fail on >30% regression of the headline BENCH metrics vs the
    # committed baseline (scripts/check_bench.py --update reseeds it).
    python scripts/check_bench.py
}

case "${1:-all}" in
    registry)          phase_registry ;;
    pytest)            phase_pytest ;;
    spmd)              phase_spmd ;;
    stream-smoke)      phase_stream_smoke ;;
    fleet-smoke)       phase_fleet_smoke ;;
    fleet-procs-smoke) phase_fleet_procs_smoke ;;
    kernels)           phase_kernels ;;
    temporal)          phase_temporal ;;
    obs)               phase_obs ;;
    slo)               phase_slo ;;
    repair)            phase_repair ;;
    bench-gate)        phase_bench_gate ;;
    all)
        phase_registry
        phase_pytest
        phase_spmd
        phase_stream_smoke
        phase_fleet_smoke
        phase_fleet_procs_smoke
        phase_kernels
        phase_temporal
        phase_obs
        phase_slo
        phase_repair
        phase_bench_gate
        ;;
    *)
        # Custom selections run as a single pass-through invocation (the SPMD
        # subprocess tests force their own device count regardless), so paths
        # never run twice and keep the single-device main-process view.
        exec python -m pytest -x -q "$@"
        ;;
esac
