#!/usr/bin/env python3
"""Offline approximation of the repo's ruff gate (see [tool.ruff] in
pyproject.toml) for machines without ruff installed — CI runs the real
thing; this keeps the lint job green from a network-less dev box.

Checks implemented (a subset of ``E4/E7/E9/E501/F/I``):

- E501  line longer than 100 characters
- E401  multiple imports on one line (``import os, sys``)
- E701/E702  compound statements (colon/semicolon) — rough, string-safe-ish
- E711/E712  comparison to None/True/False with ==/!=
- E722  bare except
- E731  lambda assignment (respects ``# noqa``)
- E741  ambiguous names ``l``/``O``/``I`` bound by assignment/for/args
- E9    syntax errors (ast.parse)
- F401  imported but unused (respects ``__all__``, ``# noqa``)
- F541  f-string without placeholders
- I001  import block ordering: stdlib -> third-party -> first-party
        (repro/benchmarks), alphabetical within a section, straight
        imports before from-imports

    python scripts/lint_lite.py [paths...]   # default: the whole repo
"""
from __future__ import annotations

import ast
import os
import sys

LINE_LIMIT = 100
FIRST_PARTY = {"repro", "benchmarks"}
STDLIB = set(getattr(sys, "stdlib_module_names", ()))


def _noqa(lines: list[str], lineno: int) -> bool:
    return "noqa" in lines[lineno - 1] if 0 < lineno <= len(lines) else False


def _section(module: str) -> int:
    root = module.split(".")[0]
    if root == "__future__":
        return 0
    if root in STDLIB:
        return 1
    if root in FIRST_PARTY:
        return 3
    return 2  # third-party (unknown modules too, matching ruff's default)


def check_file(path: str) -> list[str]:
    problems: list[str] = []
    with open(path, encoding="utf-8") as f:
        src = f.read()
    lines = src.splitlines()

    def report(lineno: int, code: str, msg: str) -> None:
        if not _noqa(lines, lineno):
            problems.append(f"{path}:{lineno}: {code} {msg}")

    for i, line in enumerate(lines, 1):
        if len(line) > LINE_LIMIT:
            report(i, "E501", f"line too long ({len(line)} > {LINE_LIMIT})")

    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [f"{path}:{e.lineno}: E999 {e.msg}"]

    # -- names used anywhere (rough F401 denominator) ----------------------
    used: set[str] = set()
    dunder_all: set[str] = set()
    format_specs: set[int] = set()  # JoinedStr nodes that are format specs
    for node in ast.walk(tree):
        if isinstance(node, ast.FormattedValue) and node.format_spec is not None:
            format_specs.add(id(node.format_spec))
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            pass  # attribute roots arrive via their Name node
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    if isinstance(node.value, (ast.List, ast.Tuple)):
                        dunder_all |= {
                            e.value
                            for e in node.value.elts
                            if isinstance(e, ast.Constant) and isinstance(e.value, str)
                        }

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if len(node.names) > 1:
                report(node.lineno, "E401", "multiple imports on one line")
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                if bound not in used and bound not in dunder_all:
                    report(node.lineno, "F401", f"{alias.name!r} imported but unused")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                bound = alias.asname or alias.name
                if bound not in used and bound not in dunder_all:
                    report(node.lineno, "F401", f"{alias.name!r} imported but unused")
        elif isinstance(node, ast.Compare):
            for op, comp in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)) and isinstance(comp, ast.Constant):
                    if comp.value is None:
                        report(node.lineno, "E711", "comparison to None with ==/!=")
                    elif comp.value is True or comp.value is False:
                        report(node.lineno, "E712", f"comparison to {comp.value} with ==/!=")
        elif isinstance(node, ast.ExceptHandler) and node.type is None:
            report(node.lineno, "E722", "bare except")
        elif isinstance(node, ast.Assign) and isinstance(node.value, ast.Lambda):
            report(node.lineno, "E731", "lambda assignment")
        elif isinstance(node, ast.JoinedStr) and id(node) not in format_specs:
            if not any(isinstance(v, ast.FormattedValue) for v in node.values):
                report(node.lineno, "F541", "f-string without placeholders")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            args = node.args
            for a in (
                args.posonlyargs + args.args + args.kwonlyargs
                + ([args.vararg] if args.vararg else [])
                + ([args.kwarg] if args.kwarg else [])
            ):
                if a.arg in {"l", "O", "I"}:
                    report(a.lineno, "E741", f"ambiguous argument name {a.arg!r}")
        elif isinstance(node, (ast.Name,)) and isinstance(
            getattr(node, "ctx", None), ast.Store
        ):
            if node.id in {"l", "O", "I"}:
                report(node.lineno, "E741", f"ambiguous variable name {node.id!r}")

    # -- import ordering (I001, module top-level blocks) -------------------
    # Matches ruff's isort defaults: sections stdlib -> third-party ->
    # first-party; within a section straight imports precede from-imports,
    # each alphabetized.  A block interrupted by any other statement is
    # checked on its own (matching ruff, which only sorts contiguous runs).
    def check_block(block: list[tuple[int, tuple]]) -> None:
        keys = [k for _, k in block]
        if keys != sorted(keys):
            for (lineno, key), prev in zip(block[1:], keys):
                if key < prev:
                    report(lineno, "I001", "import block is un-sorted or un-sectioned")
                    break

    block: list[tuple[int, tuple]] = []
    for node in tree.body:
        if isinstance(node, ast.Import):
            mod = node.names[0].name
            block.append((node.lineno, (_section(mod), 0, mod.lower())))
        elif isinstance(node, ast.ImportFrom):
            mod = ("." * node.level) + (node.module or "")
            block.append(
                (node.lineno, (_section(mod or "."), 1, (node.module or "").lower()))
            )
        else:
            if block:
                check_block(block)
            block = []
    if block:
        check_block(block)
    return problems


def iter_py(paths: list[str]):
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs if d not in {"__pycache__", ".git"}]
                yield from (
                    os.path.join(root, f) for f in files if f.endswith(".py")
                )


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv or [
        os.path.join(repo, d)
        for d in ("src", "tests", "benchmarks", "scripts", "examples")
    ]
    problems: list[str] = []
    n = 0
    for path in sorted(iter_py(paths)):
        n += 1
        problems.extend(check_file(path))
    for p in problems:
        print(p)
    print(f"lint_lite: {n} files, {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
