#!/usr/bin/env python3
"""End-to-end SLO/controller drill over a REAL 3-worker socket fleet.

The CI acceptance cell for the metrics-driven autoscaler: three
``repro.fleet.worker`` OS processes serve a chunked payload (with a
TCDQ held-out block, canaries fully on) while every initial worker
carries an injected ``--debug-flush-sleep-ms`` latency fault.  A
:class:`FleetController` polls real ``collect()`` samples:

1. drill traffic breaches the p99 objective -> the controller admits a
   sleep-free standby (``s0``), live, behind the drain barrier;
2. traffic stops -> the idle streak retires ``s0`` again;
3. throughout, every answer is verified bit-identical against a single
   resident ``CodecService`` and zero tickets fail;
4. the whole drill is traced — controller decisions must show up as
   ``controller.*`` spans in ``obs.report --format json`` and as
   ``controller_decision`` events — and the live fleet is scraped once
   through ``MetricsServer`` to prove the exposition path end to end.

    PYTHONPATH=src python scripts/slo_smoke.py
"""
import sys
import tempfile
import urllib.request

import numpy as np

from repro import obs
from repro.codecs import get_codec
from repro.fleet import (
    ControllerConfig,
    FleetController,
    FleetFrontend,
    SocketTransport,
    collect,
)
from repro.obs.exposition import render_exposition
from repro.obs.report import load_trace, report_dict
from repro.obs.serve_metrics import MetricsServer
from repro.serve.codec_service import CodecService
from repro.stream import sample_heldout, write_chunked

SHAPE = (16, 16, 8)
SLEEP_MS = 30.0  # injected per-flush latency fault on the initial workers
N_TICKS_MAX = 12


def _payload(tmp: str) -> str:
    x = np.random.default_rng(0).random(SHAPE).astype(np.float32)
    enc = get_codec("ttd").fit(x, max_rank=4)
    path = f"{tmp}/slo_smoke.tcdc"
    write_chunked(path, enc, chunk_bytes=1024,
                  heldout=sample_heldout(x, 128, seed=0))
    return path


def _batches(n=8, per=100):
    rng = np.random.default_rng(2)
    return [
        np.stack([rng.integers(0, s, per) for s in SHAPE], axis=1)
        for _ in range(n)
    ]


def _factory(iid: str):
    # initial workers (w*) carry the latency fault; standbys (s*) do not
    return SocketTransport.spawn(
        iid,
        timeout=30.0,
        canary_fraction=1.0,
        debug_flush_sleep_ms=SLEEP_MS if iid.startswith("w") else 0.0,
    )


def main() -> int:
    with tempfile.TemporaryDirectory() as tmp:
        path = _payload(tmp)
        batches = _batches()
        single = CodecService()
        single.load_stream("e", path, tile_entries=256)
        reference = [single.decode_at("e", idx) for idx in batches]

        obs.enable_tracing()
        obs.clear_events()
        fleet = FleetFrontend(
            ["w0", "w1", "w2"], transport_factory=_factory
        )
        ctl = FleetController(fleet, ControllerConfig(
            p99_target_ms=5.0,
            breach_evals=2, clear_evals=1,
            idle_flushes_per_eval=1.0, idle_evals=2, cooldown_evals=1,
            min_instances=3, max_instances=4,
        ))
        try:
            fleet.load_stream("e", path, tile_entries=256)

            def serve_round():
                for k, idx in enumerate(batches):
                    out = fleet.decode_at("e", idx)
                    assert np.array_equal(out, reference[k]), (
                        f"answer {k} diverged from the resident reference"
                    )
                assert not fleet.failed, f"failed tickets: {fleet.failed}"

            # phase 1: drill traffic under the latency fault -> scale up
            scaled_up_at = None
            for tick in range(N_TICKS_MAX):
                serve_round()
                d = ctl.step()
                if d.action == "scale_up":
                    scaled_up_at = tick
                    break
            assert scaled_up_at is not None, (
                f"no scale_up in {N_TICKS_MAX} ticks: "
                f"{[d.action for d in ctl.decisions]}"
            )
            assert "s0" in fleet.transports and len(fleet.transports) == 4
            serve_round()  # answers still bit-identical on the 4-wide ring

            # one live scrape through the exposition HTTP path
            snap = collect(fleet).as_dict()
            with MetricsServer(lambda: render_exposition(fleet=snap)) as srv:
                host, port = srv.address
                page = urllib.request.urlopen(
                    f"http://{host}:{port}/metrics", timeout=10
                ).read().decode()
            assert "repro_fleet_instances 4" in page, page[:400]
            assert "repro_fleet_canary_checks" in page, page[:400]
            assert snap["canary"]["e"]["checks"] > 0

            # phase 2: stop traffic -> idle streak retires the standby
            scaled_down_at = None
            for tick in range(N_TICKS_MAX):
                d = ctl.step()
                if d.action == "scale_down":
                    scaled_down_at = tick
                    break
            assert scaled_down_at is not None, (
                f"no scale_down in {N_TICKS_MAX} idle ticks: "
                f"{[d.action for d in ctl.decisions]}"
            )
            assert "s0" not in fleet.transports and len(fleet.transports) == 3
            serve_round()  # and still bit-identical after the retire
            final_metrics = collect(fleet).as_dict()
        finally:
            fleet.close()
            obs.disable_tracing()

        # the drill must be visible in the trace and the event stream
        trace = f"{tmp}/slo_smoke_trace.json"
        obs.export_chrome_trace(trace, metrics=final_metrics)
        doc = report_dict(load_trace(trace), top=5)
        stages = {r["stage"] for r in doc["stages"]}
        for want in ("controller.step", "controller.scale_up",
                     "controller.scale_down"):
            assert want in stages, f"missing {want} span in {sorted(stages)}"
        acts = [e["action"] for e in obs.events("controller_decision")]
        assert acts.count("scale_up") == 1 and acts.count("scale_down") == 1

        obs.get_recorder().clear()
        print(
            "slo smoke OK: scale_up tick="
            f"{scaled_up_at} scale_down tick={scaled_down_at} "
            f"decisions={acts} canary_checks="
            f"{final_metrics['canary']['e']['checks']} "
            f"failed_tickets=0 bit_identical=True"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
