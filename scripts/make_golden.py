"""Regenerate the checked-in golden containers under tests/golden/.

One tiny payload per on-disk format the loaders promise to keep reading:

* ``v2_nttd.bin``      — legacy headerless NTTD blob (pre-container)
* ``v3_mono.tcdc``     — monolithic v3 container (TT payload)
* ``v3_chunked.tcdc``  — chunked v3 container with entry ranges
* ``v4_delta.tcdc``    — delta-coded v4 container (keyframe + 2 deltas)

``expected.npz`` freezes probe indices and the decoded values at write
time; ``tests/test_golden.py`` replays every file through ``load_bytes``
and the serve layer and compares against it.  The payloads are built
from seeded rng state (TT cores drawn directly, NTTD fitted with a fixed
seed) so regeneration is reproducible, but the CONTRACT is the checked-in
bytes: only rerun this when the formats gain a new golden, and check in
the result.

Run from the repo root:  PYTHONPATH=src python scripts/make_golden.py
"""
from __future__ import annotations

import os

import numpy as np

from repro.codecs import container, get_codec
from repro.codecs.adapters import TTEncoded
from repro.core import ttd
from repro.stream import write_chunked
from repro.temporal import VersionedStore

GOLDEN = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")
SHAPE = (6, 5, 4)


def _tt_encoded(seed: int, rank: int = 3) -> TTEncoded:
    """A TT payload from seeded rng cores — no SVD, bit-reproducible."""
    rng = np.random.default_rng(seed)
    ranks = [1, rank, rank, 1]
    cores = [
        rng.standard_normal((ranks[k], n, ranks[k + 1])).astype(np.float32)
        for k, n in enumerate(SHAPE)
    ]
    return TTEncoded(ttd.TTDecomposition(cores))


def _probe_indices(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    return np.stack([rng.integers(0, s, n) for s in SHAPE], axis=1)


def main() -> None:
    os.makedirs(GOLDEN, exist_ok=True)
    rng = np.random.default_rng(2026)
    idx = _probe_indices(rng)
    expected: dict[str, np.ndarray] = {"indices": idx}

    # v2: headerless NTTD body, the pre-container format
    x = rng.random(SHAPE).astype(np.float32)
    enc2 = get_codec("nttd").fit(
        x, rank=2, hidden=4, epochs=2, batch_size=64, eval_batch=64,
        init_reorder=False, update_reorder=False, seed=0,
    )
    with open(os.path.join(GOLDEN, "v2_nttd.bin"), "wb") as f:
        f.write(enc2.to_bytes())
    expected["v2_nttd"] = np.asarray(enc2.decode_at(idx), np.float64)

    # v3 monolithic + v3 chunked share one TT payload
    enc3 = _tt_encoded(seed=3)
    container.save_file(os.path.join(GOLDEN, "v3_mono.tcdc"), enc3)
    write_chunked(os.path.join(GOLDEN, "v3_chunked.tcdc"), enc3, chunk_bytes=512)
    expected["v3"] = np.asarray(enc3.decode_at(idx), np.float64)

    # v4: TT keyframe + 2 rank-1 residual versions (keyframes every 4)
    versions = [np.asarray(_tt_encoded(seed=3).to_dense(), np.float32)]
    for k in range(2):
        bump = _tt_encoded(seed=40 + k, rank=1)
        versions.append(versions[-1] + 0.05 * np.asarray(bump.to_dense(), np.float32))
    path4 = os.path.join(GOLDEN, "v4_delta.tcdc")
    with VersionedStore.create(
        path4, "ttd", keyframe_interval=4, chunk_bytes=512,
        keyframe_opts={"max_rank": 4}, delta_opts={"max_rank": 2},
    ) as store:
        for v in versions:
            store.append(v)
    with VersionedStore.open(path4) as reader:
        for v in range(reader.n_versions):
            expected[f"v4_version{v}"] = np.asarray(
                reader.decode_at(idx, version=v), np.float64
            )

    np.savez(os.path.join(GOLDEN, "expected.npz"), **expected)
    for name in sorted(os.listdir(GOLDEN)):
        print(f"{name}: {os.path.getsize(os.path.join(GOLDEN, name))} bytes")


if __name__ == "__main__":
    main()
