"""Benchmark harness: one module per paper table/figure + substrate benches.

Prints ``name,us_per_call,derived`` CSV rows.  Set REPRO_BENCH_FULL=1 for
the full dataset/epoch budgets (hours); the default budget finishes on a
single CPU core in ~15 minutes.
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.table2_stats",
    "benchmarks.fig3_tradeoff",
    "benchmarks.fig4_ablation",
    "benchmarks.fig5_compress_scaling",
    "benchmarks.fig6_reconstruct_scaling",
    "benchmarks.fig7_order_quality",
    "benchmarks.fig8_expressiveness",
    "benchmarks.fig9_speed",
    "benchmarks.kernels_bench",
    "benchmarks.lm_steps",
    "benchmarks.fleet_bench",
]


def main() -> None:
    import importlib

    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if only and only not in mod_name:
            continue
        try:
            importlib.import_module(mod_name).run()
        except Exception as e:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(mod_name)
            print(f"{mod_name},0,ERROR:{type(e).__name__}")
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
