"""Fig. 9: total compression wall time, TensorCodec vs competitors (same
budget protocol as fig3, one dataset)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit, save_rows
from repro.core import codec, cpd, tensor_ring, ttd, tucker
from repro.data import synthetic_tensors as st


def run() -> None:
    x = st.load("uber", mini=True)
    rows = []

    t0 = time.time()
    ct, _ = codec.compress(
        x, codec.CodecConfig(rank=6, hidden=12, epochs=40 if not FULL else 150,
                             batch_size=8192, lr=1e-2, patience=6)
    )
    t_tc = time.time() - t0
    budget = ct.payload_bytes() // 8

    t0 = time.time()
    ttd.tt_svd(x, max_rank=ttd.tt_rank_for_budget(x.shape, budget))
    t_tt = time.time() - t0
    t0 = time.time()
    cpd.cp_als(x, cpd.cp_rank_for_budget(x.shape, budget), iters=25)
    t_cp = time.time() - t0
    t0 = time.time()
    tucker.tucker_hooi(x, tucker.tucker_ranks_for_budget(x.shape, budget), iters=4)
    t_tk = time.time() - t0
    t0 = time.time()
    tensor_ring.tr_svd(x, max(tensor_ring.tr_rank_for_budget(x.shape, budget), 2))
    t_tr = time.time() - t0

    for name, t in [("tensorcodec", t_tc), ("ttd", t_tt), ("cpd", t_cp),
                    ("tucker", t_tk), ("tr", t_tr)]:
        rows.append([name, round(t, 3)])
        emit(f"fig9_{name}", t * 1e6, f"seconds={t:.3f}")
    emit("fig9_slowdown_vs_ttd", 0.0, f"x{t_tc / max(t_tt, 1e-9):.1f}")
    save_rows("fig9_speed.csv", ["method", "seconds"], rows)


if __name__ == "__main__":
    run()
