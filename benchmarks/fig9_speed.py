"""Fig. 9: total compression wall time, TensorCodec vs competitors (same
budget protocol as fig3, one dataset, every codec the registry knows)."""
from __future__ import annotations

import time

from benchmarks.common import FULL, emit, save_rows
from repro.codecs import available, get_codec
from repro.data import synthetic_tensors as st

NTTD_OPTS = dict(rank=6, hidden=12, epochs=40 if not FULL else 150,
                 batch_size=8192, lr=1e-2, patience=6)


def run() -> None:
    x = st.load("uber", mini=True)
    rows = []
    times = {}

    t0 = time.time()
    ref = get_codec("nttd").fit(x, **NTTD_OPTS)
    times["nttd"] = time.time() - t0
    budget = ref.payload_bytes()

    for name in available():
        if name == "nttd":
            continue
        t0 = time.time()
        try:
            get_codec(name).fit(x, budget)
        except ValueError as e:  # budget below a codec's floor: report, go on
            emit(f"fig9_{name}", 0.0, f"skipped:{e}")
            continue
        times[name] = time.time() - t0

    for name, t in times.items():
        rows.append([name, round(t, 3)])
        emit(f"fig9_{name}", t * 1e6, f"seconds={t:.3f}")
    if "ttd" in times:
        emit("fig9_slowdown_vs_ttd", 0.0,
             f"x{times['nttd'] / max(times['ttd'], 1e-9):.1f}")
    save_rows("fig9_speed.csv", ["method", "seconds"], rows)


if __name__ == "__main__":
    run()
