"""Fig. 6: reconstruction time vs the largest mode size, per codec.

Every codec in the ``repro.codecs`` registry is fit once per mode size
(cheap knobs — this figure times QUERIES, not fitting) and a fixed batch
of ``decode_at`` lookups is timed.  The paper's claim (Theorem 3) is that
NTTD reconstruction is logarithmic in N_max: its time follows d' =
O(log N_max) while the table-lookup decompositions stay flat and SZ-lite
pays a full decompression; the summary row reports NTTD's time ratio
against the 64x mode growth."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    FULL,
    NTTD_FIT_OPTS,
    emit,
    save_rows,
    scaling_budget,
    timeit,
)
from repro.codecs import available, get_codec

EXPS = [6, 8, 10, 12] + ([14] if FULL else [])
N_QUERIES = 1 << 14
NTTD_OPTS = {**NTTD_FIT_OPTS, "init_reorder": False}


def _fit(name: str, x: np.ndarray):
    if name == "nttd":
        return get_codec(name).fit(x, **NTTD_OPTS)
    return get_codec(name).fit(x, scaling_budget(x.size))


def run() -> None:
    rows = []
    nttd_pts = []
    for e in EXPS:
        n = 1 << e
        shape = (n, 8, 8)
        rng = np.random.default_rng(0)
        x = rng.random(shape).astype(np.float32)
        idx = np.stack([rng.integers(0, s, N_QUERIES) for s in shape], axis=1)
        for name in available():
            try:
                enc = _fit(name, x)
            except ValueError as err:
                emit(f"fig6_{name}_nmax_2e{e}", 0.0, f"skipped:{err}")
                continue
            enc.decode_at(idx)  # warm (jit compile / dense cache)
            dt = timeit(lambda: np.asarray(enc.decode_at(idx)))
            rows.append([name, n, round(dt, 5)])
            emit(f"fig6_{name}_nmax_2e{e}", dt * 1e6 / N_QUERIES,
                 f"total_s={dt:.4f}")
            if name == "nttd":
                nttd_pts.append((e, dt))
    # NTTD should grow ~linearly in log(N_max) == e, far below linear in N
    ts = np.array([p[1] for p in nttd_pts], float)
    ratio = float(ts[-1] / max(ts[0], 1e-12))
    nratio = (1 << EXPS[-1]) / (1 << EXPS[0])
    emit("fig6_sublinearity", 0.0,
         f"nttd_time_ratio={ratio:.2f};mode_ratio={nratio:.0f};log_like={ratio < 4}")
    save_rows("fig6_reconstruct_scaling.csv", ["codec", "n_max", "seconds"], rows)


if __name__ == "__main__":
    run()
