"""Fig. 6: reconstruction time is logarithmic in the largest mode size.

Fixed number of reconstructed entries; mode sizes grow 2^6 .. 2^12; the
fit reports time vs log2(N_max) linearity (Theorem 3)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FULL, emit, save_rows
from repro.core import nttd
from repro.core.folding import make_folding_spec

EXPS = [6, 8, 10, 12] + ([14, 16] if FULL else [])
N_QUERIES = 1 << 16


def run() -> None:
    rows = []
    pts = []
    for e in EXPS:
        n = 1 << e
        shape = (n, 8, 8)
        spec = make_folding_spec(shape)
        cfg = nttd.NTTDConfig(rank=8, hidden=8)
        params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg)
        predict = nttd.make_predict(spec, cfg)
        rng = np.random.default_rng(0)
        pos = np.stack([rng.integers(0, s, N_QUERIES) for s in shape], axis=1)
        jpos = jnp.asarray(pos, jnp.int32)
        predict(params, jpos).block_until_ready()  # compile
        t0 = time.time()
        for _ in range(3):
            predict(params, jpos).block_until_ready()
        dt = (time.time() - t0) / 3
        rows.append([n, spec.d_prime, round(dt, 4)])
        pts.append((e, dt))
        emit(f"fig6_nmax_2e{e}", dt * 1e6 / N_QUERIES,
             f"d_prime={spec.d_prime};total_s={dt:.4f}")
    # time should grow ~linearly in log(N_max) == e (i.e. d'), far below linear in N
    es = np.array([p[0] for p in pts], float)
    ts = np.array([p[1] for p in pts], float)
    ratio = ts[-1] / ts[0]
    nratio = (1 << EXPS[-1]) / (1 << EXPS[0])
    emit("fig6_sublinearity", 0.0,
         f"time_ratio={ratio:.2f};mode_ratio={nratio:.0f};log_like={ratio < 4}")
    save_rows("fig6_reconstruct_scaling.csv", ["n_max", "d_prime", "seconds"], rows)


if __name__ == "__main__":
    run()
