"""Render the roofline table from the dry-run result JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single|multi]

Markdown table: per (arch x shape x mesh) the three roofline terms, the
dominant bound, peak per-device memory, the MODEL_FLOPS/HLO_FLOPS ratio,
and the roofline fraction.  Used to build EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_all() -> list[dict]:
    out = []
    for path in glob.glob(os.path.join(RESULTS_DIR, "*.json")):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | — | — | — | — | — | — |"
        )
    if r["status"] == "error":
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — | — | — | — | — |"
        )
    rf = r["roofline"]
    mem_gb = r["memory"]["peak_per_device"] / 1e9
    fits = "yes" if mem_gb <= 16 else f"no ({mem_gb:.0f}GB)"
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rf['dominant'].replace('_s','')} "
        f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
        f"| {r['useful_flops_ratio']:.2f} | {fits} | {rf['roofline_fraction']:.3f} |"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--rules", default=None)
    args = ap.parse_args()
    rows = load_all()
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    if args.rules:
        rows = [r for r in rows if r.get("rules") == args.rules]
    shape_key = lambda s: (  # noqa: E731
        SHAPE_ORDER.index(s) if s in SHAPE_ORDER else len(SHAPE_ORDER)
    )
    rows.sort(key=lambda r: (r["arch"], shape_key(r["shape"]), r["mesh"]))
    print(
        "| arch | shape | mesh | bound | compute_s | memory_s | collective_s "
        "| useful/HLO | fits 16GB | roofline frac |"
    )
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
        print(
            f"\nworst fraction: {worst['arch']} x {worst['shape']} x {worst['mesh']} "
            f"({worst['roofline']['roofline_fraction']:.4f})"
        )
        print(
            f"most collective-bound: {coll['arch']} x {coll['shape']} x {coll['mesh']} "
            f"({coll['roofline']['collective_s']:.3g}s)"
        )


if __name__ == "__main__":
    main()
