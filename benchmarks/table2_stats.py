"""Table II: density/smoothness of the synthetic dataset replicas vs the
paper's reported statistics (how faithful the offline stand-ins are)."""
from __future__ import annotations

import time

from benchmarks.common import emit, save_rows
from repro.data import synthetic_tensors as st


def run() -> None:
    rows = []
    for name, spec in st.DATASETS.items():
        t0 = time.time()
        x = st.load(name, mini=True)
        dens = st.density(x)
        smooth = st.smoothness(x, sample=1000)
        dt = time.time() - t0
        rows.append([name, "x".join(map(str, x.shape)), round(dens, 3),
                     spec.target_density, round(smooth, 3), spec.target_smoothness])
        emit(
            f"table2_{name}", dt * 1e6,
            f"density={dens:.3f}(paper {spec.target_density});"
            f"smoothness={smooth:.3f}(paper {spec.target_smoothness})",
        )
    save_rows(
        "table2_stats.csv",
        ["dataset", "shape", "density", "paper_density", "smoothness", "paper_smoothness"],
        rows,
    )


if __name__ == "__main__":
    run()
