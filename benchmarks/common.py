"""Shared benchmark utilities: CSV emission, budget-matched baselines."""
from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# Shared registry-fit protocol for the scaling figures (fig5/fig6): every
# codec gets the same ~5% of the fp64 dense bytes, and NTTD's work knob is
# a single epoch so time-per-entry stays constant across sizes.
# eval_batch matches batch_size so per-epoch work (train + fitness eval)
# is proportional to entries even for tensors smaller than one 64k batch
NTTD_FIT_OPTS = dict(rank=8, hidden=8, epochs=1, batch_size=4096,
                     eval_batch=4096, update_reorder=False)


def scaling_budget(n_entries: int) -> int:
    """~5% of the dense fp64 bytes, floored so tiny tensors stay feasible."""
    return max(n_entries * 8 // 20, 2048)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    return float(np.median(times))


def save_rows(fname: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
