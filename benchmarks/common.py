"""Shared benchmark utilities: CSV emission, budget-matched baselines."""
from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.time()
        fn()
        times.append(time.time() - t0)
    return float(np.median(times))


def save_rows(fname: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, fname)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
