"""Fig 10: delta-coded version chains vs independent per-version fits.

A drifting tensor sequence (``repro.temporal.drifting_versions``: a fixed
synthetic base plus cumulative low-rank drift and fresh noise per
version) is stored two ways at matched reconstruction fitness:

* **chain** — one ``VersionedStore`` (v4 container): version 0 is a full
  keyframe fit, later versions are residual fits against the previous
  version's reconstruction, keyframed every ``keyframe_interval``.
* **independent** — every version fitted from scratch with the keyframe
  settings, the way a v3-per-version deployment would store them.

The claim under test: because consecutive versions differ by a small
residual, the chain needs a FRACTION of the bytes per version — the
benchmark asserts >= 3x on the deterministic TT cell — while the chain's
fitness (measured against the true input, not the previous
reconstruction) stays within ``fitness_tol`` of the independent fits.

Rows land in ``results/BENCH_fig10.json``; ``scripts/check_bench.py``
gates ``bytes_ratio`` and ``chain_fitness`` against the baseline.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.codecs import get_codec
from repro.temporal import VersionedStore, drifting_versions

MIN_TT_RATIO = 3.0  # acceptance floor on the deterministic TT cell


def _cell(
    codec: str,
    shape: tuple[int, ...],
    n_versions: int,
    keyframe_interval: int,
    keyframe_opts: dict,
    delta_opts: dict,
    fitness_tol: float,
    delta_passes: int = 2,
) -> dict:
    data = drifting_versions(shape, n_versions, drift=0.04, noise=0.03, seed=11)

    # chain: one delta store, bytes and chain fitness from append stats
    with tempfile.TemporaryDirectory() as tmp:
        with VersionedStore.create(
            os.path.join(tmp, "chain.tcdc"),
            codec,
            keyframe_interval=keyframe_interval,
            chunk_bytes=4096,
            keyframe_opts=keyframe_opts,
            delta_opts=delta_opts,
            delta_passes=delta_passes,
        ) as store:
            stats = [store.append(x) for x in data]
    chain_bytes = float(np.mean([s["bytes"] for s in stats]))
    chain_fit = float(np.mean([s["fitness"] for s in stats]))

    # independent: every version fitted from scratch at keyframe settings
    c = get_codec(codec)
    opts = dict(keyframe_opts)
    budget = opts.pop("budget", None)
    ind_bytes, ind_fits = [], []
    for x in data:
        enc = c.fit(x, budget, **opts)
        ind_bytes.append(len(enc.to_bytes()))
        ind_fits.append(enc.fitness(x))
    ind_bytes_mean = float(np.mean(ind_bytes))
    ind_fit = float(np.mean(ind_fits))

    ratio = ind_bytes_mean / chain_bytes
    assert chain_fit >= ind_fit - fitness_tol, (
        f"{codec}: chain fitness {chain_fit:.4f} fell more than "
        f"{fitness_tol} below independent {ind_fit:.4f}"
    )
    row = {
        "codec": codec,
        "shape": list(shape),
        "n_versions": n_versions,
        "keyframe_interval": keyframe_interval,
        "bytes_per_version_chain": round(chain_bytes, 1),
        "bytes_per_version_independent": round(ind_bytes_mean, 1),
        "bytes_ratio": round(ratio, 3),
        "chain_fitness_mean": round(chain_fit, 4),
        "independent_fitness_mean": round(ind_fit, 4),
        "keyframes": sum(int(s["keyframe"]) for s in stats),
    }
    emit(
        f"fig10_{codec}", 0.0,
        f"ratio={ratio:.2f}x;chain_fit={chain_fit:.3f};ind_fit={ind_fit:.3f}",
    )
    return row


def run(smoke: bool = False) -> None:
    runs = []
    # deterministic TT cell: keyframe rank 10 vs residual rank 2 — the
    # bytes arithmetic is exact, so this is the >= 3x acceptance gate
    tt_shape, tt_versions = ((24, 16, 16), 6) if smoke else ((32, 24, 16), 12)
    runs.append(_cell(
        "ttd", tt_shape, tt_versions,
        keyframe_interval=6,
        keyframe_opts={"max_rank": 10},
        delta_opts={"max_rank": 2},
        fitness_tol=0.02,
    ))
    assert runs[0]["bytes_ratio"] >= MIN_TT_RATIO, (
        f"delta chain only {runs[0]['bytes_ratio']:.2f}x smaller than "
        f"independent fits (need >= {MIN_TT_RATIO}x)"
    )

    # paper-codec cell: NTTD keyframe vs warm-started residual refits;
    # stochastic SGD fits, so the tolerance is looser than the TT cell's
    # (in practice the chain comes out FITTER: each residual pass also
    # corrects what the keyframe net missed)
    nt_shape, nt_versions = ((16, 12, 10), 4) if smoke else ((24, 16, 16), 8)
    runs.append(_cell(
        "nttd", nt_shape, nt_versions,
        keyframe_interval=nt_versions,
        keyframe_opts=dict(rank=8, hidden=16, epochs=30, batch_size=2048,
                           eval_batch=2048, init_reorder=False,
                           update_reorder=False, seed=0),
        delta_opts=dict(rank=2, hidden=8, d_prime=2, lr=1e-2,
                        batch_size=1024, steps_per_slab=150, seed=0),
        fitness_tol=0.10,
    ))

    out = os.path.join(RESULTS_DIR, "BENCH_fig10.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"mode": "smoke" if smoke else "default", "runs": runs}, f,
                  indent=2)
    emit("fig10_json", 0.0, out)


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
