"""LM substrate micro-benchmarks: smoke-config train/prefill/decode step
latency on CPU (sanity + regression tracking; real perf lives in the
dry-run roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro import configs
from repro.models import model
from repro.optim import optimizers
from repro.train import step as step_lib

ARCHS = ["deepseek-coder-33b", "grok-1-314b", "mamba2-1.3b", "jamba-1.5-large-398b"]


def run() -> None:
    for arch in ARCHS:
        cfg = configs.get_smoke(arch)
        key = jax.random.PRNGKey(0)
        params = model.init_params(key, cfg)
        toks = jax.random.randint(key, (4, 64), 0, cfg.vocab)
        if cfg.input_kind == "embeddings":
            batch = {
                "embeds": jax.random.normal(key, (4, 64, cfg.d_model), jnp.float32),
                "labels": toks,
            }
        else:
            batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        opt = optimizers.adamw(1e-3)
        ost = opt.init(params)
        step = jax.jit(step_lib.make_train_step(cfg, opt))
        params, ost, _ = step(params, ost, batch)  # compile
        t0 = time.time()
        reps = 5
        for _ in range(reps):
            params, ost, m = step(params, ost, batch)
        jax.block_until_ready(m["loss"])
        emit(f"lm_train_step_{arch}", (time.time() - t0) / reps * 1e6,
             f"smoke;tokens={4*64}")


if __name__ == "__main__":
    run()
