"""Tracing-overhead benchmark: the ``repro.obs`` cost contract, measured.

Serves the NTTD payload through ONE fleet over the same batch sequence
with tracing toggled between passes (fused decode, so the traced passes
carry the full span stack: frontend → transport → service stages →
``kernel_decode``) and reports the traced slowdown as a percentage.
Answers must be bit-identical across traced and untraced passes (tracing
is observational only) and the overhead must stay under the gate CI
enforces (``obs.traced_overhead_pct`` <= 10 in ``check_bench``).

Untraced/traced passes ALTERNATE on the same warm fleet and the MEDIAN
wall time per mode is compared — the quantity under test (a hundred-odd
spans of bookkeeping, well under a millisecond) is far smaller than the
scheduler noise on any single pass, so interleaving cancels slow drift
and the median (unlike min-of-N, whose extremes are themselves noise
samples) converges on the true per-mode cost as repeats grow.

The traced run's spans land in ``results/obs_trace.json`` (Chrome
trace-event format with the fleet metrics snapshot embedded — the CI
artifact, loadable in Perfetto and summarized by
``python -m repro.obs.report``).

A second pair of cells measures the ONLINE FITNESS CANARY cost the same
way (two warm fleets, canaries off vs sampling ``CANARY_FRACTION`` of
decode calls against the payload's TCDQ held-out block): answers must
again be bit-identical and ``canary_overhead_pct`` joins the bench gate
at an absolute 10%% ceiling.

    python -m benchmarks.obs_bench --smoke        # the CI cell
    python -m benchmarks.obs_bench --procs 3      # real worker processes
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from benchmarks.fleet_bench import _batches, _ensure_nttd_payload
from repro import obs
from repro.fleet import FleetFrontend, SocketTransport, collect

TRACE_OUT = os.path.join(RESULTS_DIR, "obs_trace.json")


def _make_fleet(n: int, procs: bool) -> FleetFrontend:
    if procs:
        return FleetFrontend(
            [f"w{k}" for k in range(n)],
            transport_factory=lambda iid: SocketTransport.spawn(iid, timeout=60.0),
        )
    return FleetFrontend(n)


def _pass(fleet, batches) -> tuple[float, list[np.ndarray]]:
    t0 = time.perf_counter()
    outs = [fleet.decode_at("nttd", idx) for idx in batches]
    return time.perf_counter() - t0, outs


#: sampling fraction for the canary cells.  A check costs one extra
#: ~2ms decode DISPATCH (entry count is irrelevant at held-out sizes),
#: which the smoke cells' ~1ms flushes cannot hide — so the bench
#: samples sparsely; production fractions amortize over real batches.
CANARY_FRACTION = 0.02


def _canary_cells(path, batches, tile_entries, repeats):
    """Canary-overhead cells: the same interleaved-median methodology as
    the tracing cells, except the canary knob is a constructor parameter,
    so the modes alternate ACROSS two otherwise-identical warm in-process
    fleets instead of toggling one.  Answers must be bit-identical
    (canary decodes are pure extra reads) and the online checks must
    actually fire (the payload carries a TCDQ held-out block).

    Returns (overhead_pct, checks, eps_off, eps_on)."""
    fleets: dict[bool, FleetFrontend] = {}
    for on in (False, True):
        f = FleetFrontend(3, canary_fraction=CANARY_FRACTION if on else 0.0)
        f.load_stream("nttd", path, tile_entries=tile_entries)
        _pass(f, batches)  # warm-up (jit, materialization, tile fill)
        fleets[on] = f
    try:
        times: dict[bool, list[float]] = {False: [], True: []}
        results: dict[bool, list[np.ndarray]] = {}

        def _round() -> None:
            for _ in range(repeats):
                for on in (False, True):
                    dt, outs = _pass(fleets[on], batches)
                    times[on].append(dt)
                    if on not in results:
                        results[on] = outs

        def _overhead() -> float:
            off = statistics.median(times[False])
            on_t = statistics.median(times[True])
            return (on_t - off) / off * 100

        _round()
        if _overhead() > 10.0:
            # same pooled re-round policy as the tracing cells: the
            # medians converge on the true (few-percent) cost
            _round()
        for a, b in zip(results[False], results[True]):
            assert np.array_equal(a, b), "canaries changed answers"
        canary = collect(fleets[True]).canary
        checks = canary.get("nttd", {}).get("checks", 0)
        assert checks > 0, "canary never sampled a served batch"
        assert canary["nttd"]["rolling_fitness"] > 0.0
        n_entries = len(batches) * len(batches[0])
        return (
            _overhead(),
            checks,
            n_entries / statistics.median(times[False]),
            n_entries / statistics.median(times[True]),
        )
    finally:
        for f in fleets.values():
            f.close()


def run(smoke: bool = False, procs: int | None = None) -> None:
    path = _ensure_nttd_payload()
    os.environ["REPRO_DECODE_IMPL"] = "fused"  # spawned workers inherit
    n = procs if procs is not None else 3
    n_batches, batch, repeats = (16, 2048, 15) if smoke else (24, 4096, 21)
    rec = obs.get_recorder()
    try:
        probe = FleetFrontend(1)
        probe.load_stream("nttd", path)
        shape = probe.routes["nttd"].shape
        probe.close()
        tile_entries = max(int(np.prod(shape)) // 64, 64)
        batches = _batches(shape, n_batches, batch, seed=11)

        obs.disable_tracing()
        fleet = _make_fleet(n, procs is not None)
        try:
            fleet.load_stream("nttd", path, tile_entries=tile_entries)
            # warm-up: one untraced pass (jit, materialization) and one
            # traced pass (span code paths, worker-side lazy enable)
            _pass(fleet, batches)
            obs.enable_tracing()
            _pass(fleet, batches)
            rec.clear()

            times: dict[bool, list[float]] = {False: [], True: []}
            results: dict[bool, list[np.ndarray]] = {}

            def _round() -> None:
                for rep in range(repeats):
                    for traced in (False, True):
                        if traced:
                            # start each traced pass from an empty ring so
                            # every rep pays the same bookkeeping (a filling
                            # ring grows the GC's survivor set, which would
                            # drift later traced passes slower)
                            rec.clear()
                            obs.enable_tracing()
                        else:
                            obs.disable_tracing()
                        dt, outs = _pass(fleet, batches)
                        times[traced].append(dt)
                        if traced not in results:
                            results[traced] = outs

            def _overhead() -> float:
                off = statistics.median(times[False])
                on = statistics.median(times[True])
                return (on - off) / off * 100

            _round()
            if _overhead() > 10.0:
                # one pooled re-round before declaring failure: the medians
                # converge on the true cost (a few percent), so a first
                # estimate past the gate is noise more often than signal
                _round()
            overhead_pct = _overhead()
            # the last traced pass's spans + the metrics snapshot become
            # the CI trace artifact
            trace_spans = rec.snapshot()
            trace_metrics = collect(fleet).as_dict()
        finally:
            fleet.close()
            obs.disable_tracing()

        for a, b in zip(results[False], results[True]):
            assert np.array_equal(a, b), "tracing changed answers"
        best = {traced: statistics.median(ts) for traced, ts in times.items()}
        assert trace_spans, "traced run recorded no spans"
        n_spans = obs.export_chrome_trace(
            TRACE_OUT, spans=trace_spans, metrics=trace_metrics
        )
        # the artifact must be a loadable Chrome trace-event file
        with open(TRACE_OUT) as f:
            doc = json.load(f)
        assert doc["traceEvents"] and all(
            "ph" in ev for ev in doc["traceEvents"]
        )

        # canary cells run untraced and in-process either way — the knob
        # under test is the online fitness check, not the transport
        canary_pct, canary_checks, canary_eps_off, canary_eps_on = (
            _canary_cells(path, batches, tile_entries, repeats)
        )

        eps_off = n_batches * batch / best[False]
        eps_on = n_batches * batch / best[True]
        emit("obs_untraced", best[False] * 1e6 / n_batches,
             f"entries_per_sec={eps_off:.0f}")
        emit("obs_traced", best[True] * 1e6 / n_batches,
             f"entries_per_sec={eps_on:.0f};spans={n_spans}")
        emit("obs_traced_overhead", 0.0,
             f"overhead_pct={overhead_pct:.2f};bit_identical=True")
        emit("obs_canary_overhead", 0.0,
             f"overhead_pct={canary_pct:.2f};checks={canary_checks};"
             f"fraction={CANARY_FRACTION};bit_identical=True")

        out = os.path.join(RESULTS_DIR, "BENCH_obs.json")
        with open(out, "w") as f:
            json.dump({
                "mode": "smoke" if smoke else "default",
                "transport": "socket" if procs is not None else "local",
                "batches": n_batches,
                "batch_entries": batch,
                "repeats": repeats,
                "trace_file": os.path.basename(TRACE_OUT),
                "runs": [{
                    "instances": n,
                    "payload": "nttd",
                    "decode_impl": "fused",
                    "untraced_entries_per_sec": round(eps_off, 1),
                    "traced_entries_per_sec": round(eps_on, 1),
                    "traced_spans": n_spans,
                    "traced_overhead_pct": round(overhead_pct, 2),
                    "canary_fraction": CANARY_FRACTION,
                    "canary_checks": canary_checks,
                    "canary_entries_per_sec_off": round(canary_eps_off, 1),
                    "canary_entries_per_sec_on": round(canary_eps_on, 1),
                    "canary_overhead_pct": round(canary_pct, 2),
                }],
            }, f, indent=2)
        emit("obs_json", 0.0, out)
        # the same bound check_bench enforces, asserted at the source.
        # Only the in-process cell (what CI runs) carries the budget:
        # over sockets each flush additionally ships its span block, a
        # per-flush wire cost these tiny smoke batches cannot amortize.
        if procs is None:
            assert overhead_pct <= 10.0, (
                f"tracing overhead {overhead_pct:.2f}% exceeds the 10% budget"
            )
        # the canary cells are in-process in every mode, so their budget
        # always holds at the source (check_bench re-gates it in CI)
        assert canary_pct <= 10.0, (
            f"canary overhead {canary_pct:.2f}% exceeds the 10% budget"
        )
    finally:
        os.environ.pop("REPRO_DECODE_IMPL", None)
        os.environ.pop("REPRO_TRACE", None)
        obs.disable_tracing()
        obs.get_recorder().clear()


if __name__ == "__main__":
    procs = None
    if "--procs" in sys.argv:
        procs = int(sys.argv[sys.argv.index("--procs") + 1])
    run(smoke="--smoke" in sys.argv, procs=procs)
