"""Fig. 4: component ablation — TensorCodec vs -R (no repeated reorder),
-T (no TSP init either), -N (no neural net: plain TT-SVD on the folded
tensor at matched payload).  All fits go through the codec registry."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit, save_rows
from repro.codecs import get_codec
from repro.codecs.indexing import flat_to_multi
from repro.core.folding import make_folding_spec
from repro.data import synthetic_tensors as st

DATASETS = ["uber", "stock"] if not FULL else ["uber", "air_quality", "action", "stock"]


def _folded_ttsvd_fitness(x: np.ndarray, budget_bytes: int) -> float:
    """TensorCodec-N: TT-SVD on the folded tensor at the same payload
    budget (paper §V-C)."""
    spec = make_folding_spec(x.shape)
    folded = np.zeros(spec.folded_shape, dtype=np.float32)
    n = x.size
    flat = np.arange(n)
    idx = flat_to_multi(flat, x.shape)
    fidx = np.asarray(spec.fold_indices(idx))
    folded[tuple(fidx[:, j] for j in range(spec.d_prime))] = x.reshape(-1)
    t = get_codec("ttd").fit(folded, budget_bytes)
    recon = t.to_dense()[tuple(fidx[:, j] for j in range(spec.d_prime))]
    err = np.linalg.norm(recon - x.reshape(-1))
    return 1.0 - err / np.linalg.norm(x.reshape(-1))


def run() -> None:
    rows = []
    epochs = 50 if not FULL else 150
    nttd_codec = get_codec("nttd")
    for name in DATASETS:
        x = st.load(name, mini=True)
        common = dict(rank=6, hidden=12, epochs=epochs, batch_size=8192,
                      lr=1e-2, patience=8)
        t0 = time.time()
        full = nttd_codec.fit(x, **common)
        fit_full = full.fitness(x)
        no_r = nttd_codec.fit(x, update_reorder=False, **common)
        fit_r = no_r.fitness(x)
        no_t = nttd_codec.fit(x, update_reorder=False, init_reorder=False, **common)
        fit_t = no_t.fitness(x)
        fit_n = _folded_ttsvd_fitness(x, full.payload_bytes())
        dt = time.time() - t0
        rows.append([name, round(fit_full, 4), round(fit_r, 4), round(fit_t, 4),
                     round(fit_n, 4)])
        emit(
            f"fig4_{name}", dt * 1e6,
            f"full={fit_full:.4f};-R={fit_r:.4f};-T={fit_t:.4f};-N={fit_n:.4f}",
        )
    save_rows("fig4_ablation.csv", ["dataset", "full", "minus_R", "minus_T", "minus_N"], rows)


if __name__ == "__main__":
    run()
