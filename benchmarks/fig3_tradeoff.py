"""Fig. 3: compressed size vs fitness trade-off, TensorCodec vs the
decomposition competitors at matched parameter budgets.

Datasets are the synthetic Table-II replicas (mini shapes; the container is
offline — see DESIGN.md §9).  Competitors get the SAME payload budget the
codec used (paper protocol: sizes matched, fitness compared).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit, save_rows, timeit
from repro.core import codec, cpd, tensor_ring, ttd, tucker
from repro.data import synthetic_tensors as st

DATASETS = ["uber", "air_quality", "stock", "nyc"] if not FULL else list(st.DATASETS)


def run() -> None:
    rows = []
    for name in DATASETS:
        x = st.load(name, mini=True)
        epochs = 60 if not FULL else 200
        cfg = codec.CodecConfig(
            rank=6, hidden=12, epochs=epochs, batch_size=8192, lr=1e-2,
            reorder_samples=1024, patience=8,
        )
        t = timeit(lambda: None)  # placeholder so emit shape is uniform
        t0 = __import__("time").time()
        ct, log = codec.compress(x, cfg)
        t = __import__("time").time() - t0
        fit_tc = ct.fitness(x)
        budget_bytes = ct.payload_bytes()           # paper: fp64 convention
        budget_params = budget_bytes // 8

        r_tt = ttd.tt_rank_for_budget(x.shape, budget_params)
        fit_tt = ttd.tt_svd(x, max_rank=max(r_tt, 1)).fitness(x)
        r_cp = cpd.cp_rank_for_budget(x.shape, budget_params)
        fit_cp = cpd.cp_als(x, r_cp, iters=25).fitness(x)
        rk_tk = tucker.tucker_ranks_for_budget(x.shape, budget_params)
        fit_tk = tucker.tucker_hooi(x, rk_tk, iters=4).fitness(x)
        r_tr = tensor_ring.tr_rank_for_budget(x.shape, budget_params)
        tr = tensor_ring.tr_svd(x, max(r_tr, 2))
        fit_tr = tr.fitness(x)

        best_comp = max(fit_tt, fit_cp, fit_tk, fit_tr)
        rows.append([name, x.size, budget_bytes, round(fit_tc, 4), round(fit_tt, 4),
                     round(fit_cp, 4), round(fit_tk, 4), round(fit_tr, 4)])
        emit(
            f"fig3_{name}",
            t * 1e6,
            f"bytes={budget_bytes};tc={fit_tc:.4f};tt={fit_tt:.4f};cp={fit_cp:.4f};"
            f"tk={fit_tk:.4f};tr={fit_tr:.4f};tc_minus_best={fit_tc-best_comp:+.4f}",
        )
    save_rows(
        "fig3_tradeoff.csv",
        ["dataset", "entries", "budget_bytes", "tensorcodec", "ttd", "cpd", "tucker", "tr"],
        rows,
    )


if __name__ == "__main__":
    run()
