"""Fig. 3: compressed size vs fitness trade-off, TensorCodec vs every other
registered codec at matched payload budgets.

Datasets are the synthetic Table-II replicas (mini shapes; the container is
offline — see DESIGN.md §9).  Competitors get the SAME payload budget the
codec used (paper protocol: sizes matched, fitness compared) — each rival
comes from ``repro.codecs.available()``, so adding a codec to the registry
adds a column here with no wiring.
"""
from __future__ import annotations

import time

from benchmarks.common import FULL, emit, save_rows
from repro.codecs import available, get_codec
from repro.data import synthetic_tensors as st

DATASETS = ["uber", "air_quality", "stock", "nyc"] if not FULL else list(st.DATASETS)


def run() -> None:
    rivals = [n for n in available() if n != "nttd"]
    rows = []
    for name in DATASETS:
        x = st.load(name, mini=True)
        epochs = 60 if not FULL else 200
        t0 = time.time()
        enc = get_codec("nttd").fit(
            x, rank=6, hidden=12, epochs=epochs, batch_size=8192, lr=1e-2,
            reorder_samples=1024, patience=8,
        )
        t = time.time() - t0
        budget_bytes = enc.payload_bytes()          # paper: fp64 convention
        fits = {"nttd": enc.fitness(x)}
        for rival in rivals:
            try:
                fits[rival] = get_codec(rival).fit(x, budget_bytes).fitness(x)
            except ValueError:  # codec cannot meet this budget (e.g. szlite floor)
                fits[rival] = float("nan")

        best_rival = max(
            (fits[r] for r in rivals if fits[r] == fits[r]), default=float("-inf")
        )
        rows.append([name, x.size, budget_bytes]
                    + [round(fits[c], 4) for c in ["nttd"] + rivals])
        derived = ";".join(f"{c}={fits[c]:.4f}" for c in ["nttd"] + rivals)
        emit(
            f"fig3_{name}",
            t * 1e6,
            f"bytes={budget_bytes};{derived};"
            f"tc_minus_best={fits['nttd'] - best_rival:+.4f}",
        )
    save_rows(
        "fig3_tradeoff.csv",
        ["dataset", "entries", "budget_bytes", "nttd"] + rivals,
        rows,
    )


if __name__ == "__main__":
    run()
