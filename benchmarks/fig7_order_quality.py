"""Fig. 7 (quantitative proxy): order quality on a tensor with planted
spatial structure.  The paper shows NYC maps; offline we plant a 1-D
latent coordinate per index, shuffle, and measure how well the learned
order recovers latent adjacency (Spearman-style displacement) and the
Eq. 6 objective vs identity/random orders."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_rows
from repro.codecs import get_codec
from repro.core import reorder


def run() -> None:
    rng = np.random.default_rng(0)
    n0, n1, n2 = 40, 24, 16
    coord = np.linspace(0, 1, n0)
    x = (
        np.exp(-((coord[:, None, None] - np.linspace(0, 1, n1)[None, :, None]) ** 2) * 8)
        + 0.3 * np.sin(6 * coord)[:, None, None]
        + 0.05 * rng.normal(size=(n0, n1, n2))
    ).astype(np.float32)
    perm = rng.permutation(n0)
    xp = x[perm]

    t0 = time.time()
    enc = get_codec("nttd").fit(
        xp, rank=6, hidden=12, epochs=60, batch_size=4096, lr=1e-2, patience=10,
    )
    dt = time.time() - t0
    learned = enc.pi[0]

    def adjacency_score(order):
        # positions in latent space along the learned order
        latent = perm[order]
        return float(np.median(np.abs(np.diff(np.argsort(np.argsort(coord))[latent]))))

    ident = np.arange(n0)
    scores = {
        "learned": adjacency_score(learned),
        "identity": adjacency_score(ident),
        "random": adjacency_score(rng.permutation(n0)),
    }
    obj = {
        k: reorder.order_objective(xp, 0, v)
        for k, v in [("learned", learned), ("identity", ident)]
    }
    emit(
        "fig7_order_quality", dt * 1e6,
        f"median_latent_jump_learned={scores['learned']:.1f};identity={scores['identity']:.1f};"
        f"random={scores['random']:.1f};eq6_learned={obj['learned']:.1f};"
        f"eq6_identity={obj['identity']:.1f}",
    )
    save_rows("fig7_order_quality.csv", ["order", "median_jump"],
              [[k, v] for k, v in scores.items()])


if __name__ == "__main__":
    run()
