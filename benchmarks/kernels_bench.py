"""Kernel micro-benchmarks (XLA path timing on CPU; the Pallas path is the
TPU target and is validated, not timed, in this container)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops


def _time(fn, *args, reps=10):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run() -> None:
    rng = np.random.default_rng(0)
    b, k, r = 65536, 10, 8
    f = jnp.asarray(rng.normal(size=(b, r)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(b, k, r, r)) * 0.2, jnp.float32)
    last = jnp.asarray(rng.normal(size=(b, r)), jnp.float32)
    fn = jax.jit(lambda a, bb, c: ops.tt_contract(a, bb, c, impl="ref"))
    dt = _time(fn, f, m, last)
    emit("kernel_tt_contract_ref", dt * 1e6, f"B={b};K={k};R={r};{b/dt/1e6:.1f}M entries/s")

    t, h = 10, 16
    x = jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(h, 4 * h)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(h, 4 * h)) * 0.3, jnp.float32)
    bb = jnp.zeros((4 * h,), jnp.float32)
    fn = jax.jit(lambda *a: ops.lstm_scan(*a, impl="ref"))
    dt = _time(fn, x, wi, wh, bb)
    emit("kernel_lstm_ref", dt * 1e6, f"B={b};T={t};H={h};{b/dt/1e6:.1f}M seq/s")

    bq, s, hq, hkv, d = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(bq, s, hq, d)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(bq, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bq, s, hkv, d)), jnp.float32)
    fn = jax.jit(lambda *a: ops.attention(*a, impl="ref"))
    dt = _time(fn, q, kk, v, reps=3)
    flops = 4 * bq * hq * s * s * d
    emit("kernel_attention_ref", dt * 1e6, f"S={s};GQA{hq}/{hkv};{flops/dt/1e9:.1f}GFLOP/s")


if __name__ == "__main__":
    run()
