"""Kernel micro-benchmarks (XLA path timing on CPU; the Pallas path is the
TPU target and is validated, not timed, in this container).

The fused-decode section is the roofline record for ROADMAP item 3: it
times the serving hot path (``nttd.apply_at_positions``) as dispatched by
``CompressedTensor.decode`` — EAGER, multi-launch, one dispatch per op —
against ``kernel_impl="fused"`` (one program: the Pallas kernel on TPU,
the jitted oracle on CPU), validates interpret-mode bit-parity against
the oracle, and writes ``results/BENCH_kernels.json`` for ``check_bench``
to gate.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.core import nttd
from repro.core.folding import make_folding_spec
from repro.kernels import ops


def _time(fn, *args, reps=10):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def _time_eager(fn, *args, reps=10):
    """Per-call wall time WITHOUT jit — the multi-launch dispatch cost is
    the thing being measured, so no warmup-compile is subtracted beyond
    the first call."""
    np.asarray(fn(*args))  # first call pays any per-op compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    np.asarray(out)
    return (time.time() - t0) / reps


def decode_tile_roofline(smoke: bool = False) -> dict:
    """Fused vs multi-launch NTTD decode on one serving tile workload."""
    shape = (48, 40, 32)
    spec = make_folding_spec(shape)
    cfg_ref = nttd.NTTDConfig(rank=8, hidden=16, kernel_impl="ref")
    cfg_fused = nttd.NTTDConfig(rank=8, hidden=16, kernel_impl="fused")
    params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg_ref)
    bsz = 1024 if smoke else 4096
    rng = np.random.default_rng(0)
    pos = jnp.asarray(
        np.stack([rng.integers(0, s, bsz) for s in shape], axis=1), jnp.int32
    )

    # interpret-mode Pallas vs the jitted oracle: same compiled op order,
    # so parity is BITWISE (the gate tests also sweep this; the bench
    # asserts it on the exact workload being timed)
    folded = spec.fold_indices(pos)
    flat = nttd.fused_decode_inputs(params, spec, cfg_fused)
    got_i = np.asarray(
        ops.nttd_decode_tile(folded, *flat, impl="pallas_interpret", tile_b=256)
    )
    got_f = np.asarray(ops.nttd_decode_tile(folded, *flat, impl="fused"))
    assert np.array_equal(got_i, got_f), "interpret kernel drifted from oracle"

    # multi-launch: the eager serving path (CompressedTensor.decode runs
    # apply_at_positions un-jitted — one dispatch per op in the chain)
    multi = lambda p: nttd.apply_at_positions(params, p, spec, cfg_ref)  # noqa: E731
    dt_multi = _time_eager(multi, pos, reps=3 if smoke else 10)

    # fused: one XLA program end-to-end (jitted via make_predict)
    predict = nttd.make_predict(spec, cfg_fused)
    fused = lambda p: predict(params, p)  # noqa: E731
    dt_fused = _time(fused, pos, reps=10 if smoke else 50)

    # roofline accounting: weight bytes stream once per tile, flops are
    # dominated by the per-entry LSTM gate matmuls
    t_steps, hid, rank = spec.d_prime, cfg_ref.hidden, cfg_ref.rank
    flops_per_entry = t_steps * (2 * 2 * hid * 4 * hid) + 2 * hid * (
        2 * rank + (t_steps - 2) * rank * rank
    )
    weight_bytes = sum(int(np.prod(a.shape)) * 4 for a in flat)
    rec = {
        "batch": bsz,
        "shape": list(shape),
        "d_prime": t_steps,
        "multilaunch_entries_per_sec": round(bsz / dt_multi, 1),
        "fused_entries_per_sec": round(bsz / dt_fused, 1),
        "fused_speedup": round(dt_multi / dt_fused, 2),
        "fused_gflops": round(flops_per_entry * bsz / dt_fused / 1e9, 2),
        "weight_bytes_per_tile": weight_bytes,
        "parity_bitwise": True,
    }
    emit(
        "kernel_decode_tile_fused", dt_fused * 1e6,
        f"B={bsz};T={t_steps};{bsz/dt_fused/1e6:.2f}M entries/s;"
        f"speedup={rec['fused_speedup']:.1f}x over multi-launch",
    )
    return rec


def run(smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    b, k, r = 65536, 10, 8
    f = jnp.asarray(rng.normal(size=(b, r)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(b, k, r, r)) * 0.2, jnp.float32)
    last = jnp.asarray(rng.normal(size=(b, r)), jnp.float32)
    fn = jax.jit(lambda a, bb, c: ops.tt_contract(a, bb, c, impl="ref"))
    dt = _time(fn, f, m, last)
    emit("kernel_tt_contract_ref", dt * 1e6, f"B={b};K={k};R={r};{b/dt/1e6:.1f}M entries/s")

    t, h = 10, 16
    x = jnp.asarray(rng.normal(size=(b, t, h)), jnp.float32)
    wi = jnp.asarray(rng.normal(size=(h, 4 * h)) * 0.3, jnp.float32)
    wh = jnp.asarray(rng.normal(size=(h, 4 * h)) * 0.3, jnp.float32)
    bb = jnp.zeros((4 * h,), jnp.float32)
    fn = jax.jit(lambda *a: ops.lstm_scan(*a, impl="ref"))
    dt = _time(fn, x, wi, wh, bb)
    emit("kernel_lstm_ref", dt * 1e6, f"B={b};T={t};H={h};{b/dt/1e6:.1f}M seq/s")

    bq, s, hq, hkv, d = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(bq, s, hq, d)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(bq, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bq, s, hkv, d)), jnp.float32)
    fn = jax.jit(lambda *a: ops.attention(*a, impl="ref"))
    dt = _time(fn, q, kk, v, reps=3)
    flops = 4 * bq * hq * s * s * d
    emit("kernel_attention_ref", dt * 1e6, f"S={s};GQA{hq}/{hkv};{flops/dt/1e9:.1f}GFLOP/s")

    rec = decode_tile_roofline(smoke=smoke)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR, "BENCH_kernels.json")
    with open(out, "w") as f:
        json.dump(
            {"mode": "smoke" if smoke else "default", "runs": [rec]}, f, indent=2
        )
    emit("kernels_json", 0.0, out)


if __name__ == "__main__":
    import sys

    run(smoke="--smoke" in sys.argv)
