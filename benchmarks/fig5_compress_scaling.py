"""Fig. 5: compression time scales linearly with the number of entries.

Measures the three phases the paper times (order init, one model-update
epoch, one order-update sweep) on synthetic full tensors of growing size,
then reports the log-log slope (1.0 = linear)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FULL, emit, save_rows
from repro.core import codec, nttd, reorder
from repro.core.folding import make_folding_spec
from repro.optim import optimizers

SIZES = [(16, 16, 16), (24, 24, 24), (32, 32, 32), (48, 48, 48)]
if FULL:
    SIZES += [(64, 64, 64), (96, 96, 96)]


def run() -> None:
    rows = []
    times = []
    import jax
    import jax.numpy as jnp

    for shape in SIZES:
        rng = np.random.default_rng(0)
        x = rng.random(shape).astype(np.float32)
        spec = make_folding_spec(shape)
        cfg = nttd.NTTDConfig(rank=8, hidden=8)

        t0 = time.time()
        pi = reorder.tsp_init(x)
        t_init = time.time() - t0

        params = nttd.init_params(jax.random.PRNGKey(0), spec, cfg)
        opt = optimizers.adam(1e-2)
        ost = opt.init(params)
        epoch_fn = codec._make_train_epoch(spec, cfg, opt)
        n = x.size
        bsz = 4096
        steps = max(n // bsz, 1)
        flat = rng.permutation(n)[: steps * bsz]
        pos = nttd.flat_to_multi(flat, shape)
        vals = x[tuple(pi[j][pos[:, j]] for j in range(3))]
        args = (
            jnp.asarray(pos.reshape(steps, bsz, 3), jnp.int32),
            jnp.asarray(vals.reshape(steps, bsz)),
        )
        jax.block_until_ready(epoch_fn(params, ost, *args))  # compile
        t0 = time.time()
        params, ost, loss = epoch_fn(params, ost, *args)
        jax.block_until_ready(loss)
        t_epoch = time.time() - t0

        t0 = time.time()
        reorder.update_orders(x, params, pi, spec, cfg, rng, 512)
        t_order = time.time() - t0

        total = t_init + t_epoch + t_order
        times.append((n, t_epoch, total))
        rows.append([n, round(t_init, 3), round(t_epoch, 3), round(t_order, 3)])
        emit(f"fig5_n{n}", total * 1e6,
             f"init={t_init:.3f}s;epoch={t_epoch:.3f}s;order={t_order:.3f}s")

    ns = np.log([t[0] for t in times])
    # the model-update epoch dominates at production scale (the codec
    # dry-run cell); the order phases scale with sum(N_k), not entries
    ep = float(np.polyfit(ns, np.log([t[1] for t in times]), 1)[0])
    tot = float(np.polyfit(ns, np.log([t[2] for t in times]), 1)[0])
    emit("fig5_loglog_slope", 0.0,
         f"epoch_slope={ep:.3f};total_slope={tot:.3f};linear_if~1")
    save_rows("fig5_compress_scaling.csv", ["entries", "t_init", "t_epoch", "t_order"], rows)


if __name__ == "__main__":
    run()
