"""Fig. 5: compression time scales linearly with the number of entries.

Two modes:

* default — every codec in the ``repro.codecs`` registry is fit on
  synthetic full tensors of growing size under one budget protocol, and
  the per-codec log-log slope of wall time vs entries is reported
  (1.0 = linear, the paper's claim for TensorCodec).
* ``--stream`` — the headline scalability claim measured the honest way:
  ``fit_stream("nttd", ...)`` over a seeded ``SyntheticTensorSource``
  that computes slabs from indices, so the tensor is NEVER materialized.
  Entries/sec lands in ``results/BENCH_stream.json`` so CI tracks the
  streaming-throughput trajectory (``--smoke`` shrinks it to a CI-sized
  cell; REPRO_BENCH_FULL=1 grows it to 2^26 entries).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks.common import (
    FULL,
    NTTD_FIT_OPTS,
    RESULTS_DIR,
    emit,
    save_rows,
    scaling_budget,
)
from repro.codecs import available, get_codec

SIZES = [(16, 16, 16), (24, 24, 24), (32, 32, 32), (48, 48, 48)]
if FULL:
    SIZES += [(64, 64, 64), (96, 96, 96)]

NTTD_OPTS = {**NTTD_FIT_OPTS, "init_reorder": True}


def _nttd_epoch_seconds(codec, x) -> float:
    """Compile-excluded per-epoch seconds: fit at epochs=1 and epochs=5
    and difference.  The epoch count is a Python loop, not a traced shape,
    so jit compile, TSP init, and backend warm-up cancel exactly and what
    remains is the model-update + eval work the linear claim is about."""
    t0 = time.time()
    codec.fit(x, **{**NTTD_OPTS, "epochs": 1, "patience": 10})
    t1 = time.time() - t0
    t0 = time.time()
    codec.fit(x, **{**NTTD_OPTS, "epochs": 5, "patience": 10})
    t5 = time.time() - t0
    return max((t5 - t1) / 4, 1e-9)


def run() -> None:
    rows = []
    per_codec: dict[str, list[tuple[int, float]]] = {}
    for shape in SIZES:
        rng = np.random.default_rng(0)
        x = rng.random(shape).astype(np.float32)
        n = x.size
        budget = scaling_budget(n)
        for name in available():
            codec = get_codec(name)
            try:
                if name == "nttd":  # cold wall time is compile-dominated
                    dt = _nttd_epoch_seconds(codec, x)
                else:
                    t0 = time.time()
                    codec.fit(x, budget)
                    dt = time.time() - t0
            except ValueError as e:  # e.g. szlite floor above budget
                emit(f"fig5_{name}_n{n}", 0.0, f"skipped:{e}")
                continue
            if dt <= 1e-9:  # below timer resolution: would poison the slope
                emit(f"fig5_{name}_n{n}", 0.0, "skipped:below-timer-resolution")
                continue
            per_codec.setdefault(name, []).append((n, dt))
            rows.append([name, n, round(dt, 4)])
            emit(f"fig5_{name}_n{n}", dt * 1e6, f"seconds={dt:.3f}")
    for name, pts in per_codec.items():
        if len(pts) < 2:
            continue
        ns = np.log([p[0] for p in pts])
        ts = np.log([max(p[1], 1e-9) for p in pts])
        slope = float(np.polyfit(ns, ts, 1)[0])
        emit(f"fig5_{name}_loglog_slope", 0.0,
             f"slope={slope:.3f};linear_if~1")
    save_rows("fig5_compress_scaling.csv", ["codec", "entries", "seconds"], rows)


# ---------------------------------------------------------------------------
# streaming mode: the linear-time claim without materializing the tensor
# ---------------------------------------------------------------------------
def run_stream(smoke: bool = False) -> None:
    from repro.serve.codec_service import CodecService
    from repro.stream import SyntheticTensorSource, fit_stream, write_chunked

    if smoke:
        shapes = [(64, 32, 32)]                 # 2^16 entries, CI-sized
        slab_entries = 1 << 13
    else:
        shapes = [(256, 64, 64), (1024, 64, 64), (4096, 64, 64)]  # up to 2^24
        if FULL:
            shapes.append((16384, 64, 64))      # 2^26
        slab_entries = 1 << 18
    records = []
    for shape in shapes:
        src = SyntheticTensorSource(shape, slab_entries=slab_entries, seed=1)
        t0 = time.time()
        enc = fit_stream("nttd", src, rank=6, hidden=12, steps_per_slab=2,
                         batch_size=4096 if smoke else 8192, seed=0)
        dt = time.time() - t0
        eps = src.n_entries / dt
        # round-trip the payload through the chunked container + lazy serve
        path = os.path.join(RESULTS_DIR, "fig5_stream_payload.tcdc")
        os.makedirs(RESULTS_DIR, exist_ok=True)
        # small chunks so the checked-in payload has a multi-chunk index
        # (with entry ranges) for the fleet smoke to shard over
        write_chunked(path, enc, chunk_bytes=2048)
        svc = CodecService()
        svc.load_stream("stream", path)
        rng = np.random.default_rng(0)
        idx = np.stack([rng.integers(0, s, 128) for s in shape], axis=1)
        served = svc.decode_at("stream", idx)
        direct = np.asarray(enc.decode_at(idx))
        assert np.array_equal(served, direct), "load_stream round-trip drifted"
        records.append({
            "shape": list(shape),
            "entries": src.n_entries,
            "slab_entries": slab_entries,
            "n_slabs": src.n_slabs,
            "seconds": round(dt, 3),
            "entries_per_sec": round(eps, 1),
            "payload_bytes": enc.payload_bytes(),
        })
        emit(f"fig5_stream_n{src.n_entries}", dt * 1e6,
             f"entries_per_sec={eps:.0f};slabs={src.n_slabs}")
    if len(records) >= 2:
        # the smallest run pays the one-time jit compile; drop it from the
        # slope fit when there are enough points so the asymptote shows
        pts = records[1:] if len(records) >= 3 else records
        ns = np.log([r["entries"] for r in pts])
        ts = np.log([r["seconds"] for r in pts])
        slope = float(np.polyfit(ns, ts, 1)[0])
        emit("fig5_stream_loglog_slope", 0.0, f"slope={slope:.3f};linear_if~1")
    else:
        slope = None
    out = os.path.join(RESULTS_DIR, "BENCH_stream.json")
    with open(out, "w") as f:
        json.dump({"mode": "smoke" if smoke else ("full" if FULL else "default"),
                   "loglog_slope": slope, "runs": records}, f, indent=2)
    emit("fig5_stream_json", 0.0, out)


if __name__ == "__main__":
    if "--stream" in sys.argv:
        run_stream(smoke="--smoke" in sys.argv)
    else:
        run()
