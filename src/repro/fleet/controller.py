"""Metrics-driven elastic scaling: the loop ROADMAP item 1 promised.

The PR 8 metrics registry serves the signal; this module closes the loop.
A :class:`ScalingPolicy` is PURE decision logic over flat metric samples
(the ``repro.obs.slo.fleet_slo_sample`` key space) — an
:class:`~repro.obs.slo.SLOEngine` holds the latency objective (and an
optional per-payload canary-fitness objective), and the policy layers the
scaling-specific state on top:

- **scale up** when the p99 latency breach is SUSTAINED (``breach_evals``
  consecutive evaluations over target) and traffic is live;
- **scale down** when the fleet is idle (fewer than
  ``idle_flushes_per_eval`` new flushes per evaluation, ``idle_evals``
  times in a row) and above ``min_instances``;
- **hold** otherwise — including a ``cooldown_evals``-long cooldown after
  every action (the flap guard: a noisy signal can never oscillate
  add/remove faster than one action per cooldown), and whenever the
  latency sample is STALE (zero new flushes since the last evaluation
  repeat the same window percentile forever, so the policy blanks the
  latency key rather than let a frozen breach pin the engine — which is
  also what lets an idle fleet scale down while a breach is nominally
  open).

Being pure, the policy is testable over recorded fixtures — no sleeps,
no sockets (``tests/test_controller.py``).

:class:`FleetController` binds a policy to a live
:class:`~repro.fleet.frontend.FleetFrontend`: each ``step()`` polls
``collect()``, asks the policy, and applies the decision through the
existing :func:`~repro.fleet.rebalance.rebalance` — the drain barrier and
warm tile handoff are what make both directions zero-downtime.  Every
decision is emitted as a span (``controller.step`` /
``controller.scale_up`` / ``controller.scale_down``) and an
``obs.emit_event`` record, so drills show up in ``obs.report`` output.

    ctl = FleetController(fleet, ControllerConfig(p99_target_ms=5.0))
    ctl.run(steps=30, interval_s=1.0)     # or ctl.step() in your own loop
"""
from __future__ import annotations

import dataclasses
import itertools
import time

from repro import obs
from repro.fleet.frontend import FleetFrontend
from repro.fleet.metrics import collect
from repro.fleet.rebalance import rebalance
from repro.obs.slo import SLOEngine, SLOEvent, SLOSpec, fleet_slo_sample


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    #: fleet decode_p99_ms objective (window-exact, pooled across members)
    p99_target_ms: float
    #: hysteresis clear threshold; default 0.8 x target
    p99_clear_ms: float | None = None
    breach_evals: int = 3
    clear_evals: int = 2
    #: traffic floor: fewer NEW flushes than this per evaluation = idle
    idle_flushes_per_eval: float = 1.0
    idle_evals: int = 5
    #: evaluations to hold after any action (flap guard)
    cooldown_evals: int = 3
    min_instances: int = 1
    max_instances: int = 8
    #: optional per-payload canary-fitness objective (breaches are
    #: surfaced as events; quality is a repair trigger, not a scale axis)
    min_fitness: float | None = None

    def __post_init__(self):
        if self.p99_target_ms <= 0:
            raise ValueError(f"p99_target_ms must be > 0, got {self.p99_target_ms}")
        if not 1 <= self.min_instances <= self.max_instances:
            raise ValueError(
                f"need 1 <= min_instances <= max_instances, got "
                f"[{self.min_instances}, {self.max_instances}]"
            )

    @property
    def clear_ms(self) -> float:
        return (
            self.p99_clear_ms
            if self.p99_clear_ms is not None
            else 0.8 * self.p99_target_ms
        )


@dataclasses.dataclass(frozen=True)
class Decision:
    action: str  # "scale_up" | "scale_down" | "hold"
    reason: str
    #: SLO edge events from this evaluation (breach_start / breach_end)
    events: tuple[SLOEvent, ...] = ()


class ScalingPolicy:
    """Pure scaling decisions over metric samples; see module docstring."""

    def __init__(self, config: ControllerConfig):
        self.config = config
        specs = [
            SLOSpec(
                "latency", "decode_p99_ms",
                target=config.p99_target_ms, clear=config.clear_ms,
                breach_for=config.breach_evals, clear_for=config.clear_evals,
            ),
        ]
        if config.min_fitness is not None:
            specs.append(SLOSpec(
                "quality", "canary_fitness.*",
                target=config.min_fitness, op=">=",
                breach_for=config.breach_evals, clear_for=config.clear_evals,
            ))
        self.engine = SLOEngine(specs)
        self._last_flushes: int | None = None
        self._idle_streak = 0
        self._cooldown = 0

    def observe(self, sample: dict, now: float = 0.0) -> Decision:
        """Feed one metric sample; returns the decision for this tick."""
        cfg = self.config
        n = int(sample.get("instances") or 0)
        flushes = int(sample.get("flushes_total") or 0)
        first = self._last_flushes is None
        delta = 0 if first else max(flushes - self._last_flushes, 0)
        self._last_flushes = flushes
        idle = not first and delta < cfg.idle_flushes_per_eval
        if idle:
            self._idle_streak += 1
            # zero new flushes = the latency window is STALE; blank it so
            # a frozen percentile can neither open nor sustain a breach
            sample = dict(sample, decode_p99_ms=None)
        else:
            self._idle_streak = 0
        events = tuple(self.engine.evaluate(sample, now))
        if self._cooldown > 0:
            self._cooldown -= 1
            return Decision("hold", f"cooldown ({self._cooldown + 1} left)", events)
        if self.engine.is_breached("latency") and not idle:
            if n >= cfg.max_instances:
                return Decision(
                    "hold",
                    f"p99 breach but at max_instances={cfg.max_instances}",
                    events,
                )
            self._cooldown = cfg.cooldown_evals
            self._idle_streak = 0
            return Decision(
                "scale_up",
                f"decode_p99_ms over {cfg.p99_target_ms}ms for "
                f">={cfg.breach_evals} evals",
                events,
            )
        if self._idle_streak >= cfg.idle_evals:
            if n <= cfg.min_instances:
                return Decision(
                    "hold",
                    f"idle but at min_instances={cfg.min_instances}",
                    events,
                )
            self._cooldown = cfg.cooldown_evals
            self._idle_streak = 0
            return Decision(
                "scale_down",
                f"<{cfg.idle_flushes_per_eval} flushes/eval for "
                f">={cfg.idle_evals} evals",
                events,
            )
        return Decision("hold", "within slo", events)


class FleetController:
    """Bind a :class:`ScalingPolicy` to a live fleet.  ``step()`` =
    poll ``collect()`` -> decide -> apply via ``rebalance``."""

    def __init__(
        self,
        fleet: FleetFrontend,
        config: ControllerConfig,
        *,
        standby_prefix: str = "s",
    ):
        self.fleet = fleet
        self.config = config
        self.policy = ScalingPolicy(config)
        self.standby_prefix = standby_prefix
        #: instances THIS controller admitted, newest last — preferred
        #: scale-down victims (LIFO), after dead members
        self.admitted: list[str] = []
        self.decisions: list[Decision] = []

    def sample(self) -> dict:
        return fleet_slo_sample(collect(self.fleet))

    def _next_standby(self) -> str:
        for k in itertools.count():
            iid = f"{self.standby_prefix}{k}"
            if iid not in self.fleet.transports:
                return iid
        raise AssertionError("unreachable")

    def _victim(self) -> str:
        # a dead member is always the best thing to retire
        for iid in sorted(self.fleet.excluded):
            if iid in self.fleet.transports:
                return iid
        for iid in reversed(self.admitted):
            if iid in self.fleet.transports:
                return iid
        return sorted(self.fleet.transports)[-1]

    def step(self, sample: dict | None = None) -> Decision:
        """One control tick; returns (and records) the decision made."""
        with obs.span("controller.step"):
            if sample is None:
                sample = self.sample()
            decision = self.policy.observe(sample, now=time.monotonic())
            if decision.action == "scale_up":
                iid = self._next_standby()
                with obs.span("controller.scale_up", instance=iid):
                    rebalance(self.fleet, add=[iid])
                self.admitted.append(iid)
            elif decision.action == "scale_down":
                iid = self._victim()
                with obs.span("controller.scale_down", instance=iid):
                    rebalance(self.fleet, remove=[iid])
                if iid in self.admitted:
                    self.admitted.remove(iid)
            else:
                iid = None
            for ev in decision.events:
                fields = ev.as_dict()
                obs.emit_event(f"slo_{fields.pop('kind')}", **fields)
            obs.emit_event(
                "controller_decision",
                action=decision.action,
                reason=decision.reason,
                instance=iid,
                instances=len(self.fleet.transports),
            )
        self.decisions.append(decision)
        return decision

    def run(self, steps: int, interval_s: float = 0.0) -> list[Decision]:
        """Run ``steps`` ticks (sleeping ``interval_s`` between them);
        returns their decisions."""
        out = []
        for k in range(steps):
            out.append(self.step())
            if interval_s and k + 1 < steps:
                time.sleep(interval_s)
        return out
