"""Replica-aware read repair: detect -> refit/restore -> swap, zero downtime.

The serving stack already CONTAINS damage — a chunk whose CRC fails is
quarantined on the instance that read it (``ChunkCorruptError``) and the
frontend fails the sub-batch over to surviving replicas; a fitness
canary that dips below its SLO records a ``last_breach``.  This module
closes the loop: a :class:`RepairController` polls every member's
``stats()`` for those two signals and REPAIRS the payload file while the
fleet keeps serving it.

Two repair kinds, both swap through an epoch switch (drain barrier ->
file mutation -> ``fleet.refresh``), so answers for untouched entry
ranges stay bit-identical before, during, and after:

* **corruption** — a quarantined chunk is restored byte-exactly from a
  donor replica: ``export_chunk`` re-serializes the donor's materialized
  payload, CRC-verifies the slice against the footer, and
  ``rewrite_chunks`` writes it back in place (same length -> the footer
  is untouched, the repaired file is byte-identical to the original).
* **quality** — the breached entry range is re-compressed ONLINE: the
  range is densified from the payload's own served decode (the degraded
  model is still the best available estimate everywhere we lack truth),
  the container's held-out ground-truth entries (``TCDQ``) overwrite
  their positions, and an NTTD stream fitter warm-fits the range —
  optionally refining TSP mode orders mid-stream from its reservoir
  sample.  The refit is gated on the held-out sample (repaired fitness
  must be >= the degraded fitness) and lands as a ``TCDP`` patch overlay
  (``append_patch``), which REPLACES decode only inside the range —
  untouched entries keep decoding from the byte-identical base chunks.

For v4 delta files a chunk restore re-validates every dependent version
chain (``repro.temporal.revalidate_chains``) before the repair is
declared complete — repairing a keyframe must not leave a residual
decoding against bytes its fitter never saw.

    ctl = RepairController(fleet)
    tickets = ctl.poll()          # corruption + quality findings
    reports = ctl.run()           # poll + repair everything found

Observability: spans ``repair.corruption`` / ``repair.quality``, events
``repair_started`` / ``repair_completed`` / ``repair_failed`` (joining
``chunk_quarantined``, ``decode_failover``, ``quality_breach`` and
``payload_refreshed`` from the detection side), and fleet metrics
``repairs_total`` / ``repair_seconds`` / ``repair_refit_entries_per_sec``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.codecs import container
from repro.codecs.base import get_codec
from repro.codecs.indexing import flat_to_multi
from repro.fleet.frontend import FleetFrontend
from repro.fleet.transport import TransportError
from repro.stream.writer import append_patch, rewrite_chunks
from repro.temporal.store import _fitness, revalidate_chains


@dataclasses.dataclass
class RepairConfig:
    """Knobs for the online re-compression (quality) path."""

    #: codec refitted over the breached range (must support stream_fitter)
    codec: str = "nttd"
    #: stream_fitter options — defaults sized to INTERPOLATE a breached
    #: chunk range (the target carries exact truth at the held-out
    #: positions, so driving train error to ~0 is what clears the SLO)
    refit_opts: dict = dataclasses.field(
        default_factory=lambda: {
            "rank": 12, "steps_per_slab": 32, "batch_size": 512, "lr": 1e-2,
        }
    )
    #: entries per slab fed to the fitter
    slab_entries: int = 1 << 14
    #: passes over the densified range (SGD needs revisits to converge)
    passes: int = 10
    #: refine TSP mode orders mid-stream (after ``reorder_after`` passes)
    reorder: bool = False
    reorder_after: int = 1
    #: fitness gate: held-out fitness of the refit must be at least the
    #: degraded payload's held-out fitness plus this margin
    min_fitness_gain: float = 0.0
    #: chunking of the appended patch body
    chunk_bytes: int = 1 << 20
    #: refuse to densify a breached range larger than this
    max_patch_entries: int = 1 << 22


@dataclasses.dataclass(frozen=True)
class RepairTicket:
    """One repairable finding from :meth:`RepairController.poll`."""

    payload: str
    kind: str  # "corruption" | "quality"
    instance: str
    chunk: int | None
    entry_start: int | None
    entry_stop: int | None
    detail: str

    @property
    def key(self) -> tuple:
        """Dedup key: the same damage seen from N replicas is one repair."""
        return (self.payload, self.kind, self.chunk,
                self.entry_start, self.entry_stop)


@dataclasses.dataclass
class RepairReport:
    payload: str
    kind: str
    ok: bool = False
    #: chunk ids restored byte-exactly (corruption path)
    chunks_restored: list[int] = dataclasses.field(default_factory=list)
    #: chunk id -> donor instance that vouched for the bytes
    donors: dict[int, str] = dataclasses.field(default_factory=dict)
    entry_start: int | None = None
    entry_stop: int | None = None
    #: held-out fitness before/after the refit (quality path)
    fitness_before: float | None = None
    fitness_after: float | None = None
    refit_entries: int = 0
    elapsed_s: float = 0.0
    refit_entries_per_sec: float | None = None
    #: v4 only: dependent version chains re-validated after the restore
    chains_revalidated: int = 0
    error: str | None = None


class RepairController:
    """Polls fleet members for damage and repairs payload files in place
    while surviving replicas keep serving (see module docstring)."""

    def __init__(self, fleet: FleetFrontend, config: RepairConfig | None = None):
        self.fleet = fleet
        self.config = config or RepairConfig()
        self.reports: list[RepairReport] = []

    # ------------------------------------------------------------- detection
    def poll(self) -> list[RepairTicket]:
        """One stats sweep over live members: quarantined chunks become
        corruption tickets, canary ``last_breach`` records become quality
        tickets.  Deduplicated — R replicas reporting the same damage is
        one repair."""
        tickets: list[RepairTicket] = []
        seen: set[tuple] = set()
        for iid, t in self.fleet.transports.items():
            if iid in self.fleet.excluded:
                continue
            try:
                st = t.stats()
            except TransportError as e:
                self.fleet.exclude(iid, e)
                continue
            for name, chunks in (st.get("quarantine") or {}).items():
                for cid, err in chunks.items():
                    cid = int(cid)  # JSON transports stringify dict keys
                    lo, hi = self._chunk_entry_range(name, cid)
                    tk = RepairTicket(name, "corruption", iid, cid, lo, hi, str(err))
                    if tk.key not in seen:
                        seen.add(tk.key)
                        tickets.append(tk)
            for name, cst in (st.get("canary") or {}).items():
                lb = cst.get("last_breach")
                if not lb or lb.get("entry_start") is None:
                    continue
                tk = RepairTicket(
                    name, "quality", iid,
                    lb.get("chunk"),
                    int(lb["entry_start"]), int(lb["entry_stop"]),
                    f"canary fitness {lb['fitness']:.6f} < {lb['threshold']}",
                )
                if tk.key not in seen:
                    seen.add(tk.key)
                    tickets.append(tk)
        return tickets

    def run(self) -> list[RepairReport]:
        """Poll once and repair every finding; corruption first (a refit
        should not train on values decoded through a corrupt chunk)."""
        tickets = sorted(self.poll(), key=lambda t: t.kind != "corruption")
        return [self.repair(t) for t in tickets]

    def repair(self, ticket: RepairTicket) -> RepairReport:
        if ticket.kind == "corruption":
            report = self.repair_corruption(ticket.payload, ticket.chunk)
        elif ticket.kind == "quality":
            report = self.repair_quality(
                ticket.payload, ticket.entry_start, ticket.entry_stop
            )
        else:
            raise ValueError(f"unknown repair kind {ticket.kind!r}")
        return report

    # ------------------------------------------------------------ corruption
    def repair_corruption(self, name: str, chunk: int) -> RepairReport:
        """Restore one chunk byte-exactly from a donor replica and swap
        the repaired file in through an epoch switch."""
        t0 = time.perf_counter()
        path, _tile_entries = self.fleet.path_of(name)
        report = RepairReport(name, "corruption")
        obs.emit_event(
            "repair_started", payload=name, repair_kind="corruption",
            chunk=int(chunk), path=path,
        )
        with obs.span("repair.corruption", payload=name, chunk=int(chunk)):
            raw, donor = self._export_from_donor(name, chunk)
            if raw is None:
                return self._fail(
                    report, t0,
                    f"chunk {chunk}: no live replica could vouch for the bytes",
                )
            # epoch switch: resolve in-flight tickets under the old epoch,
            # rewrite in place (same length -> footer byte-identical),
            # then fan the re-open to every member
            self.fleet.drain()
            try:
                rewrite_chunks(path, {int(chunk): raw})
            except (OSError, ValueError) as e:
                return self._fail(report, t0, f"rewrite failed: {e}")
            self.fleet.refresh(name)
            report.chunks_restored = [int(chunk)]
            report.donors = {int(chunk): donor}
            route = self.fleet.routes.get(name)
            if route is not None and route.versioned:
                health = revalidate_chains(path)
                report.chains_revalidated = len(health)
                bad = [h for h in health if not h.ok]
                if bad:
                    return self._fail(
                        report, t0,
                        f"post-restore chain validation failed: {bad[0].error}",
                    )
        return self._complete(report, t0)

    def _export_from_donor(self, name: str, chunk: int) -> tuple[bytes | None, str]:
        """First live member that can CRC-vouch for the chunk's bytes wins
        (export_chunk returns None when an instance cannot: quarantined
        there too, unowned, or its re-serialization fails the footer CRC)."""
        for iid, t in self.fleet.transports.items():
            if iid in self.fleet.excluded:
                continue
            try:
                raw = t.export_chunk(name, int(chunk))
            except TransportError as e:
                self.fleet.exclude(iid, e)
                continue
            if raw is not None:
                return raw, iid
        return None, ""

    # --------------------------------------------------------------- quality
    def repair_quality(self, name: str, entry_start: int, entry_stop: int) -> RepairReport:
        """Re-compress the breached flat-entry range online and land it as
        a patch overlay; see the module docstring for the data flow."""
        t0 = time.perf_counter()
        cfg = self.config
        path, _tile_entries = self.fleet.path_of(name)
        route = self.fleet.routes[name]
        lo, hi = int(entry_start), int(entry_stop)
        n_entries = int(np.prod(route.shape))
        hi = min(hi, n_entries)
        report = RepairReport(name, "quality", ok=False, entry_start=lo, entry_stop=hi)
        if route.versioned:
            return self._fail(
                report, t0, "quality repair of versioned payloads not supported"
            )
        if not 0 <= lo < hi:
            return self._fail(report, t0, f"bad entry range [{lo}, {hi})")
        if hi - lo > cfg.max_patch_entries:
            return self._fail(
                report, t0,
                f"range of {hi - lo} entries exceeds max_patch_entries="
                f"{cfg.max_patch_entries}",
            )
        obs.emit_event(
            "repair_started", payload=name, repair_kind="quality",
            entry_start=lo, entry_stop=hi, path=path,
        )
        with obs.span("repair.quality", payload=name, entries=hi - lo):
            # 1. densify the range from the payload's own served decode —
            # the fleet keeps serving; this is just a (big) query
            idx = flat_to_multi(np.arange(lo, hi, dtype=np.int64), route.shape)
            try:
                target = np.asarray(
                    self.fleet.decode_at(name, idx), dtype=np.float64
                ).copy()
            except (KeyError, ValueError, TransportError) as e:
                return self._fail(report, t0, f"densify failed: {e}")

            # 2. overlay held-out ground truth (TCDQ) inside the range,
            # and measure the degraded payload's fitness on that sample
            h_idx, h_vals = self._heldout_in_range(path, lo, hi)
            if len(h_idx):
                report.fitness_before = _fitness(h_vals, target[h_idx - lo])
                target[h_idx - lo] = h_vals

            # 3. warm refit: NTTD stream fitter over the densified range
            sub_shape = _range_shape(hi - lo)
            enc, entries_seen = self._refit(target.reshape(sub_shape))
            report.refit_entries = entries_seen

            # 4. fitness gate on the held-out sample
            if len(h_idx):
                local = flat_to_multi(h_idx - lo, sub_shape)
                report.fitness_after = _fitness(
                    h_vals, np.asarray(enc.decode_at(local), dtype=np.float64)
                )
                if report.fitness_after < report.fitness_before + cfg.min_fitness_gain:
                    return self._fail(
                        report, t0,
                        f"refit fitness {report.fitness_after:.6f} did not beat "
                        f"degraded fitness {report.fitness_before:.6f} "
                        f"(min_fitness_gain={cfg.min_fitness_gain})",
                    )

            # 5. epoch switch: append the patch overlay, fan the re-open
            self.fleet.drain()
            try:
                append_patch(
                    path, enc.to_bytes(), (lo, hi), cfg.codec,
                    chunk_bytes=cfg.chunk_bytes,
                )
            except (OSError, ValueError) as e:
                return self._fail(report, t0, f"append_patch failed: {e}")
            self.fleet.refresh(name)
        return self._complete(report, t0)

    def _heldout_in_range(self, path: str, lo: int, hi: int):
        """(flat indices, float64 truth) of the container's held-out
        sample falling inside [lo, hi) — empty arrays when the file was
        written without a TCDQ block."""
        oc = container.open_container(path)
        try:
            if oc.heldout is None or not len(oc.heldout):
                return np.empty(0, np.int64), np.empty(0, np.float64)
            sel = (oc.heldout.indices >= lo) & (oc.heldout.indices < hi)
            return oc.heldout.indices[sel].copy(), oc.heldout.values[sel].copy()
        finally:
            oc.close()

    def _refit(self, sub: np.ndarray):
        """Drive the codec's stream fitter over the densified range for
        ``passes`` epochs, optionally refining TSP mode orders mid-stream
        from the fitter's reservoir sample."""
        cfg = self.config
        fitter = get_codec(cfg.codec).stream_fitter(sub.shape, None, **cfg.refit_opts)
        flat = sub.astype(np.float32).ravel()
        n = len(flat)
        for p in range(max(cfg.passes, 1)):
            for s in range(0, n, cfg.slab_entries):
                stop = min(s + cfg.slab_entries, n)
                fitter.update(
                    flat_to_multi(np.arange(s, stop, dtype=np.int64), sub.shape),
                    flat[s:stop],
                )
            if (
                cfg.reorder
                and p + 1 == cfg.reorder_after
                and hasattr(fitter, "refine_orders")
            ):
                fitter.refine_orders()
        return fitter.finalize(), int(getattr(fitter, "entries_seen", 0))

    # ------------------------------------------------------------- reporting
    def _complete(self, report: RepairReport, t0: float) -> RepairReport:
        report.ok = True
        report.elapsed_s = time.perf_counter() - t0
        if report.refit_entries and report.elapsed_s > 0:
            report.refit_entries_per_sec = report.refit_entries / report.elapsed_s
        self._record(report, "repair_completed")
        return report

    def _fail(self, report: RepairReport, t0: float, error: str) -> RepairReport:
        report.ok = False
        report.error = error
        report.elapsed_s = time.perf_counter() - t0
        self._record(report, "repair_failed")
        return report

    def _record(self, report: RepairReport, event: str) -> None:
        self.reports.append(report)
        m = self.fleet.metrics
        m.counter(
            "repairs_total", payload=report.payload, kind=report.kind,
            outcome="ok" if report.ok else "failed",
        ).inc()
        m.histogram("repair_seconds", kind=report.kind).observe(report.elapsed_s)
        if report.refit_entries_per_sec is not None:
            m.gauge("repair_refit_entries_per_sec", payload=report.payload).set(
                report.refit_entries_per_sec
            )
        obs.emit_event(
            event,
            payload=report.payload,
            repair_kind=report.kind,
            chunks_restored=list(report.chunks_restored),
            entry_start=report.entry_start,
            entry_stop=report.entry_stop,
            fitness_before=report.fitness_before,
            fitness_after=report.fitness_after,
            time_to_repair_s=report.elapsed_s,
            refit_entries_per_sec=report.refit_entries_per_sec,
            error=report.error,
        )

    # ---------------------------------------------------------------- lookup
    def _chunk_entry_range(self, name: str, chunk: int):
        """Flat-entry range the footer records for a chunk (None, None when
        unrecorded — monolithic v3 files, version component chunks)."""
        try:
            path, _ = self.fleet.path_of(name)
            _codec, chunks, _versions = container.container_index(path)
        except (KeyError, OSError, ValueError):
            return None, None
        if not 0 <= chunk < len(chunks):
            return None, None
        c = chunks[chunk]
        return c.entry_start, c.entry_stop


def _range_shape(n: int) -> tuple[int, ...]:
    """Factor an entry count into <= 3 roughly balanced modes — the refit
    tensor's shape.  A low-TT-rank structure in the flat range survives
    any row-major reshape of the same flat order; balance keeps the NTTD
    folding well-conditioned.  Falls back to fewer modes (worst case 1-D,
    n prime) when n has no nearby divisors."""
    if n <= 1:
        return (max(n, 1),)
    a = _nearest_divisor(n, round(n ** (1 / 3)))
    m = n // a
    b = _nearest_divisor(m, round(m ** 0.5))
    dims = tuple(sorted((a, b, m // b), reverse=True))
    return tuple(d for d in dims if d > 1) or (n,)


def _nearest_divisor(n: int, target: int) -> int:
    target = max(min(int(target), n), 1)
    for delta in range(n):
        for cand in (target - delta, target + delta):
            if 1 <= cand <= n and n % cand == 0:
                return cand
    return 1
