"""The fleet query frontend: N fleet members behind one service.

Each member sits behind a :class:`~repro.fleet.transport.Transport` —
in-process (``LocalTransport`` wrapping a ``CodecService``) or a
separate OS process (``SocketTransport`` to a ``repro.fleet.worker``).
The frontend depends only on the protocol, so batch split/reassembly,
the in-flight byte budget, the drain barrier, and warm tile handoff
behave identically across both; every instance mmaps the same
container-v3 file and — via the
:class:`~repro.serve.codec_service.Ownership` filter the router
installs — materializes and caches only its shard of chunks and decode
tiles.  A ``decode_at`` batch is split by owner, fanned out through each
instance's submit/flush coalescing path (pipelined frames on a socket
transport), and reassembled in request order, so a fleet answer is
bit-identical to a single resident instance's.

Admission control: ``max_inflight_bytes`` bounds the bytes (decoded
output + index payload) queued on any one instance during a flush.  When
a wave of sub-batches would exceed it, the instance is flushed NOW
(backpressure) instead of queueing without bound —
``backpressure_flushes`` counts how often that happened.

Replication: with ``replication=R`` each chunk/tile key has R owners on
the ring; the frontend sends each group to whichever replica has the
least bytes planned this flush, so hot chunks spread across their
replica set.

Failure containment: a dead transport (worker killed, request timeout,
framing violation) raises ``TransportError`` exactly once — the frontend
fails that flush's affected tickets cleanly, adds the instance to
``excluded``, and routes every later query to surviving replicas.  A
group whose replicas are ALL excluded fails its ticket with a clear
error instead of hanging.  ``rebalance`` removes excluded members for
real (ring change + retirement).

Read repair's serving half: a sub-batch that FAILS on one instance
(e.g. a quarantined corrupt chunk raising ``ChunkCorruptError`` on the
worker, or the transport dying mid-flush) is retried on the group's
surviving replicas before the ticket is failed — with ``replication=R>1``
a single corrupt replica costs zero failed tickets, and each failover is
recorded as a ``decode_failover`` event.  ``refresh(name)`` fans the
post-repair epoch switch (re-open the container file, clear quarantine)
to every live member.

    fleet = FleetFrontend(4, cache_bytes=1 << 24, replication=1)
    fleet.load_stream("embed", "embed.tcdc", tile_entries=4096)
    fleet.decode_at("embed", idx)        # == single instance, bit-exact

    # one worker process per member instead:
    fleet = FleetFrontend(
        ["w0", "w1"],
        transport_factory=lambda iid: SocketTransport.spawn(iid),
    )
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro import obs
from repro.codecs import container
from repro.codecs.indexing import validate_indices
from repro.fleet.router import HashRing, PayloadRoute
from repro.fleet.transport import LocalTransport, Transport, TransportError
from repro.serve.codec_service import CodecService, Ownership

#: fp64 output per decoded entry — the unit admission control budgets in
_OUT_BYTES_PER_ENTRY = 8


class FleetFrontend:
    def __init__(
        self,
        instances: int | list[str] | dict[str, CodecService | Transport] = 2,
        *,
        cache_bytes: int | None = None,
        max_batch: int = 65536,
        replication: int = 1,
        vnodes: int = 64,
        max_inflight_bytes: int | None = None,
        latency_window: int = 2048,
        transport_factory: Callable[[str], Transport] | None = None,
        prefetch: bool = False,
        canary_fraction: float = 0.0,
        canary_seed: int = 0,
        canary_min_fitness: float | None = None,
    ):
        if isinstance(instances, int):
            if instances < 1:
                raise ValueError(f"need >= 1 instance, got {instances}")
            instances = [f"i{k}" for k in range(instances)]
        self._cache_bytes = cache_bytes
        self._max_batch = max_batch
        self.max_inflight_bytes = max_inflight_bytes
        self._latency_window = latency_window
        self._transport_factory = transport_factory or (
            lambda iid: LocalTransport(
                iid, cache_bytes=cache_bytes, max_batch=max_batch,
                prefetch=prefetch, canary_fraction=canary_fraction,
                canary_seed=canary_seed,
                canary_min_fitness=canary_min_fitness,
            )
        )
        if isinstance(instances, dict):
            self.transports: dict[str, Transport] = {
                iid: (
                    LocalTransport(iid, service=t)
                    if isinstance(t, CodecService)
                    else t
                )
                for iid, t in instances.items()
            }
        else:
            self.transports = {
                iid: self._transport_factory(iid) for iid in instances
            }
        self.ring = HashRing(
            list(self.transports), vnodes=vnodes, replication=replication
        )
        self.routes: dict[str, PayloadRoute] = {}
        self._paths: dict[str, tuple[str, int | None]] = {}
        #: payload -> group id -> replica list, rebuilt by apply_ownership
        self._group_owners: dict[str, dict[int, list[str]]] = {}
        self._queue: list[tuple[int, str, np.ndarray, int | None]] = []
        self._next_ticket = 0
        #: results resolved by drain()/decode_at(), delivered by the next flush()
        self._drained: dict[int, np.ndarray] = {}
        #: failures resolved early (drain(), decode_at()), reported by the
        #: next flush() — the failure analogue of _drained
        self._pending_failed: dict[int, Exception] = {}
        #: fleet tickets whose decode failed during the LAST flush
        self.failed: dict[int, Exception] = {}
        self.backpressure_flushes = 0
        #: instances whose transport died — still fleet members (the ring
        #: keeps them until a rebalance removes them) but excluded from
        #: routing, so queries go to surviving replicas instead of hanging
        self.excluded: set[str] = set()
        #: instance -> the TransportError that excluded it
        self.exclusion_errors: dict[str, TransportError] = {}
        #: CUMULATIVE exclusion count — never decremented (retiring a dead
        #: member clears ``excluded`` but not this), so metrics consumers
        #: can tell a fresh death from an old one
        self.exclusions_total = 0
        #: per-instance flush-latency histograms + peak-inflight gauges
        #: (all-time buckets AND an exact recent window, bounded memory)
        self.metrics = obs.MetricsRegistry()
        self._lat_hist: dict[str, obs.Histogram] = {}
        self._peak_gauge: dict[str, obs.Gauge] = {}
        for iid in self.transports:
            self._add_instance_instruments(iid)

    def _add_instance_instruments(self, iid: str) -> None:
        self._lat_hist[iid] = self.metrics.histogram(
            "flush_latency_seconds", window=self._latency_window, instance=iid
        )
        self._peak_gauge[iid] = self.metrics.gauge(
            "peak_inflight_bytes", instance=iid
        )

    def _remove_instance_instruments(self, iid: str) -> None:
        self._lat_hist.pop(iid, None)
        self._peak_gauge.pop(iid, None)
        self.metrics.remove("flush_latency_seconds", instance=iid)
        self.metrics.remove("peak_inflight_bytes", instance=iid)

    # ------------------------------------------------------------------ admin
    @property
    def services(self) -> dict[str, CodecService]:
        """In-process members' services (LocalTransport only) — a debug/
        test convenience; fleet logic goes through ``transports``."""
        return {
            iid: t.service
            for iid, t in self.transports.items()
            if isinstance(t, LocalTransport)
        }

    def instances(self) -> list[str]:
        return sorted(self.transports)

    def payloads(self) -> list[str]:
        return sorted(self.routes)

    def path_of(self, name: str) -> tuple[str, int | None]:
        """(container path, tile_entries) a payload was loaded with — what
        the rebalancer replays onto a joining instance."""
        return self._paths[name]

    def exclude(self, iid: str, err: TransportError) -> None:
        """Mark a member's transport dead: it stays on the ring (ownership
        is a rebalance concern) but routing skips it from now on."""
        if iid not in self.excluded:
            self.excluded.add(iid)
            self.exclusion_errors[iid] = err
            self.exclusions_total += 1

    def spawn_instance(self, iid: str) -> Transport:
        """Build a member with this fleet's transport factory and load
        every registered payload on it.  Ring membership and ownership are
        NOT touched — that is the rebalancer's job (drain barrier first)."""
        if iid in self.transports:
            raise ValueError(f"instance {iid!r} already exists")
        t = self._transport_factory(iid)
        try:
            for name, (path, tile_entries) in self._paths.items():
                t.load_stream(name, path, tile_entries=tile_entries)
        except Exception:
            # a failed replay must not leak the member (for a socket
            # transport that is a live worker OS process)
            try:
                t.close()
            except TransportError:
                pass
            raise
        self.transports[iid] = t
        self._add_instance_instruments(iid)
        return t

    def retire_instance(self, iid: str) -> Transport:
        """Detach a member from the fleet (payloads unloaded, worker shut
        down).  Ring membership must already have been updated and
        in-flight work drained — the rebalancer sequences this.  A dead
        transport retires without a hang: the shutdown is best-effort."""
        t = self.transports.pop(iid)
        self._remove_instance_instruments(iid)
        self.excluded.discard(iid)
        self.exclusion_errors.pop(iid, None)
        try:
            t.drain()
            for name in list(t.payloads()):
                t.unload(name)
        except TransportError:
            pass
        t.close()
        return t

    def close(self) -> None:
        """Shut down every member (terminates worker processes)."""
        for iid in list(self.transports):
            t = self.transports.pop(iid)
            try:
                t.close()
            except TransportError:
                pass

    def latency_seconds(self, iid: str) -> list[float]:
        """Wall seconds of this instance's most recent flushes (window-
        capped at ``latency_window``; see ``flush_count`` for the total)."""
        return self._lat_hist[iid].window_values()

    def latency_histogram(self, iid: str) -> obs.Histogram:
        """The full flush-latency instrument: all-time bucket counts plus
        the exact recent window ``latency_seconds`` reads."""
        return self._lat_hist[iid]

    def flush_count(self, iid: str) -> int:
        return self._lat_hist[iid].count

    def peak_inflight_bytes(self, iid: str) -> int:
        return int(self._peak_gauge[iid].value)

    # ------------------------------------------------------------------ load
    def load_stream(
        self, name: str, path: str, *, tile_entries: int | None = None
    ) -> PayloadRoute:
        """Register a container v3/v4 file fleet-wide: every instance
        mmaps it lazily; the chunk index (and, for v4 delta files, the
        version index) seeds the routing table; ownership filters shard
        materialization and tile caching across the ring."""
        codec_name, chunks, versions = container.container_index(path)
        live = [iid for iid in self.transports if iid not in self.excluded]
        if not live:
            raise TransportError(
                f"cannot load {name!r}: every fleet member is excluded "
                f"(dead instances: {sorted(self.excluded)})"
            )
        try:
            for iid in live:  # dead members get the payload at rebalance
                self.transports[iid].load_stream(
                    name, path, tile_entries=tile_entries
                )
            # the chunk-0 primary is an owner either way — peeking the shape
            # there materializes a body that instance would keep anyway;
            # fall back to any live member when the primary's transport died
            candidates = self.ring.owners(f"{name}/c0", len(self.transports))
            primary = next((i for i in candidates if i in live), live[0])
            shape = self.transports[primary].shape_of(name)
            route = PayloadRoute(name, shape, chunks, tile_entries, versions)
        except Exception:
            # nothing half-registered: a corrupt chunk discovered at the
            # shape peek must not leave N-1 instances serving garbage —
            # and a failed RE-load must not keep the replaced payload's
            # stale route/path either (the instances' registrations are
            # already gone)
            for t in self.transports.values():
                try:
                    t.unload(name)
                except TransportError:
                    pass
            self.routes.pop(name, None)
            self._paths.pop(name, None)
            raise
        self.routes[name] = route
        self._paths[name] = (path, tile_entries)
        self.apply_ownership(name)
        return route

    def unload(self, name: str) -> None:
        self.routes.pop(name, None)
        self._paths.pop(name, None)
        self._group_owners.pop(name, None)
        for iid, t in self.transports.items():
            try:
                t.unload(name)
            except TransportError as e:
                self.exclude(iid, e)

    def refresh(self, name: str) -> None:
        """Fan a payload refresh to every live member — the repair
        controller's epoch switch after it rewrote chunks or appended a
        patch: each instance re-opens the container file and drops its
        quarantine marks and cached decode state for the payload."""
        if name not in self.routes:
            raise KeyError(f"no payload {name!r}")
        for iid, t in self.transports.items():
            if iid in self.excluded:
                continue
            try:
                t.refresh(name)
            except TransportError as e:
                self.exclude(iid, e)

    def apply_ownership(self, name: str) -> None:
        """(Re-)install each instance's ownership filter for a payload
        from the CURRENT ring — called at load and after every rebalance.
        One ring enumeration serves all instances; a member not on the
        ring (a leaver awaiting retirement) owns nothing."""
        route = self.routes[name]
        maps = route.owner_maps(self.ring)
        chunk_tbl, tile_tbl = route.ownership_tables(self.ring, maps)
        for iid, t in self.transports.items():
            if iid in self.excluded:
                continue  # dead transport; rebalance retires it for real
            try:
                t.set_ownership(
                    name,
                    Ownership(
                        chunk_ids=chunk_tbl.get(iid, frozenset()),
                        tile_ids=(
                            tile_tbl.get(iid, frozenset()) if route.tiled else None
                        ),
                    ),
                )
            except TransportError as e:
                self.exclude(iid, e)
        # hot-path routing table: group id -> replica list (primary first),
        # so flush() pays a dict lookup per group, not a ring hash
        self._group_owners[name] = maps[1] if route.tiled else maps[0]

    # ---------------------------------------------------------------- queries
    def _validate(self, name: str, indices: np.ndarray) -> np.ndarray:
        """Same validation as CodecService (shared helper), so a malformed
        request is rejected before any fan-out."""
        route = self.routes.get(name)
        if route is None:
            raise KeyError(
                f"no payload {name!r}; loaded: {', '.join(self.payloads())}"
            )
        return validate_indices(name, route.shape, indices)

    def _resolve_version(self, name: str, version: int | None) -> int | None:
        """Pin a versioned payload's query to a concrete version id at
        submit time (None -> latest), mirroring CodecService."""
        route = self.routes[name]
        if not route.versioned:
            if version is not None:
                raise ValueError(
                    f"payload {name!r} is not versioned (version={version})"
                )
            return None
        v = route.n_versions - 1 if version is None else int(version)
        if not 0 <= v < route.n_versions:
            raise ValueError(
                f"{name}: version {v} out of range [0, {route.n_versions})"
            )
        return v

    def submit(
        self, name: str, indices: np.ndarray, version: int | None = None
    ) -> int:
        """Queue a request; resolved by the next flush().  Validates
        eagerly so a malformed request can never poison a batch."""
        with obs.span("fleet.submit", payload=name):
            idx = self._validate(name, indices)
            v = self._resolve_version(name, version)
            ticket = self._next_ticket
            self._next_ticket += 1
            self._queue.append((ticket, name, idx, v))
            return ticket

    def decode_at(
        self, name: str, indices: np.ndarray, version: int | None = None
    ) -> np.ndarray:
        """Direct query: split by owner, fan out, reassemble in order.
        Any other queued tickets are resolved too — their results are
        held for the next flush(), and their failures (if any) stay in
        ``self.failed`` until then, mirroring CodecService semantics."""
        with obs.span(
            "fleet.decode_at", payload=name, entries=int(np.size(indices))
        ):
            ticket = self.submit(name, indices, version=version)
            results = self.flush()
            value = results.pop(ticket, None)
            self._drained.update(results)  # don't lose concurrent tickets...
            err = self.failed.pop(ticket, None)
            # ...and defer their failures to the next flush — the one
            # report, not one now and one again later
            self._pending_failed.update(self.failed)
            self.failed = {}
        if err is not None:
            raise err
        return value

    def drain(self) -> None:
        """Barrier: resolve every queued ticket.  Results are merged into
        the next flush()'s return and failures accumulate, so a rebalance
        mid-query-stream loses nothing."""
        if not self._queue:
            return
        results = self.flush()
        self._drained.update(results)
        self._pending_failed.update(self.failed)

    # ----------------------------------------------------------------- flush
    def flush(self) -> dict[int, np.ndarray]:
        """Resolve all queued tickets: one owner-split plan, one
        coalesced submit/flush round per live instance (admission-
        controlled), then per-ticket reassembly in request order."""
        # failures resolved early (drain/decode_at) are reported exactly
        # once, by this flush — mirroring how _drained delivers results
        self.failed = self._pending_failed
        self._pending_failed = {}
        results = self._drained
        self._drained = {}
        queue, self._queue = self._queue, []
        # plan: per instance, (ticket, name, version, sub-indices, positions)
        plan: dict[
            str, list[tuple[int, str, int | None, np.ndarray, np.ndarray]]
        ] = {iid: [] for iid in self.transports}
        planned_bytes = dict.fromkeys(self.transports, 0)
        for ticket, name, idx, version in queue:
            route = self.routes.get(name)
            if route is None:  # unloaded between submit and flush
                self.failed[ticket] = KeyError(f"payload {name!r} unloaded")
                continue
            if not idx.shape[0]:  # empty request: answer locally
                results[ticket] = np.empty(0, dtype=np.float64)
                continue
            gids = route.group_of(route.flat(idx), version)
            uniq, inv = np.unique(gids, return_inverse=True)
            counts = np.bincount(inv, minlength=len(uniq))
            group_owners = self._group_owners[name]
            owner_by_gid = np.empty(len(uniq), dtype=object)
            unroutable: int | None = None
            for k, gid in enumerate(uniq):
                replicas = [
                    r for r in group_owners[int(gid)] if r not in self.excluded
                ]
                if not replicas:
                    unroutable = int(gid)
                    break
                # ties go to the first (primary) replica — min() keeps
                # the earliest element among equals
                owner_by_gid[k] = min(replicas, key=planned_bytes.__getitem__)
                planned_bytes[owner_by_gid[k]] += (
                    int(counts[k]) * _OUT_BYTES_PER_ENTRY
                )
            if unroutable is not None:
                self.failed[ticket] = TransportError(
                    f"payload {name!r} group {unroutable}: every replica is "
                    f"excluded (dead instances: {sorted(self.excluded)})"
                )
                continue
            owners = owner_by_gid[inv]
            for iid in np.unique(owners):
                pos = np.nonzero(owners == iid)[0]
                plan[iid].append((ticket, name, version, idx[pos], pos))
        # execute
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {}
        part_failed: dict[int, Exception] = {}
        #: sub-batches that failed on their planned instance, eligible for
        #: replica failover: (failed instance, plan item, error)
        retries: list[tuple[str, tuple, Exception]] = []
        with obs.span(
            "fleet.flush",
            tickets=len(queue),
            instances=sum(1 for items in plan.values() if items),
        ):
            for iid, items in plan.items():
                if items:
                    self._run_instance(iid, items, parts, part_failed, retries)
            if retries:
                self._retry_failed(retries, parts, part_failed)
        # reassemble in request order
        sizes = {ticket: idx.shape[0] for ticket, _, idx, _ in queue}
        for ticket, _, idx, _ in queue:
            if ticket in results or ticket in self.failed:
                continue  # empty request / failed before fan-out
            if ticket in part_failed:
                self.failed[ticket] = part_failed[ticket]
                continue
            got = parts.get(ticket, [])
            out = np.empty(sizes[ticket], dtype=got[0][1].dtype)
            for pos, values in got:
                out[pos] = values
            results[ticket] = out
        return results

    def _run_instance(
        self,
        iid: str,
        items: list[tuple[int, str, int | None, np.ndarray, np.ndarray]],
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]],
        part_failed: dict[int, Exception],
        retries: list[tuple[str, tuple, Exception]],
    ) -> None:
        """Submit this instance's sub-batches through its transport's
        coalescing path, flushing early whenever the in-flight byte budget
        would overflow.  A failed sub-batch — request-level error or the
        transport dying mid-batch — goes to ``retries`` for replica
        failover instead of failing its ticket outright; a transport death
        additionally excludes the instance from future routing."""
        t = self.transports[iid]
        #: (ticket, rid, pos, plan item) — the item rides along so a
        #: failure can be retried on a replica with full context
        pending: list[tuple[int, int, np.ndarray, tuple]] = []
        inflight = 0
        resolved: set[int] = set()  # tickets answered by an early flush
        try:
            for item in items:
                ticket, name, version, sub_idx, pos = item
                cost = sub_idx.shape[0] * _OUT_BYTES_PER_ENTRY + sub_idx.nbytes
                if (
                    self.max_inflight_bytes is not None
                    and pending
                    and inflight + cost > self.max_inflight_bytes
                ):
                    self.backpressure_flushes += 1
                    self._flush_instance(iid, t, pending, parts, retries)
                    resolved.update(p[0] for p in pending)
                    pending, inflight = [], 0
                rid = t.submit(name, sub_idx, version=version)
                pending.append((ticket, rid, pos, item))
                inflight += cost
                self._peak_gauge[iid].set_max(inflight)
            if pending:
                self._flush_instance(iid, t, pending, parts, retries)
        except TransportError as e:
            self.exclude(iid, e)
            for item in items:
                if item[0] not in resolved:
                    retries.append((iid, item, e))

    def _flush_instance(self, iid, transport, pending, parts, retries) -> None:
        # latency is measured with raw perf_counter reads, independent of
        # tracing, so the metrics are identical with tracing off or on
        with obs.span("transport.flush", instance=iid, requests=len(pending)):
            t0 = time.perf_counter()
            results, failures = transport.flush()
            self._lat_hist[iid].observe(time.perf_counter() - t0)
        for ticket, rid, pos, item in pending:
            if rid in results:
                parts.setdefault(ticket, []).append((pos, results[rid]))
            else:
                retries.append((iid, item, failures.get(
                    rid, RuntimeError(f"instance {iid}: ticket vanished")
                )))

    def _retry_failed(
        self,
        retries: list[tuple[str, tuple, Exception]],
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]],
        part_failed: dict[int, Exception],
    ) -> None:
        """Replica failover: re-route each failed sub-batch to its groups'
        surviving replicas (decode-through keeps any owning replica
        bit-identical).  Only when no healthy replica remains does the
        original error reach the ticket.  Each successful failover emits a
        ``decode_failover`` event naming source, target, and cause —
        the repair controller's corruption signal rides the same poll."""
        for failed_iid, item, err in retries:
            ticket = item[0]
            if ticket in part_failed:
                continue
            if not self._retry_on_replicas(failed_iid, item, err, parts):
                part_failed[ticket] = err

    def _retry_on_replicas(
        self,
        failed_iid: str,
        item: tuple[int, str, int | None, np.ndarray, np.ndarray],
        err: Exception,
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]],
    ) -> bool:
        ticket, name, version, sub_idx, pos = item
        route = self.routes.get(name)
        group_owners = self._group_owners.get(name)
        if route is None or group_owners is None:
            return False
        gids = route.group_of(route.flat(sub_idx), version)
        split: dict[str, list[np.ndarray]] = {}
        for gid in np.unique(gids):
            cand = [
                r for r in group_owners[int(gid)]
                if r != failed_iid and r not in self.excluded
            ]
            if not cand:
                return False  # no surviving replica for this group
            split.setdefault(cand[0], []).append(np.nonzero(gids == gid)[0])
        done: list[tuple[np.ndarray, np.ndarray]] = []
        for iid, sels in split.items():
            sel = np.concatenate(sels)
            t = self.transports[iid]
            try:
                rid = t.submit(name, sub_idx[sel], version=version)
                results, _failures = t.flush()
            except TransportError as e:
                self.exclude(iid, e)
                return False
            if rid not in results:
                return False
            done.append((pos[sel], results[rid]))
            obs.emit_event(
                "decode_failover",
                payload=name,
                from_instance=failed_iid,
                to_instance=iid,
                entries=int(len(sel)),
                ticket=ticket,
                error=str(err),
            )
        parts.setdefault(ticket, []).extend(done)
        return True
