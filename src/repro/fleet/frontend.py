"""The fleet query frontend: N ``CodecService`` instances, one service.

Every instance mmaps the same container-v3 file (``load_stream``) but —
via the :class:`~repro.serve.codec_service.Ownership` filter the router
installs — materializes and caches only its shard of chunks and decode
tiles.  A ``decode_at`` batch is split by owner, fanned out through each
instance's existing ``submit``/``flush`` coalescing path, and reassembled
in request order, so a fleet answer is bit-identical to a single
resident instance's.

Admission control: ``max_inflight_bytes`` bounds the bytes (decoded
output + index payload) queued on any one instance during a flush.  When
a wave of sub-batches would exceed it, the instance is flushed NOW
(backpressure) instead of queueing without bound —
``backpressure_flushes`` counts how often that happened.

Replication: with ``replication=R`` each chunk/tile key has R owners on
the ring; the frontend sends each group to whichever replica has the
least bytes planned this flush, so hot chunks spread across their
replica set.

    fleet = FleetFrontend(4, cache_bytes=1 << 24, replication=1)
    fleet.load_stream("embed", "embed.tcdc", tile_entries=4096)
    fleet.decode_at("embed", idx)        # == single instance, bit-exact
"""
from __future__ import annotations

import collections
import time

import numpy as np

from repro.codecs import container
from repro.codecs.indexing import validate_indices
from repro.fleet.router import HashRing, PayloadRoute
from repro.serve.codec_service import CodecService, Ownership

#: fp64 output per decoded entry — the unit admission control budgets in
_OUT_BYTES_PER_ENTRY = 8


class FleetFrontend:
    def __init__(
        self,
        instances: int | list[str] | dict[str, CodecService] = 2,
        *,
        cache_bytes: int | None = None,
        max_batch: int = 65536,
        replication: int = 1,
        vnodes: int = 64,
        max_inflight_bytes: int | None = None,
        latency_window: int = 2048,
    ):
        if isinstance(instances, int):
            if instances < 1:
                raise ValueError(f"need >= 1 instance, got {instances}")
            instances = [f"i{k}" for k in range(instances)]
        self._cache_bytes = cache_bytes
        self._max_batch = max_batch
        self.max_inflight_bytes = max_inflight_bytes
        self._latency_window = latency_window
        if isinstance(instances, dict):
            self.services: dict[str, CodecService] = dict(instances)
        else:
            self.services = {
                iid: CodecService(max_batch=max_batch, cache_bytes=cache_bytes)
                for iid in instances
            }
        self.ring = HashRing(
            list(self.services), vnodes=vnodes, replication=replication
        )
        self.routes: dict[str, PayloadRoute] = {}
        self._paths: dict[str, tuple[str, int | None]] = {}
        #: payload -> group id -> replica list, rebuilt by apply_ownership
        self._group_owners: dict[str, dict[int, list[str]]] = {}
        self._queue: list[tuple[int, str, np.ndarray]] = []
        self._next_ticket = 0
        #: results resolved by drain()/decode_at(), delivered by the next flush()
        self._drained: dict[int, np.ndarray] = {}
        #: failures resolved early (drain(), decode_at()), reported by the
        #: next flush() — the failure analogue of _drained
        self._pending_failed: dict[int, Exception] = {}
        #: fleet tickets whose decode failed during the LAST flush
        self.failed: dict[int, Exception] = {}
        self.backpressure_flushes = 0
        self._latency: dict[str, collections.deque] = {
            iid: collections.deque(maxlen=latency_window) for iid in self.services
        }
        #: monotonic per-instance flush counter (the latency deque is
        #: window-capped, so len() is not a flush count)
        self._flush_counts: dict[str, int] = {iid: 0 for iid in self.services}
        self._peak_inflight: dict[str, int] = {iid: 0 for iid in self.services}

    # ------------------------------------------------------------------ admin
    def instances(self) -> list[str]:
        return sorted(self.services)

    def payloads(self) -> list[str]:
        return sorted(self.routes)

    def path_of(self, name: str) -> tuple[str, int | None]:
        """(container path, tile_entries) a payload was loaded with — what
        the rebalancer replays onto a joining instance."""
        return self._paths[name]

    def spawn_instance(self, iid: str) -> CodecService:
        """Build a service with this fleet's config and load every
        registered payload on it.  Ring membership and ownership are NOT
        touched — that is the rebalancer's job (drain barrier first)."""
        if iid in self.services:
            raise ValueError(f"instance {iid!r} already exists")
        svc = CodecService(max_batch=self._max_batch,
                           cache_bytes=self._cache_bytes)
        for name, (path, tile_entries) in self._paths.items():
            svc.load_stream(name, path, tile_entries=tile_entries)
        self.services[iid] = svc
        self._latency[iid] = collections.deque(maxlen=self._latency_window)
        self._flush_counts[iid] = 0
        self._peak_inflight[iid] = 0
        return svc

    def retire_instance(self, iid: str) -> CodecService:
        """Detach a service from the fleet (payloads unloaded, mmaps
        released).  Ring membership must already have been updated and
        in-flight work drained — the rebalancer sequences this."""
        svc = self.services.pop(iid)
        self._latency.pop(iid, None)
        self._flush_counts.pop(iid, None)
        self._peak_inflight.pop(iid, None)
        for name in list(svc.payloads()):
            svc.unload(name)
        return svc

    def latency_seconds(self, iid: str) -> list[float]:
        """Wall seconds of this instance's most recent flushes (window-
        capped at ``latency_window``; see ``flush_count`` for the total)."""
        return list(self._latency[iid])

    def flush_count(self, iid: str) -> int:
        return self._flush_counts[iid]

    def peak_inflight_bytes(self, iid: str) -> int:
        return self._peak_inflight[iid]

    # ------------------------------------------------------------------ load
    def load_stream(
        self, name: str, path: str, *, tile_entries: int | None = None
    ) -> PayloadRoute:
        """Register a container-v3 file fleet-wide: every instance mmaps
        it lazily; the chunk index seeds the routing table; ownership
        filters shard materialization and tile caching across the ring."""
        codec_name, chunks = container.chunk_index(path)
        try:
            for svc in self.services.values():
                svc.load_stream(name, path, tile_entries=tile_entries)
            # the chunk-0 primary is an owner either way — peeking the shape
            # there materializes a body that instance would keep anyway
            primary = self.ring.owner(f"{name}/c0")
            shape = self.services[primary].shape_of(name)
            route = PayloadRoute(name, shape, chunks, tile_entries)
        except Exception:
            # nothing half-registered: a corrupt chunk discovered at the
            # shape peek must not leave N-1 instances serving garbage —
            # and a failed RE-load must not keep the replaced payload's
            # stale route/path either (the instances' registrations are
            # already gone)
            for svc in self.services.values():
                svc.unload(name)
            self.routes.pop(name, None)
            self._paths.pop(name, None)
            raise
        self.routes[name] = route
        self._paths[name] = (path, tile_entries)
        self.apply_ownership(name)
        return route

    def unload(self, name: str) -> None:
        self.routes.pop(name, None)
        self._paths.pop(name, None)
        self._group_owners.pop(name, None)
        for svc in self.services.values():
            svc.unload(name)

    def apply_ownership(self, name: str) -> None:
        """(Re-)install each instance's ownership filter for a payload
        from the CURRENT ring — called at load and after every rebalance.
        One ring enumeration serves all instances; a service not on the
        ring (a leaver awaiting retirement) owns nothing."""
        route = self.routes[name]
        maps = route.owner_maps(self.ring)
        chunk_tbl, tile_tbl = route.ownership_tables(self.ring, maps)
        for iid, svc in self.services.items():
            svc.set_ownership(
                name,
                Ownership(
                    chunk_ids=chunk_tbl.get(iid, frozenset()),
                    tile_ids=(
                        tile_tbl.get(iid, frozenset()) if route.tiled else None
                    ),
                ),
            )
        # hot-path routing table: group id -> replica list (primary first),
        # so flush() pays a dict lookup per group, not a ring hash
        self._group_owners[name] = maps[1] if route.tiled else maps[0]

    # ---------------------------------------------------------------- queries
    def _validate(self, name: str, indices: np.ndarray) -> np.ndarray:
        """Same validation as CodecService (shared helper), so a malformed
        request is rejected before any fan-out."""
        route = self.routes.get(name)
        if route is None:
            raise KeyError(
                f"no payload {name!r}; loaded: {', '.join(self.payloads())}"
            )
        return validate_indices(name, route.shape, indices)

    def submit(self, name: str, indices: np.ndarray) -> int:
        """Queue a request; resolved by the next flush().  Validates
        eagerly so a malformed request can never poison a batch."""
        idx = self._validate(name, indices)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, name, idx))
        return ticket

    def decode_at(self, name: str, indices: np.ndarray) -> np.ndarray:
        """Direct query: split by owner, fan out, reassemble in order.
        Any other queued tickets are resolved too — their results are
        held for the next flush(), and their failures (if any) stay in
        ``self.failed`` until then, mirroring CodecService semantics."""
        ticket = self.submit(name, indices)
        results = self.flush()
        value = results.pop(ticket, None)
        self._drained.update(results)  # don't lose concurrent tickets...
        err = self.failed.pop(ticket, None)
        # ...and defer their failures to the next flush — the one report,
        # not one now and one again later
        self._pending_failed.update(self.failed)
        self.failed = {}
        if err is not None:
            raise err
        return value

    def drain(self) -> None:
        """Barrier: resolve every queued ticket.  Results are merged into
        the next flush()'s return and failures accumulate, so a rebalance
        mid-query-stream loses nothing."""
        if not self._queue:
            return
        results = self.flush()
        self._drained.update(results)
        self._pending_failed.update(self.failed)

    # ----------------------------------------------------------------- flush
    def flush(self) -> dict[int, np.ndarray]:
        """Resolve all queued tickets: one owner-split plan, one
        coalesced submit/flush round per instance (admission-controlled),
        then per-ticket reassembly in request order."""
        # failures resolved early (drain/decode_at) are reported exactly
        # once, by this flush — mirroring how _drained delivers results
        self.failed = self._pending_failed
        self._pending_failed = {}
        results = self._drained
        self._drained = {}
        queue, self._queue = self._queue, []
        # plan: per instance, (ticket, name, sub-indices, output positions)
        plan: dict[str, list[tuple[int, str, np.ndarray, np.ndarray]]] = {
            iid: [] for iid in self.services
        }
        planned_bytes = dict.fromkeys(self.services, 0)
        for ticket, name, idx in queue:
            route = self.routes.get(name)
            if route is None:  # unloaded between submit and flush
                self.failed[ticket] = KeyError(f"payload {name!r} unloaded")
                continue
            if not idx.shape[0]:  # empty request: answer locally
                results[ticket] = np.empty(0, dtype=np.float64)
                continue
            gids = route.group_of(route.flat(idx))
            uniq, inv = np.unique(gids, return_inverse=True)
            counts = np.bincount(inv, minlength=len(uniq))
            group_owners = self._group_owners[name]
            owner_by_gid = np.empty(len(uniq), dtype=object)
            for k, gid in enumerate(uniq):
                replicas = group_owners[int(gid)]
                # ties go to the first (primary) replica — min() keeps
                # the earliest element among equals
                owner_by_gid[k] = min(replicas, key=planned_bytes.__getitem__)
                planned_bytes[owner_by_gid[k]] += (
                    int(counts[k]) * _OUT_BYTES_PER_ENTRY
                )
            owners = owner_by_gid[inv]
            for iid in np.unique(owners):
                pos = np.nonzero(owners == iid)[0]
                plan[iid].append((ticket, name, idx[pos], pos))
        # execute
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {}
        part_failed: dict[int, Exception] = {}
        for iid, items in plan.items():
            if items:
                self._run_instance(iid, items, parts, part_failed)
        # reassemble in request order
        sizes = {ticket: idx.shape[0] for ticket, _, idx in queue}
        for ticket, _, idx in queue:
            if ticket in results or ticket in self.failed:
                continue  # empty request / failed before fan-out
            if ticket in part_failed:
                self.failed[ticket] = part_failed[ticket]
                continue
            got = parts.get(ticket, [])
            out = np.empty(sizes[ticket], dtype=got[0][1].dtype)
            for pos, values in got:
                out[pos] = values
            results[ticket] = out
        return results

    def _run_instance(
        self,
        iid: str,
        items: list[tuple[int, str, np.ndarray, np.ndarray]],
        parts: dict[int, list[tuple[np.ndarray, np.ndarray]]],
        part_failed: dict[int, Exception],
    ) -> None:
        """Submit this instance's sub-batches through its coalescing path,
        flushing early whenever the in-flight byte budget would overflow."""
        svc = self.services[iid]
        pending: list[tuple[int, int, np.ndarray]] = []  # (ticket, svc ticket, pos)
        inflight = 0
        for ticket, name, sub_idx, pos in items:
            cost = sub_idx.shape[0] * _OUT_BYTES_PER_ENTRY + sub_idx.nbytes
            if (
                self.max_inflight_bytes is not None
                and pending
                and inflight + cost > self.max_inflight_bytes
            ):
                self.backpressure_flushes += 1
                self._flush_instance(iid, svc, pending, parts, part_failed)
                pending, inflight = [], 0
            try:
                svc_ticket = svc.submit(name, sub_idx)
            except Exception as e:  # noqa: BLE001 — isolate this part
                part_failed[ticket] = e
                continue
            pending.append((ticket, svc_ticket, pos))
            inflight += cost
            self._peak_inflight[iid] = max(self._peak_inflight[iid], inflight)
        if pending:
            self._flush_instance(iid, svc, pending, parts, part_failed)

    def _flush_instance(self, iid, svc, pending, parts, part_failed) -> None:
        t0 = time.perf_counter()
        out = svc.flush()
        self._latency[iid].append(time.perf_counter() - t0)
        self._flush_counts[iid] += 1
        for ticket, svc_ticket, pos in pending:
            if svc_ticket in out:
                parts.setdefault(ticket, []).append((pos, out[svc_ticket]))
            else:
                part_failed[ticket] = svc.failed.get(
                    svc_ticket,
                    RuntimeError(f"instance {iid}: ticket vanished"),
                )
