"""``python -m repro.fleet.worker`` — one fleet member as an OS process.

The worker binds a TCP or Unix socket, accepts ONE frontend connection,
and runs one owned :class:`~repro.serve.codec_service.CodecService` that
mmaps whatever shared container-v3 files the frontend registers over the
wire (``OP_LOAD`` carries a *path*, never payload bytes — workers on the
same host share the page cache, workers across hosts need a shared
filesystem).  It answers the transport protocol defined in
``repro.fleet.transport``:

- pipelined ``OP_SUBMIT`` frames queue requests on the service (submit-
  time errors are held and reported at the next flush, keyed by the
  frontend's request id);
- ``OP_FLUSH`` resolves everything queued through the service's
  coalescing path and answers every outstanding request id exactly once
  — result array or error string — in request-id order; when the
  frontend requests tracing (``FLUSH_WANT_SPANS``) the worker's span
  recorder follows that request and its buffered spans ride the reply,
  timestamped on this process's clock for the frontend to re-base;
- the rebalance verbs (``OP_SET_OWNERSHIP``/``OP_EXPORT_TILES``/
  ``OP_ADMIT_TILE``/``OP_DROP_UNOWNED``) make cross-process warm
  handoff work identically to the in-process path.

The worker exits when the frontend disconnects (EOF), on ``OP_SHUTDOWN``,
or on a framing violation (a truncated or oversized frame is a protocol
error — the worker answers nothing it cannot parse and closes, so the
frontend's timeout converts it into an excluded instance instead of a
hang).

    python -m repro.fleet.worker --listen unix:/tmp/pod0.sock
    python -m repro.fleet.worker --listen tcp:127.0.0.1:7070 --cache-bytes 268435456
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import struct
import sys
import time

from repro import obs
from repro.fleet.transport import (
    FLUSH_HAS_CTX,
    FLUSH_WANT_SPANS,
    OP_ADMIT_TILE,
    OP_DROP_UNOWNED,
    OP_EXPORT_CHUNK,
    OP_EXPORT_TILES,
    OP_FLUSH,
    OP_INJECT_FAULT,
    OP_LOAD,
    OP_PAYLOADS,
    OP_PING,
    OP_REFRESH,
    OP_SET_OWNERSHIP,
    OP_SHAPE,
    OP_SHUTDOWN,
    OP_STATS,
    OP_SUBMIT,
    OP_UNLOAD,
    ProtocolError,
    Reader,
    ST_ERROR,
    ST_OK,
    Writer,
    pack_spans,
    parse_address,
    recv_frame,
    send_frame,
    unpack_ownership,
)
from repro.serve.codec_service import CodecService

#: was tracing enabled by THIS process's environment (vs a frontend
#: request)? env-enabled tracing never turns off mid-session
_ENV_TRACE = os.environ.get("REPRO_TRACE", "") not in ("", "0")


def parse_fault_flags(
    corrupt: list[str] | None, noise: list[str] | None
) -> dict[str, list[dict]]:
    """Parse the ``--debug-corrupt-chunk NAME:CHUNK`` and
    ``--debug-fitness-noise NAME:LO:HI:SIGMA[:SEED]`` CLI specs into
    payload-name-keyed ``CodecService.inject_fault`` dicts.  Shared by the
    worker CLI and the pytest ``fault_injector`` fixture so the CI drill
    and the unit tests exercise ONE injection surface."""
    out: dict[str, list[dict]] = {}
    for spec in corrupt or []:
        name, _, cid = spec.rpartition(":")
        if not name or not cid.lstrip("-").isdigit():
            raise ValueError(
                f"bad --debug-corrupt-chunk {spec!r} (want NAME:CHUNK)"
            )
        out.setdefault(name, []).append(
            {"kind": "corrupt_chunk", "chunk": int(cid)}
        )
    for spec in noise or []:
        parts = spec.split(":")
        if len(parts) not in (4, 5):
            raise ValueError(
                f"bad --debug-fitness-noise {spec!r} "
                "(want NAME:LO:HI:SIGMA[:SEED])"
            )
        fault = {
            "kind": "fitness_noise",
            "entry_start": int(parts[1]),
            "entry_stop": int(parts[2]),
            "sigma": float(parts[3]),
        }
        if len(parts) == 5:
            fault["seed"] = int(parts[4])
        out.setdefault(parts[0], []).append(fault)
    return out


class WorkerState:
    """One connection's request state: the owned service plus the
    pipelined submits awaiting the next flush."""

    def __init__(
        self,
        service: CodecService,
        flush_sleep_s: float = 0.0,
        fault_specs: dict[str, list[dict]] | None = None,
    ):
        self.service = service
        #: request id -> service ticket, in arrival order
        self.pending: dict[int, int] = {}
        #: request id -> submit-time error message, reported at flush
        self.deferred: dict[int, str] = {}
        self.shutdown = False
        #: latency fault injector (--debug-flush-sleep-ms): every flush
        #: sleeps this long FIRST, so an SLO drill can breach a p99 target
        #: without touching the service's decode path (answers stay
        #: trivially bit-identical)
        self.flush_sleep_s = flush_sleep_s
        #: CLI fault specs (parse_fault_flags), installed on a payload the
        #: moment OP_LOAD registers it — consumed once per name; a later
        #: OP_REFRESH on the payload clears the fault for good, matching
        #: "the repair epoch starts clean"
        self.fault_specs = fault_specs or {}


def _handle(state: WorkerState, op: int, rid: int, r: Reader) -> bytes | None:
    """Dispatch one request; returns the OK-response body, or None for
    pipelined ops that answer nothing until flush."""
    svc = state.service
    if op == OP_PING:
        return b""
    if op == OP_LOAD:
        name, path, tile = r.str(), r.str(), r.i64()
        svc.load_stream(name, path, tile_entries=None if tile < 0 else tile)
        for fault in state.fault_specs.pop(name, []):
            svc.inject_fault(name, fault)
        return b""
    if op == OP_UNLOAD:
        svc.unload(r.str())
        return b""
    if op == OP_SHAPE:
        shape = svc.shape_of(r.str())
        w = Writer().u8(len(shape))
        for s in shape:
            w.u64(int(s))
        return w.bytes()
    if op == OP_SUBMIT:
        name = r.str()
        version = r.i64()  # -1 encodes version=None (single-tensor payloads)
        arr = r.array()
        ctx = (r.u64(), r.u64()) if not r.eof() else None
        try:
            with obs.remote_context(ctx):
                state.pending[rid] = svc.submit(
                    name, arr, version=None if version < 0 else version
                )
        except Exception as e:  # noqa: BLE001 — deferred to flush, per protocol
            state.deferred[rid] = f"{type(e).__name__}: {e}"
        return None
    if op == OP_FLUSH:
        if state.flush_sleep_s > 0:
            time.sleep(state.flush_sleep_s)
        flags = 0 if r.eof() else r.u8()
        ctx = (r.u64(), r.u64()) if flags & FLUSH_HAS_CTX else None
        want_spans = bool(flags & FLUSH_WANT_SPANS)
        # the worker's recorder follows the frontend's request, so tracing
        # toggled mid-session on the frontend takes effect here too;
        # REPRO_TRACE in the worker's own env keeps it on regardless
        if want_spans and not obs.enabled():
            obs.enable_tracing()
        elif not want_spans and obs.enabled() and not _ENV_TRACE:
            obs.disable_tracing()
        with obs.remote_context(ctx):
            out = svc.flush()
        results: list[tuple[int, object]] = []
        failures: list[tuple[int, str]] = list(state.deferred.items())
        for srid, ticket in state.pending.items():
            if ticket in out:
                results.append((srid, out[ticket]))
            else:
                err = svc.failed.get(ticket)
                failures.append(
                    (srid, f"{type(err).__name__}: {err}" if err else "ticket vanished")
                )
        state.pending = {}
        state.deferred = {}
        w = Writer().u32(len(results))
        for srid, values in sorted(results, key=lambda t: t[0]):
            w.u64(srid).array(values)
        w.u32(len(failures))
        for srid, msg in sorted(failures, key=lambda t: t[0]):
            w.u64(srid).str(msg)
        if want_spans:
            w.u8(1)
            pack_spans(w, obs.get_recorder().drain())
        return w.bytes()
    if op == OP_STATS:
        return Writer().blob(
            json.dumps(svc.stats()).encode("utf-8")
        ).bytes()
    if op == OP_SET_OWNERSHIP:
        name = r.str()
        svc.set_ownership(name, unpack_ownership(r))
        return b""
    if op == OP_EXPORT_TILES:
        tiles = svc.export_tiles(r.str())
        w = Writer().u32(len(tiles))
        for tid, values in tiles.items():
            w.u64(int(tid)).array(values)
        return w.bytes()
    if op == OP_ADMIT_TILE:
        name, tid = r.str(), r.u64()
        return Writer().u8(1 if svc.admit_tile(name, tid, r.array()) else 0).bytes()
    if op == OP_DROP_UNOWNED:
        return Writer().u64(svc.drop_unowned(r.str())).bytes()
    if op == OP_REFRESH:
        svc.refresh(r.str())
        return b""
    if op == OP_EXPORT_CHUNK:
        raw = svc.export_chunk(r.str(), r.u64())
        w = Writer().u8(0 if raw is None else 1)
        if raw is not None:
            w.blob(raw)
        return w.bytes()
    if op == OP_INJECT_FAULT:
        name = r.str()
        svc.inject_fault(name, json.loads(r.blob().decode("utf-8")))
        return b""
    if op == OP_PAYLOADS:
        names = svc.payloads()
        w = Writer().u16(len(names))
        for name in names:
            w.str(name)
        return w.bytes()
    if op == OP_SHUTDOWN:
        state.shutdown = True
        return b""
    raise ProtocolError(f"unknown opcode {op}")


def serve_connection(
    conn: socket.socket,
    service: CodecService,
    flush_sleep_s: float = 0.0,
    fault_specs: dict[str, list[dict]] | None = None,
) -> None:
    """Run the request loop until EOF, shutdown, or a framing violation."""
    state = WorkerState(service, flush_sleep_s, fault_specs)
    while not state.shutdown:
        try:
            payload = recv_frame(conn)
        except ProtocolError as e:
            # half a frame is unanswerable (no parseable rid) — log, close
            print(f"repro.fleet.worker: protocol error: {e}", file=sys.stderr)
            return
        if payload is None:  # frontend disconnected
            return
        if len(payload) < 9:
            print("repro.fleet.worker: short request frame", file=sys.stderr)
            return
        op, rid = struct.unpack("<BQ", payload[:9])
        try:
            body = _handle(state, op, rid, Reader(payload[9:]))
        except ProtocolError as e:
            print(f"repro.fleet.worker: protocol error: {e}", file=sys.stderr)
            return
        except Exception as e:  # noqa: BLE001 — service error -> error response
            msg = f"{type(e).__name__}: {e}"
            send_frame(conn, struct.pack("<BQ", ST_ERROR, rid) + Writer().str(msg).bytes())
            continue
        if body is not None:
            send_frame(conn, struct.pack("<BQ", ST_OK, rid) + body)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro.fleet.worker",
        description="one fleet member: a CodecService behind a socket",
    )
    parser.add_argument(
        "--listen", required=True, help="unix:/path or tcp:host:port (port 0 = ephemeral)"
    )
    parser.add_argument("--cache-bytes", type=int, default=None)
    parser.add_argument("--max-batch", type=int, default=65536)
    parser.add_argument(
        "--prefetch",
        action="store_true",
        help="overlap chunk reads / tile-input builds with decode compute",
    )
    parser.add_argument(
        "--canary-fraction", type=float, default=0.0,
        help="fraction of decode_at calls that run an online fitness canary",
    )
    parser.add_argument("--canary-seed", type=int, default=0)
    parser.add_argument(
        "--canary-min-fitness", type=float, default=None,
        help="emit quality_breach events below this fitness",
    )
    parser.add_argument(
        "--debug-flush-sleep-ms", type=float, default=0.0,
        help="TESTING ONLY: sleep before every flush (latency fault injection)",
    )
    parser.add_argument(
        "--debug-corrupt-chunk", action="append", default=None,
        metavar="NAME:CHUNK",
        help="TESTING ONLY: fail the named payload chunk's CRC on read "
        "(repeatable; applied when the payload loads)",
    )
    parser.add_argument(
        "--debug-fitness-noise", action="append", default=None,
        metavar="NAME:LO:HI:SIGMA[:SEED]",
        help="TESTING ONLY: add seeded noise to served values in the flat "
        "entry range (repeatable; applied when the payload loads)",
    )
    args = parser.parse_args(argv)
    fault_specs = parse_fault_flags(
        args.debug_corrupt_chunk, args.debug_fitness_noise
    )

    family, addr = parse_address(args.listen)
    sock = socket.socket(family, socket.SOCK_STREAM)
    if family == socket.AF_INET:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    sock.bind(addr)
    sock.listen(1)
    bound = sock.getsockname()
    shown = f"tcp:{bound[0]}:{bound[1]}" if family == socket.AF_INET else f"unix:{bound}"
    print(f"READY {shown}", flush=True)

    service = CodecService(
        max_batch=args.max_batch,
        cache_bytes=args.cache_bytes,
        prefetch=args.prefetch,
        canary_fraction=args.canary_fraction,
        canary_seed=args.canary_seed,
        canary_min_fitness=args.canary_min_fitness,
    )
    try:
        conn, _ = sock.accept()
        with conn:
            serve_connection(
                conn, service,
                flush_sleep_s=args.debug_flush_sleep_ms / 1e3,
                fault_specs=fault_specs,
            )
    finally:
        sock.close()
        if family == socket.AF_UNIX:
            try:
                os.unlink(addr)
            except OSError:
                pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
