"""Fleet-wide observability: roll up per-instance cache stats + latency.

``collect`` snapshots every live instance's cache stats through its
:class:`~repro.fleet.transport.Transport` (``stats()`` returns the same
JSON-able dict for an in-process service and a worker process — the
serve layer's ``CacheStats.as_dict``), the admission-control gauges, and
decode latency off the frontend's per-instance
:class:`repro.obs.Histogram` instruments, then totals them fleet-wide.

Latency comes in TWO flavors per instance, both ``None`` (never a
crash) when the instance has zero flushes:

- ``decode_p50_ms`` / ``decode_p99_ms`` — EXACT percentiles over the
  recent flush window (the semantics this schema always had);
- ``decode_p50_ms_total`` / ``decode_p99_ms_total`` — all-time
  estimates from the histogram's fixed log buckets, which survive any
  amount of window wrap.

An instance whose transport dies MID-POLL (``stats()`` raises
``TransportError``) is demoted to the ``excluded`` list of the same
snapshot — one dead worker costs one instance's row, not the collect.
``excluded_total`` counts exclusions CUMULATIVELY (a rebalance that
retires the corpse shrinks ``excluded`` but never this), and
``collected_at`` stamps the snapshot on the monotonic clock — the two
signals a controller needs to reason about deaths and polling intervals.
Fleet-pooled ``decode_p50_ms``/``decode_p99_ms`` (exact, over the union
of instance windows) and the per-payload ``canary`` roll-up feed
``repro.obs.slo.fleet_slo_sample``.
``as_dict`` renders the snapshot JSON-able — the shape
``benchmarks/fleet_bench.py`` writes into ``BENCH_fleet.json``
(extended over time, never broken).
"""
from __future__ import annotations

import dataclasses
import time

from repro.fleet.frontend import FleetFrontend
from repro.fleet.transport import TransportError
from repro.serve.codec_service import PayloadCacheStats


@dataclasses.dataclass
class CacheCounters(PayloadCacheStats):
    """The serve layer's four cache counters plus roll-up helpers."""

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def add(self, other) -> None:
        self.hits += other.hits
        self.misses += other.misses
        self.evictions += other.evictions
        self.resident_bytes += other.resident_bytes

    @classmethod
    def of(cls, counters) -> "CacheCounters":
        if isinstance(counters, dict):  # a transport's wire snapshot
            return cls(
                counters["hits"],
                counters["misses"],
                counters["evictions"],
                counters["resident_bytes"],
            )
        return cls(counters.hits, counters.misses, counters.evictions,
                   counters.resident_bytes)


@dataclasses.dataclass
class InstanceMetrics:
    instance: str
    cache: CacheCounters
    per_payload: dict[str, CacheCounters]
    peak_inflight_bytes: int
    #: exact percentiles over the recent flush window; None if no flushes
    decode_p50_ms: float | None
    decode_p99_ms: float | None
    #: all-time bucket estimates (survive window wrap); None if no flushes
    decode_p50_ms_total: float | None
    decode_p99_ms_total: float | None
    flushes: int  # monotonic (all-time), matches the _total percentiles
    #: per-payload canary snapshot (checks/breaches/fitness) from the
    #: instance's serve-layer stats; empty for canary-off instances and
    #: old workers whose stats blob predates the key
    canary: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FleetMetrics:
    instances: dict[str, InstanceMetrics]
    fleet: CacheCounters            # totals across live instances
    per_payload: dict[str, CacheCounters]  # fleet totals by payload
    backpressure_flushes: int
    #: members whose transport died — still on the ring, not polled
    excluded: list[str] = dataclasses.field(default_factory=list)
    #: CUMULATIVE exclusion count — unlike ``excluded`` (current members
    #: only, shrinks when a rebalance retires the corpse) this never goes
    #: down, so a controller can tell a NEW death from a long-dead one
    excluded_total: int = 0
    #: monotonic-clock snapshot time — subtract two snapshots' values for
    #: a wall-immune polling interval
    collected_at: float = 0.0
    #: fleet-wide EXACT percentiles over the union of every live
    #: instance's recent flush window; None until anything flushed
    decode_p50_ms: float | None = None
    decode_p99_ms: float | None = None
    #: fleet canary roll-up by payload: summed checks/breaches, worst
    #: (minimum) rolling fitness across instances
    canary: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        def counters(c: CacheCounters) -> dict:
            return {
                "hits": c.hits, "misses": c.misses, "evictions": c.evictions,
                "resident_bytes": c.resident_bytes,
                "hit_rate": round(c.hit_rate, 4),
            }

        return {
            "fleet": counters(self.fleet),
            "per_payload": {k: counters(v) for k, v in self.per_payload.items()},
            "backpressure_flushes": self.backpressure_flushes,
            "excluded": list(self.excluded),
            "excluded_total": self.excluded_total,
            "collected_at": self.collected_at,
            "decode_p50_ms": self.decode_p50_ms,
            "decode_p99_ms": self.decode_p99_ms,
            "canary": self.canary,
            "instances": {
                iid: {
                    "cache": counters(m.cache),
                    "per_payload": {
                        k: counters(v) for k, v in m.per_payload.items()
                    },
                    "peak_inflight_bytes": m.peak_inflight_bytes,
                    "decode_p50_ms": m.decode_p50_ms,
                    "decode_p99_ms": m.decode_p99_ms,
                    "decode_p50_ms_total": m.decode_p50_ms_total,
                    "decode_p99_ms_total": m.decode_p99_ms_total,
                    "flushes": m.flushes,
                    "canary": m.canary,
                }
                for iid, m in self.instances.items()
            },
        }


def _ms(seconds: float | None) -> float | None:
    return None if seconds is None else round(seconds * 1e3, 4)


def _pooled_percentile(values: list[float], q: float) -> float | None:
    """Exact linear-interpolated percentile over pooled samples (same
    convention as ``Histogram.window_percentile``); None when empty."""
    if not values:
        return None
    vals = sorted(values)
    if len(vals) == 1:
        return vals[0]
    pos = q / 100.0 * (len(vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    return vals[lo] + (vals[hi] - vals[lo]) * (pos - lo)


def _rollup_canary(per_instance: dict[str, dict]) -> dict:
    """Fleet canary view by payload: total checks/breaches, worst
    (minimum) rolling fitness across the instances reporting one."""
    out: dict[str, dict] = {}
    for canary in per_instance.values():
        for payload, c in canary.items():
            agg = out.setdefault(
                payload,
                {"checks": 0, "breaches": 0, "rolling_fitness": None},
            )
            agg["checks"] += int(c.get("checks", 0))
            agg["breaches"] += int(c.get("breaches", 0))
            rf = c.get("rolling_fitness")
            if rf is not None and (
                agg["rolling_fitness"] is None or rf < agg["rolling_fitness"]
            ):
                agg["rolling_fitness"] = rf
    return out


def collect(fleet: FleetFrontend) -> FleetMetrics:
    instances: dict[str, InstanceMetrics] = {}
    fleet_total = CacheCounters()
    fleet_per_payload: dict[str, CacheCounters] = {}
    pooled_latency: list[float] = []
    for iid in fleet.instances():
        if iid in fleet.excluded:
            continue
        try:
            stats = fleet.transports[iid].stats()
        except TransportError as e:
            fleet.exclude(iid, e)
            continue
        cache = CacheCounters.of(stats)
        per_payload = {
            name: CacheCounters.of(p)
            for name, p in stats["per_payload"].items()
        }
        hist = fleet.latency_histogram(iid)
        pooled_latency.extend(hist.window_values())
        instances[iid] = InstanceMetrics(
            instance=iid,
            cache=cache,
            per_payload=per_payload,
            peak_inflight_bytes=fleet.peak_inflight_bytes(iid),
            decode_p50_ms=_ms(hist.window_percentile(50)),
            decode_p99_ms=_ms(hist.window_percentile(99)),
            decode_p50_ms_total=_ms(hist.percentile(50)),
            decode_p99_ms_total=_ms(hist.percentile(99)),
            flushes=hist.count,
            # .get: an old worker's stats blob predates the canary key
            canary=stats.get("canary") or {},
        )
        fleet_total.add(cache)
        for name, c in per_payload.items():
            fleet_per_payload.setdefault(name, CacheCounters()).add(c)
    return FleetMetrics(
        instances=instances,
        fleet=fleet_total,
        per_payload=fleet_per_payload,
        backpressure_flushes=fleet.backpressure_flushes,
        excluded=sorted(fleet.excluded),
        excluded_total=getattr(fleet, "exclusions_total", len(fleet.excluded)),
        collected_at=time.monotonic(),
        decode_p50_ms=_ms(_pooled_percentile(pooled_latency, 50)),
        decode_p99_ms=_ms(_pooled_percentile(pooled_latency, 99)),
        canary=_rollup_canary(
            {iid: m.canary for iid, m in instances.items() if m.canary}
        ),
    )
