"""Fleet-wide observability: roll up per-instance cache stats + latency.

``collect`` snapshots every live instance's cache stats through its
:class:`~repro.fleet.transport.Transport` (``stats()`` returns the same
JSON-able dict for an in-process service and a worker process — the
serve layer's ``CacheStats.as_dict``), the admission-control gauges, and
decode latency off the frontend's per-instance
:class:`repro.obs.Histogram` instruments, then totals them fleet-wide.

Latency comes in TWO flavors per instance, both ``None`` (never a
crash) when the instance has zero flushes:

- ``decode_p50_ms`` / ``decode_p99_ms`` — EXACT percentiles over the
  recent flush window (the semantics this schema always had);
- ``decode_p50_ms_total`` / ``decode_p99_ms_total`` — all-time
  estimates from the histogram's fixed log buckets, which survive any
  amount of window wrap.

An instance whose transport dies MID-POLL (``stats()`` raises
``TransportError``) is demoted to the ``excluded`` list of the same
snapshot — one dead worker costs one instance's row, not the collect.
``as_dict`` renders the snapshot JSON-able — the shape
``benchmarks/fleet_bench.py`` writes into ``BENCH_fleet.json``
(extended over time, never broken).
"""
from __future__ import annotations

import dataclasses

from repro.fleet.frontend import FleetFrontend
from repro.fleet.transport import TransportError
from repro.serve.codec_service import PayloadCacheStats


@dataclasses.dataclass
class CacheCounters(PayloadCacheStats):
    """The serve layer's four cache counters plus roll-up helpers."""

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def add(self, other) -> None:
        self.hits += other.hits
        self.misses += other.misses
        self.evictions += other.evictions
        self.resident_bytes += other.resident_bytes

    @classmethod
    def of(cls, counters) -> "CacheCounters":
        if isinstance(counters, dict):  # a transport's wire snapshot
            return cls(
                counters["hits"],
                counters["misses"],
                counters["evictions"],
                counters["resident_bytes"],
            )
        return cls(counters.hits, counters.misses, counters.evictions,
                   counters.resident_bytes)


@dataclasses.dataclass
class InstanceMetrics:
    instance: str
    cache: CacheCounters
    per_payload: dict[str, CacheCounters]
    peak_inflight_bytes: int
    #: exact percentiles over the recent flush window; None if no flushes
    decode_p50_ms: float | None
    decode_p99_ms: float | None
    #: all-time bucket estimates (survive window wrap); None if no flushes
    decode_p50_ms_total: float | None
    decode_p99_ms_total: float | None
    flushes: int  # monotonic (all-time), matches the _total percentiles


@dataclasses.dataclass
class FleetMetrics:
    instances: dict[str, InstanceMetrics]
    fleet: CacheCounters            # totals across live instances
    per_payload: dict[str, CacheCounters]  # fleet totals by payload
    backpressure_flushes: int
    #: members whose transport died — still on the ring, not polled
    excluded: list[str] = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        def counters(c: CacheCounters) -> dict:
            return {
                "hits": c.hits, "misses": c.misses, "evictions": c.evictions,
                "resident_bytes": c.resident_bytes,
                "hit_rate": round(c.hit_rate, 4),
            }

        return {
            "fleet": counters(self.fleet),
            "per_payload": {k: counters(v) for k, v in self.per_payload.items()},
            "backpressure_flushes": self.backpressure_flushes,
            "excluded": list(self.excluded),
            "instances": {
                iid: {
                    "cache": counters(m.cache),
                    "per_payload": {
                        k: counters(v) for k, v in m.per_payload.items()
                    },
                    "peak_inflight_bytes": m.peak_inflight_bytes,
                    "decode_p50_ms": m.decode_p50_ms,
                    "decode_p99_ms": m.decode_p99_ms,
                    "decode_p50_ms_total": m.decode_p50_ms_total,
                    "decode_p99_ms_total": m.decode_p99_ms_total,
                    "flushes": m.flushes,
                }
                for iid, m in self.instances.items()
            },
        }


def _ms(seconds: float | None) -> float | None:
    return None if seconds is None else round(seconds * 1e3, 4)


def collect(fleet: FleetFrontend) -> FleetMetrics:
    instances: dict[str, InstanceMetrics] = {}
    fleet_total = CacheCounters()
    fleet_per_payload: dict[str, CacheCounters] = {}
    for iid in fleet.instances():
        if iid in fleet.excluded:
            continue
        try:
            stats = fleet.transports[iid].stats()
        except TransportError as e:
            fleet.exclude(iid, e)
            continue
        cache = CacheCounters.of(stats)
        per_payload = {
            name: CacheCounters.of(p)
            for name, p in stats["per_payload"].items()
        }
        hist = fleet.latency_histogram(iid)
        instances[iid] = InstanceMetrics(
            instance=iid,
            cache=cache,
            per_payload=per_payload,
            peak_inflight_bytes=fleet.peak_inflight_bytes(iid),
            decode_p50_ms=_ms(hist.window_percentile(50)),
            decode_p99_ms=_ms(hist.window_percentile(99)),
            decode_p50_ms_total=_ms(hist.percentile(50)),
            decode_p99_ms_total=_ms(hist.percentile(99)),
            flushes=hist.count,
        )
        fleet_total.add(cache)
        for name, c in per_payload.items():
            fleet_per_payload.setdefault(name, CacheCounters()).add(c)
    return FleetMetrics(
        instances=instances,
        fleet=fleet_total,
        per_payload=fleet_per_payload,
        backpressure_flushes=fleet.backpressure_flushes,
        excluded=sorted(fleet.excluded),
    )
