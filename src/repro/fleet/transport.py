"""Pluggable fleet transports: in-process today, one OS process per pod.

PR 4's frontend fanned out to ``CodecService`` objects held in its own
process; this module puts a :class:`Transport` protocol between the
frontend and the instance so each fleet member can instead run as a
separate worker process (``python -m repro.fleet.worker``) that mmaps
the shared container-v3 file and owns one ``CodecService``.

Two implementations:

- :class:`LocalTransport` wraps an in-process ``CodecService`` — zero
  behavior change, zero serialization, what tests and single-host
  fleets use.
- :class:`SocketTransport` speaks a length-prefixed binary protocol
  (struct framing; arrays ride the container layer's
  ``write_array``/``read_array`` encoding so values stay bit-exact)
  over a TCP or Unix socket to one worker process.  ``submit`` frames
  are pipelined — no per-request round trip — and ``flush`` returns
  every outstanding request id with either its result array or its
  error, in request-id order, so the frontend's reassembly is identical
  to the in-process path.

Failure semantics: request-level errors on the worker (unknown payload,
decode failure) come back as :class:`RemoteError` entries in ``flush``'s
failure map — the instance stays routable.  A dead socket, truncated
frame, or per-request timeout raises :class:`TransportError` and marks
the transport dead; the frontend converts that into a routed
``excluded`` instance instead of a hang.

Wire format (little-endian)::

    frame    := u32 len | payload
    request  := u8 opcode | u64 rid | body
    response := u8 status | u64 rid | body     # status 0 ok, 1 error
    str      := u16 len | utf-8 bytes
    blob     := u32 len | bytes
    array    := container.write_array encoding (dtype | ndim | shape | raw)

Trace-context extension (all fields OPTIONAL and eof-guarded, so bodies
without them parse exactly as before)::

    OP_SUBMIT body  := str name | i64 version | array [| u64 tid | u64 sid]
    OP_FLUSH  body  := [u8 flags [| u64 tid | u64 sid]]   # see FLUSH_*
    OP_FLUSH  reply := ...results/failures... [| u8 has | span_block]
    span_block      := f64 sender_now | u32 n | span*
    span            := str name | u64 trace | u64 span | u64 parent
                       | f64 t0 | f64 t1 | str attrs_json

The (tid, sid) pair is the frontend's ambient trace context — the worker
adopts it so its ``CodecService`` stage spans parent under the
frontend's ``transport.flush`` span; the flush reply ships the worker's
drained spans back with the worker's own monotonic clock so the
frontend can re-base them onto ITS timeline (one stitched trace).
"""
from __future__ import annotations

import io
import json
import os
import shutil
import socket
import struct
import subprocess
import sys
import tempfile
import time
from typing import Protocol, runtime_checkable

import numpy as np

from repro import obs
from repro.codecs.container import read_array, write_array
from repro.serve.codec_service import CodecService, Ownership

# -- opcodes ----------------------------------------------------------------
(
    OP_PING,
    OP_LOAD,
    OP_UNLOAD,
    OP_SHAPE,
    OP_SUBMIT,
    OP_FLUSH,
    OP_STATS,
    OP_SET_OWNERSHIP,
    OP_EXPORT_TILES,
    OP_ADMIT_TILE,
    OP_DROP_UNOWNED,
    OP_PAYLOADS,
    OP_SHUTDOWN,
    OP_REFRESH,
    OP_EXPORT_CHUNK,
    OP_INJECT_FAULT,
) = range(16)

ST_OK, ST_ERROR = 0, 1

#: sanity bound on one frame — a length prefix past this is a framing bug
#: (or garbage on the socket), not a real payload
MAX_FRAME_BYTES = 1 << 31


class TransportError(ConnectionError):
    """The transport itself failed (dead worker, timeout, bad framing).
    The frontend reacts by excluding the instance from routing."""


class ProtocolError(TransportError):
    """The byte stream violated the framing rules — truncated frame,
    oversized length prefix, out-of-order response id."""


class RemoteError(RuntimeError):
    """An error raised BY the worker's service (unknown payload, decode
    failure) and shipped back over a healthy connection — the per-ticket
    failure analogue of a local exception, not a transport death."""


# ---------------------------------------------------------------------------
# framing helpers (shared by SocketTransport and repro.fleet.worker)
# ---------------------------------------------------------------------------
def send_frame(sock: socket.socket, payload: bytes) -> None:
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_frame(sock: socket.socket) -> bytes | None:
    """One length-prefixed frame; None on clean EOF at a frame boundary."""
    head = _recv_exact(sock, 4, eof_ok=True)
    if head is None:
        return None
    (n,) = struct.unpack("<I", head)
    if n > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame length {n} exceeds {MAX_FRAME_BYTES}")
    return _recv_exact(sock, n)


def _recv_exact(sock: socket.socket, n: int, eof_ok: bool = False) -> bytes | None:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if eof_ok and not buf:
                return None
            raise ProtocolError(f"truncated frame: got {len(buf)} of {n} bytes")
        buf += chunk
    return bytes(buf)


class Writer:
    """Body builder for one frame — mirrors :class:`Reader` field for field."""

    def __init__(self) -> None:
        self.buf = io.BytesIO()

    def u8(self, v: int) -> "Writer":
        self.buf.write(struct.pack("<B", v))
        return self

    def u16(self, v: int) -> "Writer":
        self.buf.write(struct.pack("<H", v))
        return self

    def u32(self, v: int) -> "Writer":
        self.buf.write(struct.pack("<I", v))
        return self

    def u64(self, v: int) -> "Writer":
        self.buf.write(struct.pack("<Q", v))
        return self

    def i64(self, v: int) -> "Writer":
        self.buf.write(struct.pack("<q", v))
        return self

    def f64(self, v: float) -> "Writer":
        self.buf.write(struct.pack("<d", v))
        return self

    def str(self, s: str) -> "Writer":
        raw = s.encode("utf-8")[:65535]
        self.buf.write(struct.pack("<H", len(raw)) + raw)
        return self

    def blob(self, raw: bytes) -> "Writer":
        self.buf.write(struct.pack("<I", len(raw)) + raw)
        return self

    def array(self, arr: np.ndarray) -> "Writer":
        write_array(self.buf, np.ascontiguousarray(arr))
        return self

    def bytes(self) -> bytes:
        return self.buf.getvalue()


class Reader:
    """Body parser for one frame; every read raises ProtocolError on
    truncation instead of returning short data."""

    def __init__(self, data: bytes) -> None:
        self.buf = io.BytesIO(data)

    def _take(self, n: int) -> bytes:
        raw = self.buf.read(n)
        if len(raw) < n:
            raise ProtocolError(f"truncated body: got {len(raw)} of {n} bytes")
        return raw

    def u8(self) -> int:
        return struct.unpack("<B", self._take(1))[0]

    def u16(self) -> int:
        return struct.unpack("<H", self._take(2))[0]

    def u32(self) -> int:
        return struct.unpack("<I", self._take(4))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self._take(8))[0]

    def i64(self) -> int:
        return struct.unpack("<q", self._take(8))[0]

    def f64(self) -> float:
        return struct.unpack("<d", self._take(8))[0]

    def eof(self) -> bool:
        """True at end of body — the guard for OPTIONAL trailing fields
        (how the trace-context extension stays wire-compatible)."""
        here = self.buf.tell()
        ahead = bool(self.buf.read(1))
        self.buf.seek(here)
        return not ahead

    def str(self) -> str:
        return self._take(self.u16()).decode("utf-8")

    def blob(self) -> bytes:
        return self._take(self.u32())

    def array(self) -> np.ndarray:
        try:
            return read_array(self.buf)
        except ValueError as e:  # container helper's truncation errors
            raise ProtocolError(str(e)) from None


def pack_ownership(w: Writer, ownership: Ownership | None) -> None:
    w.u8(0 if ownership is None else 1)
    if ownership is None:
        return
    for ids in (ownership.chunk_ids, ownership.tile_ids):
        w.u8(0 if ids is None else 1)
        if ids is not None:
            w.u32(len(ids))
            for i in sorted(ids):
                w.u64(i)


def unpack_ownership(r: Reader) -> Ownership | None:
    if not r.u8():
        return None
    sets: list[frozenset[int] | None] = []
    for _ in range(2):
        if r.u8():
            sets.append(frozenset(r.u64() for _ in range(r.u32())))
        else:
            sets.append(None)
    return Ownership(chunk_ids=sets[0], tile_ids=sets[1])


# -- trace-context / span block (flush-reply extension) ---------------------
#: OP_FLUSH body flag bits
FLUSH_WANT_SPANS = 1  # worker should drain its recorder into the reply
FLUSH_HAS_CTX = 2  # a (trace id, span id) pair follows the flags byte


def pack_spans(w: Writer, spans: list[obs.Span]) -> None:
    """Append a span block: ``f64 worker_now | u32 n | span*`` where one
    span is ``str name | u64 trace | u64 span | u64 parent | f64 t0 |
    f64 t1 | str attrs-json``.  ``worker_now`` is the sender's
    ``perf_counter`` AT PACK TIME — the receiver subtracts it from its
    own clock to re-base the timestamps (transit delay only shifts every
    span by the same small amount)."""
    w.f64(time.perf_counter())
    w.u32(len(spans))
    for s in spans:
        w.str(s.name)
        w.u64(s.trace_id).u64(s.span_id).u64(s.parent_id)
        w.f64(s.t_start).f64(s.t_end)
        w.str(json.dumps(s.attrs, default=str) if s.attrs else "")


def unpack_spans(r: Reader) -> tuple[float, list[obs.Span]]:
    """Inverse of :func:`pack_spans` -> (sender's clock, spans)."""
    sender_now = r.f64()
    spans = []
    for _ in range(r.u32()):
        name = r.str()
        tid, sid, pid = r.u64(), r.u64(), r.u64()
        t0, t1 = r.f64(), r.f64()
        raw = r.str()
        spans.append(obs.Span(name, tid, sid, pid, t0, t1,
                              json.loads(raw) if raw else {}))
    return sender_now, spans


def parse_address(address: str) -> tuple[int, str | tuple[str, int]]:
    """``unix:/path`` or ``tcp:host:port`` -> (socket family, connect arg)."""
    if address.startswith("unix:"):
        return socket.AF_UNIX, address[len("unix:"):]
    if address.startswith("tcp:"):
        host, _, port = address[len("tcp:"):].rpartition(":")
        if not host or not port.isdigit():
            raise ValueError(f"bad tcp address {address!r} (want tcp:host:port)")
        return socket.AF_INET, (host, int(port))
    raise ValueError(f"bad address {address!r} (want unix:/path or tcp:host:port)")


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------
@runtime_checkable
class Transport(Protocol):
    """What the fleet frontend, rebalancer, and metrics depend on — the
    full surface of one fleet member, location-transparent.

    ``submit`` returns a transport-local ticket and NEVER raises for a
    request-level problem (that failure arrives in ``flush``'s second
    return value, exactly once); it may raise :class:`TransportError`
    when the transport itself is dead.  ``flush`` resolves every
    outstanding ticket to either a result array or an exception.
    """

    instance_id: str

    def load_stream(self, name: str, path: str, *,
                    tile_entries: int | None = None) -> None: ...
    def unload(self, name: str) -> None: ...
    def payloads(self) -> list[str]: ...
    def shape_of(self, name: str) -> tuple[int, ...]: ...
    def submit(
        self, name: str, indices: np.ndarray, version: int | None = None
    ) -> int: ...
    def flush(self) -> tuple[dict[int, np.ndarray], dict[int, Exception]]: ...
    def drain(self) -> None: ...
    def stats(self) -> dict: ...
    def set_ownership(self, name: str, ownership: Ownership | None) -> None: ...
    def export_tiles(self, name: str) -> dict[int, np.ndarray]: ...
    def admit_tile(self, name: str, tid: int, values: np.ndarray) -> bool: ...
    def drop_unowned(self, name: str) -> int: ...
    def refresh(self, name: str) -> None: ...
    def export_chunk(self, name: str, chunk: int) -> bytes | None: ...
    def inject_fault(self, name: str, fault: dict) -> None: ...
    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# in-process
# ---------------------------------------------------------------------------
class LocalTransport:
    """The PR-4 fan-out path behind the new protocol: one in-process
    ``CodecService``, no serialization, tests stay fast."""

    def __init__(
        self,
        instance_id: str = "local",
        service: CodecService | None = None,
        *,
        cache_bytes: int | None = None,
        max_batch: int = 65536,
        prefetch: bool = False,
        canary_fraction: float = 0.0,
        canary_seed: int = 0,
        canary_min_fitness: float | None = None,
    ):
        self.instance_id = instance_id
        self.service = service or CodecService(
            max_batch=max_batch, cache_bytes=cache_bytes, prefetch=prefetch,
            canary_fraction=canary_fraction, canary_seed=canary_seed,
            canary_min_fitness=canary_min_fitness,
        )
        self._next_rid = 0
        self._pending: dict[int, int] = {}  # rid -> service ticket
        self._deferred: dict[int, Exception] = {}  # rid -> submit-time error

    def load_stream(self, name, path, *, tile_entries=None) -> None:
        self.service.load_stream(name, path, tile_entries=tile_entries)

    def unload(self, name) -> None:
        self.service.unload(name)

    def payloads(self) -> list[str]:
        return self.service.payloads()

    def shape_of(self, name) -> tuple[int, ...]:
        return self.service.shape_of(name)

    def submit(self, name, indices, version=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        try:
            self._pending[rid] = self.service.submit(name, indices, version=version)
        except Exception as e:  # noqa: BLE001 — deferred, mirrors the wire
            self._deferred[rid] = e
        return rid

    def flush(self) -> tuple[dict[int, np.ndarray], dict[int, Exception]]:
        out = self.service.flush()
        failures = self._deferred
        self._deferred = {}
        results: dict[int, np.ndarray] = {}
        for rid, ticket in self._pending.items():
            if ticket in out:
                results[rid] = out[ticket]
            else:
                failures[rid] = self.service.failed.get(
                    ticket, RuntimeError("ticket vanished")
                )
        self._pending = {}
        return results, failures

    def drain(self) -> None:
        self.flush()

    def stats(self) -> dict:
        return self.service.stats()

    def set_ownership(self, name, ownership) -> None:
        self.service.set_ownership(name, ownership)

    def export_tiles(self, name) -> dict[int, np.ndarray]:
        return self.service.export_tiles(name)

    def admit_tile(self, name, tid, values) -> bool:
        return self.service.admit_tile(name, tid, values)

    def drop_unowned(self, name) -> int:
        return self.service.drop_unowned(name)

    def refresh(self, name) -> None:
        self.service.refresh(name)

    def export_chunk(self, name, chunk) -> bytes | None:
        return self.service.export_chunk(name, chunk)

    def inject_fault(self, name, fault) -> None:
        self.service.inject_fault(name, fault)

    def close(self) -> None:
        for name in list(self.service.payloads()):
            self.service.unload(name)


# ---------------------------------------------------------------------------
# cross-process
# ---------------------------------------------------------------------------
class SocketTransport:
    """One fleet member behind a TCP/Unix socket.

    ``submit`` writes a pipelined frame (no response until flush);
    every synchronous verb is one request/response round trip whose
    response must echo the request id — an out-of-order or truncated
    response is a :class:`ProtocolError`, and any transport-level
    failure marks the transport dead so every later call fails fast
    instead of hanging on a half-closed socket.
    """

    def __init__(
        self,
        instance_id: str,
        address: str,
        *,
        timeout: float = 30.0,
        connect_timeout: float = 60.0,
        retry_delay: float = 0.1,
        proc: subprocess.Popen | None = None,
    ):
        self.instance_id = instance_id
        self.address = address
        self.timeout = timeout
        self._proc = proc
        self._dead: TransportError | None = None
        self._pending: list[int] = []
        self._next_rid = 0
        #: temp dir spawn() created for the default Unix socket — removed
        #: by close() (the worker only unlinks the socket file itself)
        self._owned_dir: str | None = None
        self._sock = self._connect(connect_timeout, retry_delay)

    # -- connection ---------------------------------------------------------
    def _connect(self, connect_timeout: float, retry_delay: float) -> socket.socket:
        """Retry until the worker is listening (it may still be importing
        jax) or the deadline passes; a worker that already exited fails
        immediately with its return code instead of burning the deadline."""
        family, addr = parse_address(self.address)
        deadline = time.monotonic() + connect_timeout
        last: Exception | None = None
        while True:
            if self._proc is not None and self._proc.poll() is not None:
                raise TransportError(
                    f"{self.instance_id}: worker exited with code "
                    f"{self._proc.returncode} before accepting a connection"
                )
            sock = socket.socket(family, socket.SOCK_STREAM)
            sock.settimeout(self.timeout)
            try:
                sock.connect(addr)
                return sock
            except (ConnectionError, FileNotFoundError, socket.timeout, OSError) as e:
                sock.close()
                last = e
                if time.monotonic() >= deadline:
                    raise TransportError(
                        f"{self.instance_id}: could not connect to "
                        f"{self.address} within {connect_timeout}s: {last}"
                    ) from None
                time.sleep(retry_delay)

    def _die(self, err: Exception) -> TransportError:
        self._dead = (
            err
            if isinstance(err, TransportError)
            else TransportError(f"{self.instance_id}: {err}")
        )
        try:
            self._sock.close()
        except OSError:
            pass
        raise self._dead

    def _send(self, op: int, rid: int, body: bytes = b"") -> None:
        if self._dead is not None:
            raise self._dead
        try:
            send_frame(self._sock, struct.pack("<BQ", op, rid) + body)
        except (OSError, ValueError) as e:
            self._die(e)

    def _recv_response(self, rid: int) -> Reader:
        try:
            payload = recv_frame(self._sock)
        except socket.timeout:
            self._die(
                TransportError(
                    f"{self.instance_id}: request timed out after "
                    f"{self.timeout}s — worker presumed dead"
                )
            )
        except (OSError, ProtocolError) as e:
            self._die(e)
        if payload is None:
            self._die(TransportError(f"{self.instance_id}: worker closed the connection"))
        if len(payload) < 9:
            self._die(ProtocolError(f"{self.instance_id}: short response frame"))
        status, got = struct.unpack("<BQ", payload[:9])
        if got != rid:
            self._die(
                ProtocolError(
                    f"{self.instance_id}: response id {got} != request id {rid}"
                )
            )
        r = Reader(payload[9:])
        if status == ST_ERROR:
            raise RemoteError(r.str())
        return r

    def _request(self, op: int, body: bytes = b"") -> Reader:
        rid = self._next_rid
        self._next_rid += 1
        self._send(op, rid, body)
        return self._recv_response(rid)

    # -- spawning -----------------------------------------------------------
    @classmethod
    def spawn(
        cls,
        instance_id: str,
        *,
        cache_bytes: int | None = None,
        max_batch: int = 65536,
        timeout: float = 30.0,
        connect_timeout: float = 120.0,
        address: str | None = None,
        python: str | None = None,
        prefetch: bool = False,
        canary_fraction: float = 0.0,
        canary_seed: int = 0,
        canary_min_fitness: float | None = None,
        debug_flush_sleep_ms: float = 0.0,
        debug_corrupt_chunk: list[str] | None = None,
        debug_fitness_noise: list[str] | None = None,
    ) -> "SocketTransport":
        """Launch ``python -m repro.fleet.worker`` as a child process and
        connect to it.  Default address is a Unix socket in a fresh temp
        dir; pass ``tcp:host:port`` to cross machines.  The returned
        transport owns the process — ``close()`` shuts it down.
        ``debug_flush_sleep_ms`` (latency), ``debug_corrupt_chunk``
        (``NAME:CHUNK`` entries) and ``debug_fitness_noise``
        (``NAME:LO:HI:SIGMA[:SEED]`` entries) are the worker's fault
        injectors for SLO/repair drills; leave unset outside tests."""
        sock_dir = None
        if address is None:
            sock_dir = tempfile.mkdtemp(prefix="repro-fleet-")
            address = f"unix:{os.path.join(sock_dir, instance_id + '.sock')}"
        src_root = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        cmd = [
            python or sys.executable,
            "-m",
            "repro.fleet.worker",
            "--listen",
            address,
            "--max-batch",
            str(max_batch),
        ]
        if cache_bytes is not None:
            cmd += ["--cache-bytes", str(cache_bytes)]
        if prefetch:
            cmd += ["--prefetch"]
        if canary_fraction:
            cmd += ["--canary-fraction", str(canary_fraction)]
        if canary_seed:
            cmd += ["--canary-seed", str(canary_seed)]
        if canary_min_fitness is not None:
            cmd += ["--canary-min-fitness", str(canary_min_fitness)]
        if debug_flush_sleep_ms:
            cmd += ["--debug-flush-sleep-ms", str(debug_flush_sleep_ms)]
        for spec in debug_corrupt_chunk or []:
            cmd += ["--debug-corrupt-chunk", spec]
        for spec in debug_fitness_noise or []:
            cmd += ["--debug-fitness-noise", spec]
        proc = subprocess.Popen(cmd, env=env)
        try:
            t = cls(
                instance_id,
                address,
                timeout=timeout,
                connect_timeout=connect_timeout,
                proc=proc,
            )
        except TransportError:
            if proc.poll() is None:
                proc.kill()
                proc.wait()
            if sock_dir is not None:
                shutil.rmtree(sock_dir, ignore_errors=True)
            raise
        t._owned_dir = sock_dir
        return t

    # -- protocol verbs -----------------------------------------------------
    def ping(self) -> None:
        self._request(OP_PING)

    def load_stream(self, name, path, *, tile_entries=None) -> None:
        body = (
            Writer()
            .str(name)
            .str(os.path.abspath(path))
            .i64(-1 if tile_entries is None else int(tile_entries))
            .bytes()
        )
        self._request(OP_LOAD, body)

    def unload(self, name) -> None:
        self._request(OP_UNLOAD, Writer().str(name).bytes())

    def payloads(self) -> list[str]:
        r = self._request(OP_PAYLOADS)
        return [r.str() for _ in range(r.u16())]

    def shape_of(self, name) -> tuple[int, ...]:
        r = self._request(OP_SHAPE, Writer().str(name).bytes())
        return tuple(r.u64() for _ in range(r.u8()))

    def submit(self, name, indices, version=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        w = (
            Writer()
            .str(name)
            .i64(-1 if version is None else int(version))
            .array(np.asarray(indices))
        )
        if obs.enabled():
            ctx = obs.current_context()
            if ctx is not None:
                w.u64(ctx[0]).u64(ctx[1])
        self._send(OP_SUBMIT, rid, w.bytes())
        self._pending.append(rid)
        return rid

    def flush(self) -> tuple[dict[int, np.ndarray], dict[int, Exception]]:
        pending, self._pending = self._pending, []
        w, want_spans = Writer(), False
        flags = 0
        if obs.enabled():
            want_spans = True
            flags |= FLUSH_WANT_SPANS
            ctx = obs.current_context()
            if ctx is not None:
                flags |= FLUSH_HAS_CTX
        w.u8(flags)
        if flags & FLUSH_HAS_CTX:
            w.u64(ctx[0]).u64(ctx[1])
        r = self._request(OP_FLUSH, w.bytes())
        results: dict[int, np.ndarray] = {}
        failures: dict[int, Exception] = {}
        for _ in range(r.u32()):
            rid = r.u64()
            results[rid] = r.array()
        for _ in range(r.u32()):
            rid = r.u64()
            failures[rid] = RemoteError(r.str())
        for rid in pending:  # worker must answer every submitted rid
            if rid not in results and rid not in failures:
                failures[rid] = RemoteError(
                    f"{self.instance_id}: ticket vanished on worker"
                )
        if want_spans and not r.eof() and r.u8():
            worker_now, spans = unpack_spans(r)
            obs.get_recorder().ingest(
                spans,
                clock_offset=time.perf_counter() - worker_now,
                instance=self.instance_id,
            )
        return results, failures

    def drain(self) -> None:
        if self._pending:
            self.flush()

    def stats(self) -> dict:
        return json.loads(self._request(OP_STATS).blob().decode("utf-8"))

    def set_ownership(self, name, ownership) -> None:
        w = Writer().str(name)
        pack_ownership(w, ownership)
        self._request(OP_SET_OWNERSHIP, w.bytes())

    def export_tiles(self, name) -> dict[int, np.ndarray]:
        r = self._request(OP_EXPORT_TILES, Writer().str(name).bytes())
        return {r.u64(): r.array() for _ in range(r.u32())}

    def admit_tile(self, name, tid, values) -> bool:
        body = Writer().str(name).u64(int(tid)).array(np.asarray(values)).bytes()
        return bool(self._request(OP_ADMIT_TILE, body).u8())

    def drop_unowned(self, name) -> int:
        return self._request(OP_DROP_UNOWNED, Writer().str(name).bytes()).u64()

    def refresh(self, name) -> None:
        self._request(OP_REFRESH, Writer().str(name).bytes())

    def export_chunk(self, name, chunk) -> bytes | None:
        body = Writer().str(name).u64(int(chunk)).bytes()
        r = self._request(OP_EXPORT_CHUNK, body)
        return r.blob() if r.u8() else None

    def inject_fault(self, name, fault) -> None:
        body = Writer().str(name).blob(
            json.dumps(fault).encode("utf-8")
        ).bytes()
        self._request(OP_INJECT_FAULT, body)

    def close(self) -> None:
        if self._dead is None:
            try:
                self._request(OP_SHUTDOWN)
            except (TransportError, RemoteError):
                pass
        try:
            self._sock.close()
        except OSError:
            pass
        if self._proc is not None:
            if self._proc.poll() is None:
                self._proc.terminate()
                try:
                    self._proc.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    self._proc.kill()
                    self._proc.wait()
            self._proc = None
        if self._owned_dir is not None:
            shutil.rmtree(self._owned_dir, ignore_errors=True)
            self._owned_dir = None
        if self._dead is None:
            self._dead = TransportError(f"{self.instance_id}: transport closed")
