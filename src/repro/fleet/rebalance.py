"""Warm scale-up / scale-down: change the ring without failing a ticket.

The sequence for any membership change:

1. ``drain()`` — barrier: every queued fleet ticket is resolved under the
   OLD ownership epoch, so no in-flight ticket can land on a departed
   instance or a not-yet-owning one.
2. Mutate the ring (add instances after ``spawn_instance`` so a joiner
   can serve the moment it owns anything; removals leave the ring first).
3. Ownership moves chunk-by-chunk: for every payload the per-instance
   filters are recomputed from the new ring and re-installed; the report
   records exactly which chunks and tiles changed hands.
4. Warm handoff (``warm=True``): decode tiles whose ownership moved are
   copied from the old owner's cache into the new owner's (through the
   byte-budgeted ``admit_tile`` path) before the old owner drops them —
   a scale-up starts with a warm cache instead of a miss storm.  Across
   processes the tiles ride the transport's array encoding, so a socket
   fleet warms exactly like an in-process one.
5. Evicted owners drop cache bytes under the existing LRU accounting
   (``drop_unowned``), and departed instances are retired (payloads
   unloaded, worker processes shut down).

Everything goes through the :class:`~repro.fleet.transport.Transport`
protocol.  A member whose transport died (``fleet.excluded``) neither
contributes warm tiles nor receives any — removing it through
``rebalance(fleet, remove=[iid])`` is how a dead worker leaves the fleet
for real.
"""
from __future__ import annotations

import dataclasses

from repro.fleet.frontend import FleetFrontend
from repro.fleet.transport import TransportError


@dataclasses.dataclass
class RebalanceReport:
    added: list[str]
    removed: list[str]
    #: payload -> number of chunk ids whose owner set changed
    chunks_moved: dict[str, int]
    #: payload -> number of decode tiles whose owner set changed
    tiles_moved: dict[str, int]
    #: payload -> tiles warm-copied into a new owner's cache
    tiles_warmed: dict[str, int]
    #: bytes freed by evicted owners dropping unowned cache state
    bytes_dropped: int

    @property
    def total_moved(self) -> int:
        return sum(self.chunks_moved.values()) + sum(self.tiles_moved.values())


def _ownership_snapshot(
    fleet: FleetFrontend,
) -> dict[str, dict[str, tuple[frozenset, frozenset]]]:
    """payload -> instance -> (owned chunk ids, owned tile ids) — one
    ring enumeration per payload (``PayloadRoute.ownership_tables``)."""
    snap: dict[str, dict[str, tuple[frozenset, frozenset]]] = {}
    for name, route in fleet.routes.items():
        chunk_tbl, tile_tbl = route.ownership_tables(fleet.ring)
        snap[name] = {
            iid: (chunk_tbl[iid], tile_tbl[iid]) for iid in fleet.ring.instances
        }
    return snap


def rebalance(
    fleet: FleetFrontend,
    *,
    add: list[str] | tuple[str, ...] = (),
    remove: list[str] | tuple[str, ...] = (),
    warm: bool = True,
) -> RebalanceReport:
    """Apply a membership change; see the module docstring for semantics."""
    add, remove = list(add), list(remove)
    for iid in add:
        if iid in fleet.transports:
            raise ValueError(f"cannot add {iid!r}: already in the fleet")
    for iid in remove:
        if iid not in fleet.transports:
            raise KeyError(f"cannot remove {iid!r}: not in the fleet")
    if set(fleet.transports) - set(remove) | set(add) == set():
        raise ValueError("rebalance would leave an empty fleet")

    # 1. barrier — in-flight tickets resolve under the old epoch
    fleet.drain()
    before = _ownership_snapshot(fleet)

    # warm-handoff source: cached tiles of every current live instance
    # (the departing ones' caches are exactly what must not go cold)
    tile_cache: dict[str, dict[int, object]] = {}
    if warm:
        for name, route in fleet.routes.items():
            if not route.tiled:
                continue
            merged: dict[int, object] = {}
            for iid, t in fleet.transports.items():
                if iid in fleet.excluded:
                    continue  # a dead worker's cache is unreadable
                try:
                    merged.update(t.export_tiles(name))
                except TransportError as e:
                    fleet.exclude(iid, e)
            tile_cache[name] = merged

    # 2. ring mutation — spawn joiners first so they can serve immediately
    for iid in add:
        fleet.spawn_instance(iid)
        fleet.ring.add(iid)
    for iid in remove:
        fleet.ring.remove(iid)

    # 3. chunk-by-chunk ownership movement
    after = _ownership_snapshot(fleet)
    chunks_moved: dict[str, int] = {}
    tiles_moved: dict[str, int] = {}
    for name, route in fleet.routes.items():
        old_chunk_owner = _owner_map(before.get(name, {}), 0)
        new_chunk_owner = _owner_map(after.get(name, {}), 0)
        chunks_moved[name] = sum(
            1 for c in range(route.n_chunks)
            if old_chunk_owner.get(c) != new_chunk_owner.get(c)
        )
        if route.tiled:
            old_tile_owner = _owner_map(before.get(name, {}), 1)
            new_tile_owner = _owner_map(after.get(name, {}), 1)
            tiles_moved[name] = sum(
                1 for t in range(route.n_tiles)
                if old_tile_owner.get(t) != new_tile_owner.get(t)
            )
        fleet.apply_ownership(name)

    # 4. warm handoff into owners the tile GAINED (before old owners
    # drop) — stationary tiles are neither re-admitted (that would reset
    # their LRU recency) nor counted
    tiles_warmed: dict[str, int] = {}
    if warm:
        for name, cached in tile_cache.items():
            old_owner = _owner_map(before.get(name, {}), 1)
            new_owner = _owner_map(after.get(name, {}), 1)
            route = fleet.routes[name]
            n = 0
            for tid, values in cached.items():
                # versioned payloads export COMPOSITE tile ids
                # (version * n_tiles + tile); ownership rides on the base
                # tile, so all versions of a tile move together
                base = tid % route.n_tiles if route.versioned else tid
                gained = new_owner.get(base, frozenset()) - old_owner.get(
                    base, frozenset()
                )
                for iid in gained:
                    if iid in fleet.excluded:
                        continue
                    try:
                        if fleet.transports[iid].admit_tile(name, tid, values):
                            n += 1
                    except TransportError as e:
                        fleet.exclude(iid, e)
            tiles_warmed[name] = n

    # 5. evicted owners drop cache bytes; departed instances retire
    bytes_dropped = 0
    for name in fleet.routes:
        for iid in list(fleet.ring.instances):
            if iid in fleet.excluded:
                continue
            try:
                bytes_dropped += fleet.transports[iid].drop_unowned(name)
            except TransportError as e:
                fleet.exclude(iid, e)
    for iid in remove:
        fleet.retire_instance(iid)

    return RebalanceReport(
        added=add,
        removed=remove,
        chunks_moved=chunks_moved,
        tiles_moved=tiles_moved,
        tiles_warmed=tiles_warmed,
        bytes_dropped=bytes_dropped,
    )


def _owner_map(
    per_instance: dict[str, tuple[frozenset, frozenset]], slot: int
) -> dict[int, frozenset]:
    """id -> frozenset of owning instances, from an ownership snapshot."""
    owners: dict[int, set[str]] = {}
    for iid, sets in per_instance.items():
        for ident in sets[slot]:
            owners.setdefault(ident, set()).add(iid)
    return {k: frozenset(v) for k, v in owners.items()}
