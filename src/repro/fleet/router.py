"""Consistent-hash routing of payload chunks and decode tiles.

The unit of ownership is a ring KEY: one per container chunk
(``name/c<i>``) and, for payloads served through the decode-tile cache,
one per tile (``name/t<tid>``).  A :class:`HashRing` hashes instance ids
onto a ring with virtual nodes; a key's owners are the first R distinct
instances clockwise from the key's point, so adding or removing one
instance moves only the keys whose owner arc it occupied — the property
that makes fleet rebalancing chunk-by-chunk instead of all-at-once.

:class:`PayloadRoute` is the payload-side half: built from a container's
chunk index (``repro.codecs.container.chunk_index``), it maps a batch of
query indices onto ring keys — by decode tile when ``tile_entries`` is
set, else by the chunk whose recorded entry range covers the query's
flat index (uniform partition when the file predates entry ranges).
"""
from __future__ import annotations

import bisect
import hashlib

import numpy as np

from repro.codecs import container
from repro.codecs.indexing import multi_to_flat


def _hash(key: str) -> int:
    """Stable 64-bit point on the ring (independent of PYTHONHASHSEED)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode(), digest_size=8).digest(), "big"
    )


class HashRing:
    """Consistent-hash ring over instance ids with virtual nodes."""

    def __init__(
        self,
        instances: tuple[str, ...] | list[str] = (),
        *,
        vnodes: int = 64,
        replication: int = 1,
    ):
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1, got {vnodes}")
        if replication < 1:
            raise ValueError(f"replication must be >= 1, got {replication}")
        self.vnodes = vnodes
        self.replication = replication
        self._points: list[tuple[int, str]] = []  # sorted (hash, instance)
        self._instances: set[str] = set()
        for iid in instances:
            self.add(iid)

    @property
    def instances(self) -> list[str]:
        return sorted(self._instances)

    def __len__(self) -> int:
        return len(self._instances)

    def __contains__(self, iid: str) -> bool:
        return iid in self._instances

    def add(self, iid: str) -> None:
        if iid in self._instances:
            raise ValueError(f"instance {iid!r} already on the ring")
        self._instances.add(iid)
        for v in range(self.vnodes):
            bisect.insort(self._points, (_hash(f"{iid}#{v}"), iid))

    def remove(self, iid: str) -> None:
        if iid not in self._instances:
            raise KeyError(f"instance {iid!r} not on the ring")
        self._instances.discard(iid)
        self._points = [p for p in self._points if p[1] != iid]

    def owners(self, key: str, r: int | None = None) -> list[str]:
        """The first ``r`` (default: replication factor) DISTINCT instances
        clockwise from the key's ring point, primary first."""
        if not self._points:
            raise RuntimeError("hash ring is empty")
        r = self.replication if r is None else r
        r = min(r, len(self._instances))
        start = bisect.bisect_left(self._points, (_hash(key), ""))
        out: list[str] = []
        for i in range(len(self._points)):
            iid = self._points[(start + i) % len(self._points)][1]
            if iid not in out:
                out.append(iid)
                if len(out) == r:
                    break
        return out

    def owner(self, key: str) -> str:
        return self.owners(key, 1)[0]


class PayloadRoute:
    """Query-index -> ring-key mapping for one chunked payload."""

    def __init__(
        self,
        name: str,
        shape: tuple[int, ...],
        chunks: list[container.ChunkEntry],
        tile_entries: int | None = None,
        versions: list[container.VersionEntry] | None = None,
    ):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.n_entries = int(np.prod(self.shape))
        self.tile_entries = int(tile_entries) if tile_entries else None
        self.n_chunks = len(chunks)
        self.versions = list(versions) if versions is not None else None
        if not chunks:
            raise ValueError(f"payload {name!r} has no chunks to route")
        if self.versions is not None:
            # one chunk-start table per version: entry ranges restart at 0
            # for every version's chunk run, so queries route to ABSOLUTE
            # chunk ids via the version's own table
            self._chunk_starts = None
            self._version_starts = [
                self._starts_for(chunks[v.chunk_start : v.chunk_stop])
                for v in self.versions
            ]
        else:
            self._chunk_starts = self._starts_for(chunks)

    def _starts_for(self, chunks: list[container.ChunkEntry]) -> np.ndarray:
        """Entry-start table for one contiguous chunk run, validated to
        partition [0, n_entries); uniform split for legacy files."""
        if all(c.entry_start is not None for c in chunks):
            starts = [c.entry_start for c in chunks]
            stops = [c.entry_stop for c in chunks]
            if starts != sorted(starts) or starts[0] != 0 or any(
                a != b for a, b in zip(starts[1:], stops[:-1])
            ) or stops[-1] != self.n_entries:
                raise ValueError(
                    f"payload {self.name!r}: recorded entry ranges do not "
                    f"partition [0, {self.n_entries})"
                )
            return np.asarray(starts, dtype=np.int64)
        # legacy file without recorded ranges: uniform partition
        return (
            np.arange(len(chunks), dtype=np.int64)
            * self.n_entries
            // len(chunks)
        )

    @property
    def n_tiles(self) -> int:
        if not self.tile_entries:
            return 0
        return -(-self.n_entries // self.tile_entries)

    @property
    def tiled(self) -> bool:
        return self.tile_entries is not None

    @property
    def versioned(self) -> bool:
        return self.versions is not None

    @property
    def n_versions(self) -> int:
        return len(self.versions) if self.versions is not None else 0

    # -- index space ---------------------------------------------------------
    def flat(self, indices: np.ndarray) -> np.ndarray:
        return multi_to_flat(indices, self.shape)

    def chunk_of(self, flat: np.ndarray, version: int | None = None) -> np.ndarray:
        """ABSOLUTE chunk id whose entry range covers each flat index —
        for versioned payloads, within ``version``'s chunk run (default:
        latest), so every version's queries key distinct ring points."""
        if self.versions is not None:
            v = len(self.versions) - 1 if version is None else int(version)
            ve = self.versions[v]
            return ve.chunk_start + (
                np.searchsorted(self._version_starts[v], flat, side="right") - 1
            )
        return np.searchsorted(self._chunk_starts, flat, side="right") - 1

    def tile_of(self, flat: np.ndarray) -> np.ndarray:
        return flat // self.tile_entries

    def group_of(self, flat: np.ndarray, version: int | None = None) -> np.ndarray:
        """The ownership-group id per flat index: tile when tiled (fine-
        grained sharding, deliberately VERSION-INDEPENDENT so all versions
        of a tile share one owner and base tiles are reused), else the
        version's covering chunk."""
        return self.tile_of(flat) if self.tiled else self.chunk_of(flat, version)

    # -- ring keys -----------------------------------------------------------
    def chunk_key(self, cid: int) -> str:
        return f"{self.name}/c{int(cid)}"

    def tile_key(self, tid: int) -> str:
        return f"{self.name}/t{int(tid)}"

    def group_key(self, gid: int) -> str:
        return self.tile_key(gid) if self.tiled else self.chunk_key(gid)

    # -- ownership -----------------------------------------------------------
    def owner_maps(
        self, ring: HashRing
    ) -> tuple[dict[int, list[str]], dict[int, list[str]]]:
        """Enumerate the ring ONCE for this payload: chunk id -> replica
        list and tile id -> replica list (primary first; tiles empty when
        untiled).  One pass costs n_chunks + n_tiles ring lookups total —
        the single source every ownership view derives from."""
        chunk_owners = {
            c: ring.owners(self.chunk_key(c)) for c in range(self.n_chunks)
        }
        tile_owners = (
            {t: ring.owners(self.tile_key(t)) for t in range(self.n_tiles)}
            if self.tiled
            else {}
        )
        return chunk_owners, tile_owners

    def ownership_tables(
        self,
        ring: HashRing,
        maps: tuple[dict[int, list[str]], dict[int, list[str]]] | None = None,
    ) -> tuple[dict[str, frozenset[int]], dict[str, frozenset[int]]]:
        """Invert :meth:`owner_maps`: instance id -> owned chunk ids, and
        instance id -> owned tile ids.  Pass ``maps`` to reuse an
        enumeration already paid for; the resulting sets make every later
        ownership decision (decode-tile caching, drop_unowned, rebalance
        diffs) a set lookup instead of a fresh hash + ring scan."""
        chunk_owners, tile_owners = (
            self.owner_maps(ring) if maps is None else maps
        )
        chunks: dict[str, set[int]] = {iid: set() for iid in ring.instances}
        tiles: dict[str, set[int]] = {iid: set() for iid in ring.instances}
        for c, own in chunk_owners.items():
            for iid in own:
                chunks[iid].add(c)
        for t, own in tile_owners.items():
            for iid in own:
                tiles[iid].add(t)
        return (
            {iid: frozenset(s) for iid, s in chunks.items()},
            {iid: frozenset(s) for iid, s in tiles.items()},
        )
