"""Multi-pod serving of chunked codec payloads.

The paper's serving story — any entry reconstructible in logarithmic
time — makes compressed payloads directly servable, but one
``CodecService`` is bounded by one machine's RAM and one process's
decode throughput.  ``repro.fleet`` runs N instances as a single
logical service:

    from repro.fleet import FleetFrontend, rebalance, collect

    fleet = FleetFrontend(4, cache_bytes=1 << 24, replication=1)
    fleet.load_stream("embed", "embed.tcdc", tile_entries=4096)
    fleet.decode_at("embed", idx)       # bit-identical to one instance

    rebalance(fleet, remove=["i3"])     # drain -> move chunks -> evict
    collect(fleet).as_dict()            # fleet-wide cache + latency roll-up

``controller`` closes the loop: a :class:`FleetController` polls
``collect()`` against declarative SLOs (``repro.obs.slo``) and calls
``rebalance`` itself — sustained p99 breach admits a standby, sustained
idle retires one, with hysteresis + cooldown so it cannot flap.

Every instance mmaps the same container-v3 file; a consistent-hash ring
(``router``) over the file's chunk index entries decides which instances
own a payload — only owners materialize its body — and, when
``tile_entries`` is set, which instance caches which decode tiles, so
resident cache bytes shard across the fleet (with a configurable
replication factor for hot chunks).  The frontend splits each query
batch by owner, fans out through
the per-instance ``submit``/``flush`` coalescing path under an in-flight
byte budget (backpressure, not unbounded queues), and reassembles
results in request order.  ``rebalance`` changes ring membership behind
a drain barrier so zero in-flight tickets are lost, with a warm tile
handoff so scale-up does not start from a cold cache.

Members are location-transparent (``transport``): ``LocalTransport``
wraps an in-process ``CodecService``; ``SocketTransport`` speaks a
length-prefixed binary protocol to a ``repro.fleet.worker`` OS process,
so the same fleet spans processes —

    fleet = FleetFrontend(
        ["w0", "w1"], transport_factory=lambda iid: SocketTransport.spawn(iid)
    )

— with identical (bit-exact) answers; a dead worker becomes a routed
``excluded`` instance instead of a hang.
"""
from repro.fleet.controller import (
    ControllerConfig,
    Decision,
    FleetController,
    ScalingPolicy,
)
from repro.fleet.frontend import FleetFrontend
from repro.fleet.metrics import CacheCounters, FleetMetrics, InstanceMetrics, collect
from repro.fleet.rebalance import RebalanceReport, rebalance
from repro.fleet.repair import (
    RepairConfig,
    RepairController,
    RepairReport,
    RepairTicket,
)
from repro.fleet.router import HashRing, PayloadRoute
from repro.fleet.transport import (
    LocalTransport,
    RemoteError,
    SocketTransport,
    Transport,
    TransportError,
)

__all__ = [
    "CacheCounters",
    "ControllerConfig",
    "Decision",
    "FleetController",
    "FleetFrontend",
    "FleetMetrics",
    "HashRing",
    "InstanceMetrics",
    "LocalTransport",
    "PayloadRoute",
    "RebalanceReport",
    "RemoteError",
    "RepairConfig",
    "RepairController",
    "RepairReport",
    "RepairTicket",
    "ScalingPolicy",
    "SocketTransport",
    "Transport",
    "TransportError",
    "collect",
    "rebalance",
]
