"""Synthetic drifting tensor sequences for temporal benchmarks/tests.

A versioned store only wins when consecutive versions are CLOSE, so the
fig10 benchmark needs a sequence with (a) shared smooth structure every
version keeps, (b) a small smooth per-version drift a tiny residual fit
can capture, and (c) a fixed unstructured noise floor that caps the
reachable fitness EQUALLY for delta chains and independent fits — making
the bytes-per-version comparison at matched fitness honest.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.codecs.indexing import flat_to_multi
from repro.stream.source import SyntheticTensorSource


def drifting_versions(
    shape: tuple[int, ...],
    n_versions: int,
    *,
    drift: float = 0.04,
    noise: float = 0.03,
    seed: int = 0,
) -> list[np.ndarray]:
    """Deterministic sequence of ``n_versions`` float32 tensors.

    Version 0 is a seeded separable-harmonic tensor plus a FIXED noise
    field; version v adds ``v`` accumulated rank-1 drift steps (smooth
    per-mode sine vectors, amplitude ``drift`` each) on top.  Consecutive
    versions differ by one smooth rank-1 step, so a low-rank residual fit
    captures the change at a fraction of a full fit's bytes.
    """
    shape = tuple(int(s) for s in shape)
    if n_versions < 1:
        raise ValueError(f"n_versions must be >= 1, got {n_versions}")
    n_entries = int(np.prod(shape))
    src = SyntheticTensorSource(shape, seed=seed)
    idx = flat_to_multi(np.arange(n_entries, dtype=np.int64), shape)
    base = np.asarray(src.values_at(idx), np.float64).reshape(shape)
    rng = np.random.default_rng(seed * 7919 + 13)
    base = base + noise * rng.standard_normal(shape)

    versions = []
    x = base
    for v in range(n_versions):
        versions.append(np.asarray(x, np.float32))
        # one smooth rank-1 drift step: outer product of per-mode sines
        vecs = [
            np.sin(
                2 * np.pi * rng.integers(1, 3) * np.arange(n) / n
                + rng.uniform(0.0, 2 * np.pi)
            )
            for n in shape
        ]
        x = x + drift * functools.reduce(np.multiply.outer, vecs)
    return versions
