"""Delta chains: residual-coded versions of one logical tensor.

A v4 container stores per-version codec bodies plus a version index
(``repro.codecs.container.VersionEntry``): keyframes decode stand-alone,
deltas decode to a residual that is ADDED to their base version's
decode.  This module holds the pieces shared by the writer
(``repro.temporal.store``), the eager loader (``container.load_bytes``),
and the serve layer:

* :func:`resolve_chain` — walk base pointers down to a keyframe;
* :class:`ChainEncoded` — an :class:`~repro.codecs.base.Encoded` whose
  decode is the float64 SUM of its component decodes (keyframe first) —
  the ONE summation convention every reader (store, service, fleet)
  must share so answers stay bit-identical across serving paths;
* :class:`DeltaFitter` — fits residual tensors, warm-starting NTTD from
  the previous delta's parameters via the ``fit_stream`` resume
  contract so consecutive residuals (which look alike under drift)
  converge in a couple of passes at tiny rank.
"""
from __future__ import annotations

import numpy as np

from repro.codecs.base import Codec, Encoded, get_codec
from repro.codecs.container import VersionEntry
from repro.stream.source import DenseSource


def resolve_chain(versions: list[VersionEntry], version: int) -> list[int]:
    """Version ids whose decodes sum to ``version``, KEYFRAME FIRST."""
    if not 0 <= version < len(versions):
        raise ValueError(f"version {version} out of range [0, {len(versions)})")
    chain = []
    v = int(version)
    while True:
        chain.append(v)
        ve = versions[v]
        if ve.is_keyframe:
            break
        v = ve.base  # validated strictly decreasing, so this terminates
    chain.reverse()
    return chain


class ChainEncoded(Encoded):
    """A resolved keyframe→delta chain behaving like one payload.

    Components are in decode order (keyframe first); every query is the
    float64 sum of the component answers.  Chains are assembled from a v4
    container rather than serialized themselves, so the byte round-trip
    hooks refuse.
    """

    codec_name = "chain"  # not in the registry: v4 files name the INNER codec

    def __init__(self, components: list[Encoded]):
        if not components:
            raise ValueError("empty chain")
        self.components = list(components)
        shape = tuple(self.components[0].shape)
        for c in self.components[1:]:
            if tuple(c.shape) != shape:
                raise ValueError(
                    f"chain components disagree on shape: {tuple(c.shape)} vs {shape}"
                )

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.components[0].shape)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = np.asarray(indices)
        out = np.zeros((idx.shape[0],), dtype=np.float64)
        for c in self.components:
            out += np.asarray(c.decode_at(idx), np.float64)
        return out

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float64)
        for c in self.components:
            out += np.asarray(c.to_dense(), np.float64)
        return out

    def payload_bytes(self) -> int:
        return sum(c.payload_bytes() for c in self.components)

    def to_bytes(self) -> bytes:
        raise ValueError(
            "chain payloads are written by repro.temporal.VersionedStore, "
            "not to_bytes"
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "ChainEncoded":
        raise ValueError(
            "chain payloads are read from v4 containers "
            "(container.load_bytes / VersionedStore.open), not from_bytes"
        )

    def cache_nbytes(self) -> int:
        return sum(c.cache_nbytes() for c in self.components)

    def drop_caches(self) -> None:
        for c in self.components:
            c.drop_caches()


def load_chain(
    codec: Codec,
    bodies: list[bytes],
    versions: list[VersionEntry],
    version: int | None = None,
) -> ChainEncoded:
    """Assemble the chain for ``version`` (default: latest) from per-version
    codec bodies — the eager counterpart of the serve layer's lazy path."""
    if len(bodies) != len(versions):
        raise ValueError(f"{len(bodies)} bodies for {len(versions)} versions")
    v = len(versions) - 1 if version is None else int(version)
    chain = resolve_chain(versions, v)
    return ChainEncoded([codec.encoded_cls.from_bytes(bodies[c]) for c in chain])


class DeltaFitter:
    """Fit residual tensors, reusing fit state across consecutive deltas.

    For NTTD the fitter keeps ONE persistent ``NTTDStreamFitter`` and
    resumes it through ``Codec.fit_stream(..., fitter=)`` for every
    residual: delta k+1's SGD warm-starts from delta k's parameters, which
    is what makes tiny-rank residual fits converge in ``passes`` epochs.
    Normalization is off by default — the stream fitter freezes first-slab
    statistics, which would mis-scale every later residual.  Codecs
    without a native stream fitter (TT/Tucker/CP/TR/SZ) refit per
    residual via plain ``fit``.
    """

    def __init__(
        self,
        shape: tuple[int, ...],
        codec: str = "nttd",
        *,
        slab_entries: int = 1 << 14,
        passes: int = 2,
        opts: dict | None = None,
    ):
        self.shape = tuple(int(s) for s in shape)
        self.codec = get_codec(codec)
        self.slab_entries = int(slab_entries)
        self.passes = int(passes)
        self.opts = dict(opts or {})
        self._fitter = None
        if codec == "nttd":
            self.opts.setdefault("normalize", False)
            self._fitter = self.codec.stream_fitter(self.shape, None, **self.opts)

    def fit_residual(self, residual: np.ndarray) -> Encoded:
        residual = np.asarray(residual, np.float32)
        if residual.shape != self.shape:
            raise ValueError(f"residual shape {residual.shape} != {self.shape}")
        if self._fitter is not None:
            source = DenseSource(residual, slab_entries=self.slab_entries)
            return self.codec.fit_stream(source, passes=self.passes, fitter=self._fitter)
        opts = dict(self.opts)
        budget = opts.pop("budget", None)
        return self.codec.fit(residual, budget, **opts)
