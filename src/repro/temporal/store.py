"""`VersionedStore`: write/read delta-coded version sequences (v4 files).

The writer keeps a float64 running reconstruction ``hat`` of the LAST
written version — exactly the sum every reader computes — so each
residual is fitted against what a decoder will actually see, not against
the raw previous tensor.  Residual error therefore cannot compound
silently: version k's chain fitness is measured against the true input
and ``rekey_below`` (optional) forces a fresh keyframe whenever a drifty
sequence degrades a chain below the gate.  Every ``append`` ends with a
``sync`` so the file on disk is always a valid, readable v4 container —
the checkpoint durability story.

    with VersionedStore.create("run.tcdc", codec="nttd",
                               keyframe_interval=8) as store:
        for x in snapshots:
            stats = store.append(x)   # {"version", "keyframe", "bytes", ...}

    reader = VersionedStore.open("run.tcdc")
    x5 = reader.decode(version=5)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.codecs import container
from repro.codecs.base import Encoded, get_codec
from repro.stream.writer import ChunkedWriter
from repro.temporal.delta import ChainEncoded, DeltaFitter, resolve_chain


class VersionedStore:
    """Writer for a v4 delta container.  Use :meth:`create` / :meth:`open`."""

    def __init__(
        self,
        path: str,
        codec: str = "nttd",
        *,
        keyframe_interval: int = 8,
        chunk_bytes: int = 1 << 20,
        keyframe_opts: dict | None = None,
        delta_opts: dict | None = None,
        delta_passes: int = 2,
        slab_entries: int = 1 << 14,
        rekey_below: float | None = None,
    ):
        if keyframe_interval < 1:
            raise ValueError(f"keyframe_interval must be >= 1, got {keyframe_interval}")
        if chunk_bytes <= 0:
            raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
        self.path = path
        self.codec_name = codec
        self.codec = get_codec(codec)
        self.keyframe_interval = int(keyframe_interval)
        self.chunk_bytes = int(chunk_bytes)
        self.keyframe_opts = dict(keyframe_opts or {})
        self.delta_opts = dict(delta_opts or {})
        self.delta_passes = int(delta_passes)
        self.slab_entries = int(slab_entries)
        self.rekey_below = rekey_below
        self._writer = ChunkedWriter(path, codec, delta=True)
        self._shape: tuple[int, ...] | None = None
        self._delta: DeltaFitter | None = None
        self._hat: np.ndarray | None = None  # f64 decode of the last version
        self._vid = 0

    @classmethod
    def create(cls, path: str, codec: str = "nttd", **kw) -> "VersionedStore":
        """Start a new versioned store at ``path`` (constructor alias,
        mirroring :meth:`open`)."""
        return cls(path, codec, **kw)

    @staticmethod
    def open(path: str) -> "VersionedReader":
        return VersionedReader(path)

    # -- writing -----------------------------------------------------------
    @property
    def n_versions(self) -> int:
        return self._vid

    def append(self, x: np.ndarray) -> dict:
        """Write tensor ``x`` as the next version; returns append stats."""
        x32 = np.asarray(x, np.float32)
        if self._shape is None:
            self._shape = tuple(x32.shape)
            self._delta = DeltaFitter(
                self._shape,
                self.codec_name,
                slab_entries=self.slab_entries,
                passes=self.delta_passes,
                opts=self.delta_opts,
            )
        elif tuple(x32.shape) != self._shape:
            raise ValueError(
                f"version {self._vid} shape {tuple(x32.shape)} != {self._shape}"
            )
        vid = self._vid
        keyframe = vid % self.keyframe_interval == 0
        rekeyed = False
        if not keyframe:
            residual = np.asarray(x32, np.float64) - self._hat
            enc = self._delta.fit_residual(residual.astype(np.float32))
            hat = self._hat + np.asarray(enc.to_dense(), np.float64)
            fit = _fitness(x32, hat)
            if self.rekey_below is not None and fit < self.rekey_below:
                keyframe = rekeyed = True  # chain degraded: cut a fresh keyframe
            else:
                nbytes = self._write_version(enc, base=vid - 1)
                self._hat = hat
        if keyframe:
            enc = self._fit_keyframe(x32)
            nbytes = self._write_version(enc, base=-1)
            self._hat = np.asarray(enc.to_dense(), np.float64)
            fit = _fitness(x32, self._hat)
        self._writer.sync()  # file on disk is valid after every append
        self._vid += 1
        obs.fit_event(
            "version_append",
            version=vid,
            keyframe=keyframe,
            rekeyed=rekeyed,
            bytes=nbytes,
            fitness=fit,
        )
        return {
            "version": vid,
            "keyframe": keyframe,
            "rekeyed": rekeyed,
            "bytes": nbytes,
            "fitness": fit,
        }

    def _fit_keyframe(self, x32: np.ndarray) -> Encoded:
        opts = dict(self.keyframe_opts)
        budget = opts.pop("budget", None)
        return self.codec.fit(x32, budget, **opts)

    def _write_version(self, enc: Encoded, base: int) -> int:
        body = enc.to_bytes()
        n_entries = int(np.prod(self._shape))
        n_chunks = -(-len(body) // self.chunk_bytes)
        self._writer.begin_version(base)
        for i, off in enumerate(range(0, len(body), self.chunk_bytes)):
            lo = i * n_entries // n_chunks
            hi = (i + 1) * n_entries // n_chunks
            self._writer.append(
                body[off : off + self.chunk_bytes],
                entry_range=(lo, hi) if hi > lo else None,
            )
        return len(body)

    def close(self) -> int:
        return self._writer.close()

    def __enter__(self) -> "VersionedStore":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._writer.__exit__(exc_type, exc, tb)


def _fitness(x: np.ndarray, hat: np.ndarray) -> float:
    x64 = np.asarray(x, np.float64)
    err = float(np.linalg.norm(x64 - hat))
    return 1.0 - err / max(float(np.linalg.norm(x64)), 1e-30)


@dataclasses.dataclass
class ChainHealth:
    """One version's post-repair verdict from :func:`revalidate_chains`."""

    version: int
    #: keyframe-to-version decode chain (resolve_chain order)
    chain: list[int]
    #: every chunk CRC on the chain passed and the decode is finite
    ok: bool
    error: str | None = None
    #: chain fitness against caller-provided truth (None without truth)
    fitness: float | None = None


def revalidate_chains(
    path: str, truth: dict[int, np.ndarray] | None = None
) -> list[ChainHealth]:
    """Re-validate every version chain of a v4 delta file — the repair
    controller's post-repair step for versioned payloads.

    Repairing a keyframe's chunks changes bytes that EVERY dependent
    residual decodes through, so a byte restore is not done until each
    chain re-reads clean (chunk CRCs) and decodes to finite values.  Pass
    ``truth`` (version -> dense original tensor, any subset) to also
    re-measure chain fitness the way the writer's ``rekey_below`` gate
    did at append time.
    """
    out: list[ChainHealth] = []
    with VersionedReader(path) as reader:
        for v in range(reader.n_versions):
            chain = resolve_chain(reader.versions, v)
            try:
                hat = reader.decode(v)
                if not np.all(np.isfinite(hat)):
                    raise ValueError(f"version {v}: non-finite chain decode")
            except ValueError as e:
                out.append(ChainHealth(v, chain, ok=False, error=str(e)))
                continue
            fit = None
            if truth is not None and v in truth:
                fit = _fitness(np.asarray(truth[v]), hat.astype(np.float64))
            out.append(ChainHealth(v, chain, ok=True, fitness=fit))
    return out


class VersionedReader:
    """Eager in-process reader for a v4 file (the serve layer has its own
    lazy path through ``CodecService.load_stream``).  Component payloads
    materialize once and are shared by every chain that includes them."""

    def __init__(self, path: str):
        self.path = path
        self._oc = container.open_container(path)
        if not self._oc.is_versioned:
            self._oc.close()
            raise ValueError(f"{path}: not a v{container.DELTA_VERSION} delta container")
        self.codec_name = self._oc.codec
        self.codec = get_codec(self._oc.codec)
        self._components: dict[int, Encoded] = {}

    @property
    def versions(self) -> list[container.VersionEntry]:
        return list(self._oc.versions)

    @property
    def n_versions(self) -> int:
        return len(self._oc.versions)

    def version_bytes(self, version: int) -> int:
        ve = self._oc.versions[version]
        return sum(c.length for c in self._oc.chunks[ve.chunk_start : ve.chunk_stop])

    def component(self, version: int) -> Encoded:
        """The stand-alone decode component version ``version`` contributes
        (keyframe payload or delta residual), cached after first read."""
        if version not in self._components:
            ve = self._oc.versions[version]
            body = b"".join(
                container.read_chunk(self._oc.view, c)
                for c in self._oc.chunks[ve.chunk_start : ve.chunk_stop]
            )
            self._components[version] = self.codec.encoded_cls.from_bytes(body)
        return self._components[version]

    def encoded(self, version: int | None = None) -> ChainEncoded:
        v = self.n_versions - 1 if version is None else int(version)
        chain = resolve_chain(self._oc.versions, v)
        return ChainEncoded([self.component(c) for c in chain])

    def decode(self, version: int | None = None) -> np.ndarray:
        return self.encoded(version).to_dense()

    def decode_at(self, indices: np.ndarray, version: int | None = None) -> np.ndarray:
        return self.encoded(version).decode_at(indices)

    def close(self) -> None:
        self._components.clear()
        self._oc.close()

    def __enter__(self) -> "VersionedReader":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
