"""Delta-coded versioned tensor payloads (container v4).

Sequences of closely related tensors — training checkpoints over steps,
daily snapshots, sliding windows — share almost all their structure, so
paying a full independent fit per version wastes most of the bytes.
`repro.temporal` borrows the video-codec I-frame/P-frame split: version 0
is a full payload (keyframe), each subsequent version a cheap residual
fit against the previous version's decode, with a configurable keyframe
interval bounding the decode chain depth.

    from repro.temporal import VersionedStore

    with VersionedStore.create("run.tcdc", codec="nttd") as store:
        for step_tensor in snapshots:
            store.append(step_tensor)

    reader = VersionedStore.open("run.tcdc")
    x3 = reader.decode(version=3)   # keyframe + delta decodes, summed

The same files serve lazily through ``CodecService.load_stream`` +
``decode_at(name, idx, version=v)`` and fan out across a fleet with
version-aware routing; ``repro.compress.checkpoint_codec`` uses it so
checkpoint step N+1 compresses against step N.
"""
from repro.temporal.delta import (
    ChainEncoded,
    DeltaFitter,
    load_chain,
    resolve_chain,
)
from repro.temporal.drift import drifting_versions
from repro.temporal.store import ChainHealth, VersionedStore, revalidate_chains

__all__ = [
    "ChainEncoded",
    "ChainHealth",
    "DeltaFitter",
    "VersionedStore",
    "drifting_versions",
    "load_chain",
    "resolve_chain",
    "revalidate_chains",
]
