"""Versioned self-describing container for ANY registered codec.

Extends the original NTTD-only TCDC layout (core/serialization.py, v2)
with a codec-id header, so every codec round-trips to disk bit-exactly.
Monolithic layout (``flags == 0``):

    magic 'TCDC' | u16 version=3 | u8 flags | u8 name_len | name ascii
    u64 body_len | u32 crc32(body) | body

Chunked layout (``flags & FLAG_CHUNKED``, written by
``repro.stream.writer``) replaces the single body with chunks appended
as a streaming fit progresses, indexed by a footer so the file is valid
the moment the writer closes — no seeking back to patch a length field:

    header (as above) | chunk bytes ... | footer | u64 footer_len | 'TCDX'
    footer = chunk index | [ranges block] | [version-index block]
                         | [held-out block] | [patch block]
    chunk index   = u32 n_chunks | n x (u64 offset | u64 length | u32 crc32)
    ranges block  = 'TCDR' | n x (u64 entry_start | u64 entry_stop)
    version index = 'TCDV' | u32 n_versions
                           | n x (i64 base | u32 chunk_start | u32 chunk_stop)
    held-out      = 'TCDQ' | u32 n_entries | n x u64 flat_index | n x f64 value
    patch block   = 'TCDP' | u32 n_patches
                           | n x (u64 entry_start | u64 entry_stop
                                  | u32 chunk_start | u32 chunk_stop
                                  | u8 codec_len | codec ascii)

The footer blocks after the chunk index are optional and magic-tagged,
parsed in the fixed order above; any trailing bytes the blocks do not
account for make the footer corrupt.

The patch (``TCDP``) block is the durable artifact of a read repair
(``repro.fleet.repair``): each entry names a flat-entry range whose
decode is OVERRIDDEN by a stand-alone overlay payload whose body is
``chunks[chunk_start:chunk_stop)``.  Patch chunks always occupy a suffix
of the chunk index (they are appended by ``repro.stream.writer.
append_patch`` under the footer reseal discipline), so the BASE payload
— ``chunks[:n_base]`` — is byte-identical to what was originally
written and untouched entry ranges keep decoding bit-identically.
Overlapping patches resolve last-wins (a repair of a repair).  Patches
are a v3 (single-tensor) feature; a v4 delta container with a patch
block is rejected.

The held-out (``TCDQ``) block carries ground-truth entries SAMPLED FROM
THE ORIGINAL TENSOR at fit time (flat index + exact value), recorded by
``repro.stream.ChunkedWriter``.  The serve layer's online fitness
canaries re-decode these entries on a sampled fraction of live traffic
and compare against the recorded truth — quality stays an observed
signal after deployment instead of a write-time constant.  Files without
the block (every pre-existing v2/v3/v4 container) load and serve
unchanged; canaries just stay off for them.

Delta layout (container **v4**: ``u16 version=4`` with
``FLAG_CHUNKED | FLAG_DELTA``, written by ``repro.stream.writer`` in
delta mode / ``repro.temporal.VersionedStore``) stores a SEQUENCE of
related tensors in one file.  The version-index block partitions the
chunk index into per-version chunk ranges: version ``v``'s codec body is
the concatenation of ``chunks[chunk_start:chunk_stop)``.  A version with
``base == -1`` is a keyframe (its body decodes stand-alone); ``base == k``
marks a delta whose decode must be ADDED to version ``k``'s decode, so
reconstructing version ``v`` walks the base chain back to a keyframe and
sums the component decodes.  Version 0 is always a keyframe and bases
only point backwards, so every chain terminates.  Plain single-tensor
files stay v3 — v4 is only ever written for delta files.

The concatenated chunks of a v3 file (or of one v4 version) ARE the
codec's ``Encoded.to_bytes()`` body, so every codec gets chunked and
delta persistence for free.  ``load_bytes`` accepts monolithic v3,
chunked v3, bare legacy v2 blobs (headerless NTTD payloads written by
older checkpoints), and v4 delta files (decoded at their latest version
through ``repro.temporal``); ``open_container``/``open_chunks`` expose
the index without touching chunk bytes, which is what the serve layer's
lazy mmap-backed ``load_stream`` builds on.

Array (de)serialization helpers are shared by the adapter bodies:
``write_array``/``read_array`` preserve dtype and shape so float64
baselines round-trip bit-exactly.
"""
from __future__ import annotations

import dataclasses
import io
import mmap
import struct
import zlib

import numpy as np

from repro.codecs.base import Encoded, get_codec

MAGIC = b"TCDC"
VERSION = 3
DELTA_VERSION = 4  # container carrying a version-index (delta chain) block
FOOTER_MAGIC = b"TCDX"
RANGES_MAGIC = b"TCDR"  # optional per-chunk entry-range block in the footer
VINDEX_MAGIC = b"TCDV"  # optional version-index block in the footer
HELDOUT_MAGIC = b"TCDQ"  # optional held-out ground-truth block in the footer
PATCH_MAGIC = b"TCDP"  # optional read-repair patch (overlay) block in the footer
FLAG_CHUNKED = 0x01
FLAG_DELTA = 0x02  # chunk index is partitioned into versions (v4 only)
_LEGACY_NTTD_VERSION = 2
_TRAILER_LEN = 12  # u64 footer_len + FOOTER_MAGIC

_DTYPES = {
    0: np.float16,
    1: np.float32,
    2: np.float64,
    3: np.int32,
    4: np.int64,
    5: np.uint8,
}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


# ---------------------------------------------------------------------------
# array helpers (used by adapter to_bytes/from_bytes bodies)
# ---------------------------------------------------------------------------
def write_array(out: io.BytesIO, arr: np.ndarray) -> None:
    """u8 dtype-code | u8 ndim | ndim x u64 shape | raw bytes (C order)."""
    arr = np.ascontiguousarray(arr)
    out.write(struct.pack("<BB", _DTYPE_CODES[arr.dtype], arr.ndim))
    out.write(np.asarray(arr.shape, dtype=np.uint64).tobytes())
    out.write(arr.tobytes())


def pack_arrays(*arrays: np.ndarray) -> bytes:
    """u8 count | count x array — the shared body framing for the
    decomposition codecs (TT/Tucker/CP/TR cores and factors)."""
    if len(arrays) > 255:
        raise ValueError("too many arrays for u8 count")
    out = io.BytesIO()
    out.write(struct.pack("<B", len(arrays)))
    for arr in arrays:
        write_array(out, arr)
    return out.getvalue()


def unpack_arrays(data: bytes) -> list[np.ndarray]:
    buf = io.BytesIO(data)
    head = buf.read(1)
    if not head:
        raise ValueError("truncated payload: array count")
    (n,) = struct.unpack("<B", head)
    return [read_array(buf) for _ in range(n)]


def read_array(buf: io.BytesIO) -> np.ndarray:
    head = buf.read(2)
    if len(head) < 2:
        raise ValueError("truncated payload: array header")
    code, ndim = struct.unpack("<BB", head)
    if code not in _DTYPES:
        raise ValueError(f"corrupt payload: unknown dtype code {code}")
    shape = tuple(np.frombuffer(buf.read(8 * ndim), dtype=np.uint64).astype(int))
    dtype = np.dtype(_DTYPES[code])
    nbytes = int(np.prod(shape)) * dtype.itemsize if ndim else dtype.itemsize
    raw = buf.read(nbytes)
    if len(raw) < nbytes:
        raise ValueError("truncated payload: array body")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChunkEntry:
    offset: int  # absolute file offset of the chunk's first byte
    length: int
    crc: int
    #: optional flat-entry range [entry_start, entry_stop) this chunk is
    #: responsible for — a ROUTING partition of the tensor's flat index
    #: space (recorded by the stream writer), not a decode dependency:
    #: the fleet router uses it to assign queries to chunk owners, while
    #: decoding still concatenates all chunks into the payload body.
    entry_start: int | None = None
    entry_stop: int | None = None


@dataclasses.dataclass(frozen=True)
class VersionEntry:
    """One version in a v4 delta file's version-index block.

    ``base == -1`` marks a keyframe; otherwise the version's decode is a
    residual to be ADDED to version ``base``'s decode.  The version's codec
    body is the concatenation of ``chunks[chunk_start:chunk_stop)``.
    """

    base: int
    chunk_start: int
    chunk_stop: int

    @property
    def is_keyframe(self) -> bool:
        return self.base < 0


@dataclasses.dataclass(frozen=True)
class PatchEntry:
    """One read-repair overlay in the ``TCDP`` footer block.

    The overlay's codec body is ``chunks[chunk_start:chunk_stop)``; its
    decode REPLACES the base payload's values for flat entries in
    ``[entry_start, entry_stop)`` (the overlay tensor's own shape must
    hold exactly ``entry_stop - entry_start`` entries, addressed by
    ``flat - entry_start`` in row-major order).  Entries outside every
    patch range keep decoding from the untouched base chunks."""

    entry_start: int
    entry_stop: int
    chunk_start: int
    chunk_stop: int
    codec: str


@dataclasses.dataclass(frozen=True)
class HeldoutEntries:
    """Fit-time ground truth for online fitness canaries: exact values of
    ``n`` entries of the ORIGINAL tensor, addressed by flat index.  Both
    arrays are the footer block verbatim (int64 indices, float64 values),
    so recording and re-reading round-trips bit-exactly."""

    indices: np.ndarray  # [n] int64 flat indices into the original tensor
    values: np.ndarray   # [n] float64 original values at those indices

    def __post_init__(self):
        idx = np.ascontiguousarray(np.asarray(self.indices, dtype=np.int64))
        vals = np.ascontiguousarray(np.asarray(self.values, dtype=np.float64))
        if idx.ndim != 1 or vals.ndim != 1 or len(idx) != len(vals):
            raise ValueError(
                f"held-out indices/values must be equal-length 1-D arrays, "
                f"got {idx.shape} / {vals.shape}"
            )
        if len(idx) and int(idx.min()) < 0:
            raise ValueError("held-out flat indices must be non-negative")
        object.__setattr__(self, "indices", idx)
        object.__setattr__(self, "values", vals)

    def __len__(self) -> int:
        return len(self.indices)


def pack_header(codec_name: str, flags: int = 0, version: int = VERSION) -> bytes:
    name = codec_name.encode("ascii")
    if not name or len(name) > 255:
        raise ValueError(f"bad codec id {codec_name!r}")
    return MAGIC + struct.pack("<HBB", version, flags, len(name)) + name


def pack_footer(
    chunks: list[ChunkEntry],
    versions: list[VersionEntry] | None = None,
    heldout: HeldoutEntries | None = None,
    patches: list[PatchEntry] | None = None,
) -> bytes:
    footer = struct.pack("<I", len(chunks)) + b"".join(
        struct.pack("<QQI", c.offset, c.length, c.crc) for c in chunks
    )
    # entry ranges are all-or-nothing: a partial mapping cannot route
    if chunks and all(c.entry_start is not None for c in chunks):
        footer += RANGES_MAGIC + b"".join(
            struct.pack("<QQ", c.entry_start, c.entry_stop) for c in chunks
        )
    if versions is not None:
        footer += VINDEX_MAGIC + struct.pack("<I", len(versions)) + b"".join(
            struct.pack("<qII", v.base, v.chunk_start, v.chunk_stop) for v in versions
        )
    if heldout is not None and len(heldout):
        footer += (
            HELDOUT_MAGIC
            + struct.pack("<I", len(heldout))
            + heldout.indices.astype("<i8").tobytes()
            + heldout.values.astype("<f8").tobytes()
        )
    if patches:
        footer += PATCH_MAGIC + struct.pack("<I", len(patches))
        for p in patches:
            name = p.codec.encode("ascii")
            if not name or len(name) > 255:
                raise ValueError(f"bad patch codec id {p.codec!r}")
            footer += struct.pack(
                "<QQIIB", p.entry_start, p.entry_stop,
                p.chunk_start, p.chunk_stop, len(name),
            ) + name
    return footer + struct.pack("<Q", len(footer)) + FOOTER_MAGIC


def _parse_header(data) -> tuple[int, str, int]:
    """-> (flags, codec name, offset just past the header)."""
    if len(data) < 8:
        raise ValueError("truncated payload: header")
    flags, name_len = struct.unpack("<BB", bytes(data[6:8]))
    if len(data) < 8 + name_len:
        raise ValueError("truncated payload: codec id")
    name = bytes(data[8 : 8 + name_len]).decode("ascii")
    return flags, name, 8 + name_len


def _validate_versions(
    versions: list[VersionEntry], n_chunks: int, ctx: str = ""
) -> None:
    """Version entries must contiguously partition [0, n_chunks) from 0 and
    form well-founded base chains (keyframe 0, bases strictly backwards)."""
    if not versions:
        raise ValueError(f"{ctx}corrupt payload: empty version index")
    expect = 0
    for i, v in enumerate(versions):
        if v.chunk_start != expect or v.chunk_stop <= v.chunk_start:
            raise ValueError(f"{ctx}corrupt payload: version {i} chunk range")
        expect = v.chunk_stop
        if i == 0 and not v.is_keyframe:
            raise ValueError(f"{ctx}corrupt payload: version 0 must be a keyframe")
        if not v.is_keyframe and v.base >= i:
            raise ValueError(f"{ctx}corrupt payload: version {i} base {v.base}")
    if expect != n_chunks:
        raise ValueError(f"{ctx}corrupt payload: version index does not cover chunks")


def _validate_patches(
    patches: list[PatchEntry], n_chunks: int, ctx: str = ""
) -> None:
    """Patch chunk ranges must be non-empty, disjoint, and together cover a
    SUFFIX ``[n_base, n_chunks)`` of the chunk index — the invariant that
    keeps ``chunks[:n_base]`` the untouched base payload."""
    covered: set[int] = set()
    for i, p in enumerate(patches):
        if p.entry_stop <= p.entry_start or p.entry_start < 0:
            raise ValueError(f"{ctx}corrupt payload: patch {i} entry range")
        if not 0 <= p.chunk_start < p.chunk_stop <= n_chunks:
            raise ValueError(f"{ctx}corrupt payload: patch {i} chunk range")
        ids = set(range(p.chunk_start, p.chunk_stop))
        if ids & covered:
            raise ValueError(f"{ctx}corrupt payload: patch {i} chunks overlap")
        covered |= ids
    if covered and covered != set(range(min(covered), n_chunks)):
        raise ValueError(f"{ctx}corrupt payload: patch chunks must be a suffix")


def patch_base_count(n_chunks: int, patches: list[PatchEntry] | None) -> int:
    """Number of BASE (non-patch) chunks — patch chunks are a validated
    suffix, so the base payload is always ``chunks[:n_base]``."""
    if not patches:
        return n_chunks
    return min(p.chunk_start for p in patches)


def _parse_footer(
    data, header_end: int, ctx: str = ""
) -> tuple[
    list[ChunkEntry],
    list[VersionEntry] | None,
    HeldoutEntries | None,
    list[PatchEntry],
]:
    """Parse the trailer-addressed footer: chunk index, then the optional
    magic-tagged TCDR (entry ranges), TCDV (version index), TCDQ
    (held-out ground truth), and TCDP (read-repair patch) blocks."""
    if len(data) < header_end + _TRAILER_LEN:
        raise ValueError(f"{ctx}truncated payload: chunk trailer")
    if bytes(data[-4:]) != FOOTER_MAGIC:
        raise ValueError(f"{ctx}truncated payload: chunk footer magic missing")
    (footer_len,) = struct.unpack("<Q", bytes(data[-12:-4]))
    footer_start = len(data) - _TRAILER_LEN - footer_len
    if footer_start < header_end:
        raise ValueError(f"{ctx}corrupt payload: chunk footer overlaps header")
    footer = bytes(data[footer_start : footer_start + footer_len])
    if len(footer) < 4:
        raise ValueError(f"{ctx}truncated payload: chunk index")
    (n,) = struct.unpack("<I", footer[:4])
    pos = 4 + 20 * n
    if len(footer) < pos:
        raise ValueError(f"{ctx}corrupt payload: chunk index length mismatch")
    ranges: list[tuple[int, int]] | None = None
    if footer[pos : pos + 4] == RANGES_MAGIC:
        if len(footer) < pos + 4 + 16 * n:
            raise ValueError(f"{ctx}corrupt payload: chunk index length mismatch")
        ranges = [
            struct.unpack("<QQ", footer[pos + 4 + 16 * i : pos + 20 + 16 * i])
            for i in range(n)
        ]
        pos += 4 + 16 * n
    versions: list[VersionEntry] | None = None
    if footer[pos : pos + 4] == VINDEX_MAGIC:
        if len(footer) < pos + 8:
            raise ValueError(f"{ctx}truncated payload: version index")
        (nv,) = struct.unpack("<I", footer[pos + 4 : pos + 8])
        pos += 8
        if len(footer) < pos + 16 * nv:
            raise ValueError(f"{ctx}truncated payload: version index")
        versions = [
            VersionEntry(*struct.unpack("<qII", footer[pos + 16 * i : pos + 16 * (i + 1)]))
            for i in range(nv)
        ]
        pos += 16 * nv
        _validate_versions(versions, n, ctx)
    heldout: HeldoutEntries | None = None
    if footer[pos : pos + 4] == HELDOUT_MAGIC:
        if len(footer) < pos + 8:
            raise ValueError(f"{ctx}truncated payload: held-out block")
        (nq,) = struct.unpack("<I", footer[pos + 4 : pos + 8])
        pos += 8
        if nq == 0:
            raise ValueError(f"{ctx}corrupt payload: empty held-out block")
        if len(footer) < pos + 16 * nq:
            raise ValueError(f"{ctx}truncated payload: held-out block")
        idx = np.frombuffer(footer, dtype="<i8", count=nq, offset=pos)
        vals = np.frombuffer(footer, dtype="<f8", count=nq, offset=pos + 8 * nq)
        if len(idx) and int(idx.min()) < 0:
            raise ValueError(f"{ctx}corrupt payload: held-out index negative")
        heldout = HeldoutEntries(idx, vals)
        pos += 16 * nq
    patches: list[PatchEntry] = []
    if footer[pos : pos + 4] == PATCH_MAGIC:
        if len(footer) < pos + 8:
            raise ValueError(f"{ctx}truncated payload: patch block")
        (np_,) = struct.unpack("<I", footer[pos + 4 : pos + 8])
        pos += 8
        for _ in range(np_):
            if len(footer) < pos + 25:
                raise ValueError(f"{ctx}truncated payload: patch block")
            lo, hi, cstart, cstop, nlen = struct.unpack(
                "<QQIIB", footer[pos : pos + 25]
            )
            pos += 25
            if len(footer) < pos + nlen:
                raise ValueError(f"{ctx}truncated payload: patch codec id")
            codec = footer[pos : pos + nlen].decode("ascii")
            pos += nlen
            patches.append(PatchEntry(lo, hi, cstart, cstop, codec))
        _validate_patches(patches, n, ctx)
    if pos != len(footer):
        raise ValueError(f"{ctx}corrupt payload: chunk index length mismatch")
    chunks = []
    for i in range(n):
        off, length, crc = struct.unpack("<QQI", footer[4 + 20 * i : 24 + 20 * i])
        if off < header_end or off + length > footer_start:
            raise ValueError(f"{ctx}corrupt payload: chunk outside data region")
        start, stop = ranges[i] if ranges is not None else (None, None)
        chunks.append(ChunkEntry(off, length, crc, start, stop))
    return chunks, versions, heldout, patches


def _check_delta(
    data, flags: int, header_end: int, ctx: str = ""
) -> tuple[list[ChunkEntry], list[VersionEntry], HeldoutEntries | None]:
    """Parse + validate a v4 footer: both delta flags and a version index
    are mandatory, so a v4 file is never silently read as a single tensor."""
    if not (flags & FLAG_CHUNKED) or not (flags & FLAG_DELTA):
        raise ValueError(f"{ctx}corrupt payload: v4 container without delta flags")
    chunks, versions, heldout, patches = _parse_footer(data, header_end, ctx)
    if versions is None:
        raise ValueError(f"{ctx}corrupt payload: v4 container missing version index")
    if patches:
        raise ValueError(f"{ctx}corrupt payload: patch block on a delta container")
    return chunks, versions, heldout


def read_chunk(data, chunk: ChunkEntry, ctx: str = "") -> bytes:
    """Materialize one chunk's bytes, CRC-checked.  ``ctx`` (conventionally
    ``f"{path}: "``) prefixes both failure messages so a corrupt chunk names
    the file it lives in, matching every other container error path."""
    raw = bytes(data[chunk.offset : chunk.offset + chunk.length])
    if len(raw) < chunk.length:
        raise ValueError(f"{ctx}truncated payload: chunk body")
    if zlib.crc32(raw) & 0xFFFFFFFF != chunk.crc:
        raise ValueError(f"{ctx}corrupt payload: chunk checksum mismatch")
    return raw


class PatchedEncoded(Encoded):
    """A base payload with read-repair overlays applied last-wins.

    Decode semantics of a patched v3 container: entries inside a patch's
    ``[entry_start, entry_stop)`` come from the overlay payload (addressed
    by ``flat - entry_start`` in the overlay's own row-major index space);
    everything else comes from the untouched base payload — which is why
    untouched ranges stay bit-identical through a repair.  Serialization
    goes through the container file (writer ``append_patch``), not
    ``to_bytes``: the patched whole has no single codec body.
    """

    def __init__(
        self, base: Encoded, overlays: list[tuple[PatchEntry, Encoded]]
    ):
        self.base = base
        self.overlays = list(overlays)
        for p, enc in self.overlays:
            n = int(np.prod(enc.shape))
            if n != p.entry_stop - p.entry_start:
                raise ValueError(
                    f"corrupt payload: patch overlay shape {enc.shape} holds "
                    f"{n} entries, range needs {p.entry_stop - p.entry_start}"
                )

    @property
    def codec_name(self) -> str:  # type: ignore[override]
        return self.base.codec_name

    @property
    def shape(self) -> tuple[int, ...]:
        return self.base.shape

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        out = np.asarray(self.base.decode_at(indices))
        if not self.overlays:
            return out
        idx = np.asarray(indices, dtype=np.int64)
        flat = np.ravel_multi_index(tuple(idx.T), self.base.shape).astype(np.int64)
        for p, enc in self.overlays:  # later patches win
            mask = (flat >= p.entry_start) & (flat < p.entry_stop)
            if not mask.any():
                continue
            local = flat[mask] - p.entry_start
            pos = np.stack(
                np.unravel_index(local, enc.shape), axis=1
            ).astype(np.int64)
            out = out.copy()
            out[mask] = np.asarray(enc.decode_at(pos), out.dtype)
        return out

    def to_dense(self) -> np.ndarray:
        out = np.asarray(self.base.to_dense()).copy()
        flat = out.reshape(-1)
        for p, enc in self.overlays:
            flat[p.entry_start : p.entry_stop] = np.asarray(
                enc.to_dense(), flat.dtype
            ).reshape(-1)
        return out

    def payload_bytes(self) -> int:
        return self.base.payload_bytes() + sum(
            enc.payload_bytes() for _, enc in self.overlays
        )

    def to_bytes(self) -> bytes:
        raise NotImplementedError(
            "patched payloads serialize through the container file "
            "(stream.writer.append_patch), not to_bytes"
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "Encoded":
        raise NotImplementedError("patched payloads load via the container file")

    def cache_nbytes(self) -> int:
        return self.base.cache_nbytes() + sum(
            enc.cache_nbytes() for _, enc in self.overlays
        )

    def drop_caches(self) -> None:
        self.base.drop_caches()
        for _, enc in self.overlays:
            enc.drop_caches()


def _load_patch_overlay(data, chunks: list[ChunkEntry], p: PatchEntry) -> Encoded:
    """Materialize one patch overlay's payload from its chunk suffix."""
    try:
        codec = get_codec(p.codec)
    except KeyError:
        raise ValueError(f"unknown codec id {p.codec!r} in patch block") from None
    body = b"".join(
        read_chunk(data, c) for c in chunks[p.chunk_start : p.chunk_stop]
    )
    return codec.encoded_cls.from_bytes(body)


def save_bytes(enc: Encoded) -> bytes:
    body = enc.to_bytes()
    out = io.BytesIO()
    out.write(pack_header(enc.codec_name))
    out.write(struct.pack("<QI", len(body), zlib.crc32(body) & 0xFFFFFFFF))
    out.write(body)
    return out.getvalue()


def load_bytes(data: bytes) -> Encoded:
    if len(data) < 4 or bytes(data[:4]) != MAGIC:
        raise ValueError("not a TensorCodec container")
    if len(data) < 6:
        raise ValueError("truncated payload: version header")
    (version,) = struct.unpack("<H", bytes(data[4:6]))
    if version == _LEGACY_NTTD_VERSION:
        # headerless NTTD blob from core/serialization.py (older checkpoints)
        from repro.codecs.adapters import NTTDEncoded

        return NTTDEncoded.from_bytes(bytes(data))
    if version not in (VERSION, DELTA_VERSION):
        raise ValueError(f"unsupported container version {version}")
    flags, name, off = _parse_header(data)
    if version == DELTA_VERSION:
        chunks, versions, _ = _check_delta(data, flags, off)
        try:
            codec = get_codec(name)
        except KeyError:
            raise ValueError(f"unknown codec id {name!r} in container") from None
        from repro.temporal.delta import load_chain

        bodies = [
            b"".join(read_chunk(data, c) for c in chunks[v.chunk_start : v.chunk_stop])
            for v in versions
        ]
        return load_chain(codec, bodies, versions)
    if flags & FLAG_DELTA:
        raise ValueError("corrupt payload: delta flag on a v3 container")
    if flags & FLAG_CHUNKED:
        chunks, versions, _, patches = _parse_footer(data, off)
        if versions is not None:
            raise ValueError("corrupt payload: version index on a v3 container")
        n_base = patch_base_count(len(chunks), patches)
        body = b"".join(read_chunk(data, c) for c in chunks[:n_base])
        if patches:
            try:
                codec = get_codec(name)
            except KeyError:
                raise ValueError(f"unknown codec id {name!r} in container") from None
            base = codec.encoded_cls.from_bytes(body)
            return PatchedEncoded(
                base,
                [
                    (p, _load_patch_overlay(data, chunks, p))
                    for p in patches
                ],
            )
    else:
        if len(data) < off + 12:
            raise ValueError("truncated payload: codec id")
        body_len, crc = struct.unpack("<QI", bytes(data[off : off + 12]))
        off += 12
        body = bytes(data[off : off + body_len])
        if len(body) < body_len:
            raise ValueError(
                f"truncated payload: body has {len(body)} of {body_len} bytes"
            )
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise ValueError("corrupt payload: body checksum mismatch")
    try:
        codec = get_codec(name)
    except KeyError:
        raise ValueError(f"unknown codec id {name!r} in container") from None
    return codec.encoded_cls.from_bytes(body)


def save_file(path: str, enc: Encoded) -> int:
    data = save_bytes(enc)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_file(path: str) -> Encoded:
    with open(path, "rb") as f:
        return load_bytes(f.read())


@dataclasses.dataclass
class OpenContainer:
    """Lazily opened container: header + footer parsed, chunk bytes mmapped.

    ``versions`` is ``None`` for a plain v3 (single tensor) file and the
    validated version index for a v4 delta file.  ``heldout`` is the
    fit-time ground-truth sample from the optional ``TCDQ`` footer block
    (``None`` for files written without one — every legacy container).
    """

    codec: str
    flags: int
    chunks: list[ChunkEntry]
    versions: list[VersionEntry] | None
    view: memoryview
    heldout: HeldoutEntries | None = None
    #: read-repair overlays (TCDP block); empty for unrepaired files
    patches: list[PatchEntry] = dataclasses.field(default_factory=list)

    @property
    def is_versioned(self) -> bool:
        return self.versions is not None

    @property
    def n_base(self) -> int:
        """Chunks before the patch suffix — the untouched base payload."""
        return patch_base_count(len(self.chunks), self.patches)

    @property
    def base_chunks(self) -> list[ChunkEntry]:
        return self.chunks[: self.n_base]

    def close(self) -> None:
        mm = self.view.obj
        self.view.release()
        if hasattr(mm, "close"):
            mm.close()


def open_container(path: str) -> OpenContainer:
    """Open a v3/v4 file lazily: parse header + footer, mmap the rest.

    No chunk bytes are read — the serve layer materializes chunks on
    demand through ``read_chunk``.  Monolithic v3 files come back as one
    pseudo-chunk, so callers need not care how the payload was written.
    """
    with open(path, "rb") as f:
        mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
    view = memoryview(mm)
    try:
        if len(view) < 6 or bytes(view[:4]) != MAGIC:
            raise ValueError(f"{path}: not a TensorCodec container")
        (version,) = struct.unpack("<H", bytes(view[4:6]))
        if version not in (VERSION, DELTA_VERSION):
            raise ValueError(
                f"{path}: lazy open needs a v{VERSION}/v{DELTA_VERSION} "
                f"container, got v{version}"
            )
        flags, name, off = _parse_header(view)
        ctx = f"{path}: "
        if version == DELTA_VERSION:
            chunks, versions, heldout = _check_delta(view, flags, off, ctx)
            return OpenContainer(name, flags, chunks, versions, view, heldout)
        if flags & FLAG_DELTA:
            raise ValueError(f"{ctx}corrupt payload: delta flag on a v3 container")
        patches: list[PatchEntry] = []
        if flags & FLAG_CHUNKED:
            chunks, versions, heldout, patches = _parse_footer(view, off, ctx)
            if versions is not None:
                raise ValueError(
                    f"{ctx}corrupt payload: version index on a v3 container"
                )
        else:
            if len(view) < off + 12:
                raise ValueError(f"{ctx}truncated payload: codec id")
            body_len, crc = struct.unpack("<QI", bytes(view[off : off + 12]))
            if len(view) < off + 12 + body_len:
                raise ValueError(f"{ctx}truncated payload: body")
            chunks, heldout = [ChunkEntry(off + 12, body_len, crc)], None
        return OpenContainer(name, flags, chunks, None, view, heldout, patches)
    except Exception:
        view.release()
        mm.close()
        raise


def open_chunks(path: str) -> tuple[str, list[ChunkEntry], memoryview]:
    """Back-compat lazy open for single-tensor (v3) callers.

    Returns ``(codec_name, chunks, mmap-backed view)``; rejects v4 delta
    files, whose chunk list only makes sense alongside the version index
    (use :func:`open_container` for those).
    """
    oc = open_container(path)
    if oc.is_versioned:
        oc.close()
        raise ValueError(
            f"{path}: v{DELTA_VERSION} delta container needs open_container"
        )
    return oc.codec, oc.chunks, oc.view


def container_index(
    path: str,
) -> tuple[str, list[ChunkEntry], list[VersionEntry] | None]:
    """Parse a v3/v4 file's header + footer WITHOUT keeping it open.

    The fleet router builds its consistent-hash ring over exactly these
    chunk entries (one key per chunk; entry ranges, when recorded, tell it
    which flat indices each chunk routes, and the version index tells it
    which chunks belong to which version).  Unlike :func:`open_container`
    no mmap outlives the call — the ring only needs the index, never
    chunk bytes.

    Read-repair patch chunks (the TCDP suffix) are EXCLUDED: routing is by
    the base chunks' entry-range partition, which a repair never changes,
    so a patched file keeps the exact ring and ownership tables it had
    before the repair.  Callers that need the overlays use
    :func:`open_container`.
    """
    oc = open_container(path)
    oc.close()
    return oc.codec, oc.base_chunks, oc.versions


def chunk_index(path: str) -> tuple[str, list[ChunkEntry]]:
    """Back-compat :func:`container_index` for single-tensor callers."""
    name, chunks, versions = container_index(path)
    if versions is not None:
        raise ValueError(
            f"{path}: v{DELTA_VERSION} delta container needs container_index"
        )
    return name, chunks
