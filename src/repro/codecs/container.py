"""Versioned self-describing container for ANY registered codec.

Extends the original NTTD-only TCDC layout (core/serialization.py, v2)
with a codec-id header, so every codec round-trips to disk bit-exactly.
Monolithic layout (``flags == 0``):

    magic 'TCDC' | u16 version=3 | u8 flags | u8 name_len | name ascii
    u64 body_len | u32 crc32(body) | body

Chunked layout (``flags & FLAG_CHUNKED``, written by
``repro.stream.writer``) replaces the single body with chunks appended
as a streaming fit progresses, indexed by a footer so the file is valid
the moment the writer closes — no seeking back to patch a length field:

    header (as above) | chunk bytes ... | footer | u64 footer_len | 'TCDX'
    footer = u32 n_chunks | n x (u64 offset | u64 length | u32 crc32)

The concatenated chunks ARE the codec's ``Encoded.to_bytes()`` body, so
every codec gets chunked persistence for free, and readers that want the
whole payload just join the chunks.  ``load_bytes`` accepts monolithic
v3, chunked v3, and bare legacy v2 blobs (headerless NTTD payloads
written by older checkpoints); ``open_chunks`` exposes the index without
touching chunk bytes, which is what the serve layer's lazy mmap-backed
``load_stream`` builds on.

Array (de)serialization helpers are shared by the adapter bodies:
``write_array``/``read_array`` preserve dtype and shape so float64
baselines round-trip bit-exactly.
"""
from __future__ import annotations

import dataclasses
import io
import mmap
import struct
import zlib

import numpy as np

from repro.codecs.base import Encoded, get_codec

MAGIC = b"TCDC"
VERSION = 3
FOOTER_MAGIC = b"TCDX"
RANGES_MAGIC = b"TCDR"  # optional per-chunk entry-range block in the footer
FLAG_CHUNKED = 0x01
_LEGACY_NTTD_VERSION = 2
_TRAILER_LEN = 12  # u64 footer_len + FOOTER_MAGIC

_DTYPES = {
    0: np.float16,
    1: np.float32,
    2: np.float64,
    3: np.int32,
    4: np.int64,
    5: np.uint8,
}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


# ---------------------------------------------------------------------------
# array helpers (used by adapter to_bytes/from_bytes bodies)
# ---------------------------------------------------------------------------
def write_array(out: io.BytesIO, arr: np.ndarray) -> None:
    """u8 dtype-code | u8 ndim | ndim x u64 shape | raw bytes (C order)."""
    arr = np.ascontiguousarray(arr)
    out.write(struct.pack("<BB", _DTYPE_CODES[arr.dtype], arr.ndim))
    out.write(np.asarray(arr.shape, dtype=np.uint64).tobytes())
    out.write(arr.tobytes())


def pack_arrays(*arrays: np.ndarray) -> bytes:
    """u8 count | count x array — the shared body framing for the
    decomposition codecs (TT/Tucker/CP/TR cores and factors)."""
    if len(arrays) > 255:
        raise ValueError("too many arrays for u8 count")
    out = io.BytesIO()
    out.write(struct.pack("<B", len(arrays)))
    for arr in arrays:
        write_array(out, arr)
    return out.getvalue()


def unpack_arrays(data: bytes) -> list[np.ndarray]:
    buf = io.BytesIO(data)
    head = buf.read(1)
    if not head:
        raise ValueError("truncated payload: array count")
    (n,) = struct.unpack("<B", head)
    return [read_array(buf) for _ in range(n)]


def read_array(buf: io.BytesIO) -> np.ndarray:
    head = buf.read(2)
    if len(head) < 2:
        raise ValueError("truncated payload: array header")
    code, ndim = struct.unpack("<BB", head)
    if code not in _DTYPES:
        raise ValueError(f"corrupt payload: unknown dtype code {code}")
    shape = tuple(np.frombuffer(buf.read(8 * ndim), dtype=np.uint64).astype(int))
    dtype = np.dtype(_DTYPES[code])
    nbytes = int(np.prod(shape)) * dtype.itemsize if ndim else dtype.itemsize
    raw = buf.read(nbytes)
    if len(raw) < nbytes:
        raise ValueError("truncated payload: array body")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChunkEntry:
    offset: int  # absolute file offset of the chunk's first byte
    length: int
    crc: int
    #: optional flat-entry range [entry_start, entry_stop) this chunk is
    #: responsible for — a ROUTING partition of the tensor's flat index
    #: space (recorded by the stream writer), not a decode dependency:
    #: the fleet router uses it to assign queries to chunk owners, while
    #: decoding still concatenates all chunks into the payload body.
    entry_start: int | None = None
    entry_stop: int | None = None


def pack_header(codec_name: str, flags: int = 0) -> bytes:
    name = codec_name.encode("ascii")
    if not name or len(name) > 255:
        raise ValueError(f"bad codec id {codec_name!r}")
    return MAGIC + struct.pack("<HBB", VERSION, flags, len(name)) + name


def pack_footer(chunks: list[ChunkEntry]) -> bytes:
    footer = struct.pack("<I", len(chunks)) + b"".join(
        struct.pack("<QQI", c.offset, c.length, c.crc) for c in chunks
    )
    # entry ranges are all-or-nothing: a partial mapping cannot route
    if chunks and all(c.entry_start is not None for c in chunks):
        footer += RANGES_MAGIC + b"".join(
            struct.pack("<QQ", c.entry_start, c.entry_stop) for c in chunks
        )
    return footer + struct.pack("<Q", len(footer)) + FOOTER_MAGIC


def _parse_header(data) -> tuple[int, str, int]:
    """-> (flags, codec name, offset just past the header)."""
    if len(data) < 8:
        raise ValueError("truncated payload: header")
    flags, name_len = struct.unpack("<BB", bytes(data[6:8]))
    if len(data) < 8 + name_len:
        raise ValueError("truncated payload: codec id")
    name = bytes(data[8 : 8 + name_len]).decode("ascii")
    return flags, name, 8 + name_len


def _parse_chunk_index(data, header_end: int) -> list[ChunkEntry]:
    if len(data) < header_end + _TRAILER_LEN:
        raise ValueError("truncated payload: chunk trailer")
    if bytes(data[-4:]) != FOOTER_MAGIC:
        raise ValueError("truncated payload: chunk footer magic missing")
    (footer_len,) = struct.unpack("<Q", bytes(data[-12:-4]))
    footer_start = len(data) - _TRAILER_LEN - footer_len
    if footer_start < header_end:
        raise ValueError("corrupt payload: chunk footer overlaps header")
    footer = bytes(data[footer_start : footer_start + footer_len])
    if len(footer) < 4:
        raise ValueError("truncated payload: chunk index")
    (n,) = struct.unpack("<I", footer[:4])
    base_len = 4 + 20 * n
    ranges: list[tuple[int, int]] | None = None
    if len(footer) == base_len + 4 + 16 * n and footer[base_len : base_len + 4] == RANGES_MAGIC:
        ranges = [
            struct.unpack("<QQ", footer[base_len + 4 + 16 * i : base_len + 20 + 16 * i])
            for i in range(n)
        ]
    elif len(footer) != base_len:
        raise ValueError("corrupt payload: chunk index length mismatch")
    chunks = []
    for i in range(n):
        off, length, crc = struct.unpack("<QQI", footer[4 + 20 * i : 24 + 20 * i])
        if off < header_end or off + length > footer_start:
            raise ValueError("corrupt payload: chunk outside data region")
        start, stop = ranges[i] if ranges is not None else (None, None)
        chunks.append(ChunkEntry(off, length, crc, start, stop))
    return chunks


def read_chunk(data, chunk: ChunkEntry) -> bytes:
    raw = bytes(data[chunk.offset : chunk.offset + chunk.length])
    if len(raw) < chunk.length:
        raise ValueError("truncated payload: chunk body")
    if zlib.crc32(raw) & 0xFFFFFFFF != chunk.crc:
        raise ValueError("corrupt payload: chunk checksum mismatch")
    return raw


def save_bytes(enc: Encoded) -> bytes:
    body = enc.to_bytes()
    out = io.BytesIO()
    out.write(pack_header(enc.codec_name))
    out.write(struct.pack("<QI", len(body), zlib.crc32(body) & 0xFFFFFFFF))
    out.write(body)
    return out.getvalue()


def load_bytes(data: bytes) -> Encoded:
    if len(data) < 4 or bytes(data[:4]) != MAGIC:
        raise ValueError("not a TensorCodec container")
    if len(data) < 6:
        raise ValueError("truncated payload: version header")
    (version,) = struct.unpack("<H", bytes(data[4:6]))
    if version == _LEGACY_NTTD_VERSION:
        # headerless NTTD blob from core/serialization.py (older checkpoints)
        from repro.codecs.adapters import NTTDEncoded

        return NTTDEncoded.from_bytes(bytes(data))
    if version != VERSION:
        raise ValueError(f"unsupported container version {version}")
    flags, name, off = _parse_header(data)
    if flags & FLAG_CHUNKED:
        chunks = _parse_chunk_index(data, off)
        body = b"".join(read_chunk(data, c) for c in chunks)
    else:
        if len(data) < off + 12:
            raise ValueError("truncated payload: codec id")
        body_len, crc = struct.unpack("<QI", bytes(data[off : off + 12]))
        off += 12
        body = bytes(data[off : off + body_len])
        if len(body) < body_len:
            raise ValueError(
                f"truncated payload: body has {len(body)} of {body_len} bytes"
            )
        if zlib.crc32(body) & 0xFFFFFFFF != crc:
            raise ValueError("corrupt payload: body checksum mismatch")
    try:
        codec = get_codec(name)
    except KeyError:
        raise ValueError(f"unknown codec id {name!r} in container") from None
    return codec.encoded_cls.from_bytes(body)


def save_file(path: str, enc: Encoded) -> int:
    data = save_bytes(enc)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_file(path: str) -> Encoded:
    with open(path, "rb") as f:
        return load_bytes(f.read())


def open_chunks(path: str) -> tuple[str, list[ChunkEntry], memoryview]:
    """Open a v3 file lazily: parse header + chunk index, mmap the rest.

    Returns ``(codec_name, chunks, mmap-backed view)`` without reading any
    chunk bytes — the serve layer materializes chunks on demand through
    ``read_chunk``.  Monolithic files come back as one pseudo-chunk, so
    callers need not care how the payload was written.
    """
    with open(path, "rb") as f:
        mm = mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ)
    view = memoryview(mm)
    if len(view) < 6 or bytes(view[:4]) != MAGIC:
        raise ValueError(f"{path}: not a TensorCodec container")
    (version,) = struct.unpack("<H", bytes(view[4:6]))
    if version != VERSION:
        raise ValueError(
            f"{path}: lazy open needs a v{VERSION} container, got v{version}"
        )
    flags, name, off = _parse_header(view)
    if flags & FLAG_CHUNKED:
        chunks = _parse_chunk_index(view, off)
    else:
        if len(view) < off + 12:
            raise ValueError("truncated payload: codec id")
        body_len, crc = struct.unpack("<QI", bytes(view[off : off + 12]))
        if len(view) < off + 12 + body_len:
            raise ValueError("truncated payload: body")
        chunks = [ChunkEntry(off + 12, body_len, crc)]
    return name, chunks, view


def chunk_index(path: str) -> tuple[str, list[ChunkEntry]]:
    """Parse a v3 file's header + chunk index WITHOUT keeping it open.

    The fleet router builds its consistent-hash ring over exactly these
    entries (one key per chunk; entry ranges, when recorded, tell it which
    flat indices each chunk routes).  Unlike :func:`open_chunks` no mmap
    outlives the call — the ring only needs the index, never chunk bytes.
    """
    name, chunks, view = open_chunks(path)
    mm = view.obj
    view.release()
    if hasattr(mm, "close"):
        mm.close()
    return name, chunks
