"""Versioned self-describing container for ANY registered codec.

Extends the original NTTD-only TCDC layout (core/serialization.py, v2)
with a codec-id header, so every codec round-trips to disk bit-exactly:

    magic 'TCDC' | u16 version=3 | u8 flags | u8 name_len | name ascii
    u64 body_len | u32 crc32(body) | body

The body is the codec's own ``Encoded.to_bytes()`` payload; for NTTD it
is exactly the legacy v2 blob, and ``load_bytes`` still accepts bare v2
blobs (headerless NTTD payloads written by older checkpoints).

Array (de)serialization helpers are shared by the adapter bodies:
``write_array``/``read_array`` preserve dtype and shape so float64
baselines round-trip bit-exactly.
"""
from __future__ import annotations

import io
import struct
import zlib

import numpy as np

from repro.codecs.base import Encoded, get_codec

MAGIC = b"TCDC"
VERSION = 3
_LEGACY_NTTD_VERSION = 2

_DTYPES = {
    0: np.float16,
    1: np.float32,
    2: np.float64,
    3: np.int32,
    4: np.int64,
    5: np.uint8,
}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


# ---------------------------------------------------------------------------
# array helpers (used by adapter to_bytes/from_bytes bodies)
# ---------------------------------------------------------------------------
def write_array(out: io.BytesIO, arr: np.ndarray) -> None:
    """u8 dtype-code | u8 ndim | ndim x u64 shape | raw bytes (C order)."""
    arr = np.ascontiguousarray(arr)
    out.write(struct.pack("<BB", _DTYPE_CODES[arr.dtype], arr.ndim))
    out.write(np.asarray(arr.shape, dtype=np.uint64).tobytes())
    out.write(arr.tobytes())


def pack_arrays(*arrays: np.ndarray) -> bytes:
    """u8 count | count x array — the shared body framing for the
    decomposition codecs (TT/Tucker/CP/TR cores and factors)."""
    if len(arrays) > 255:
        raise ValueError("too many arrays for u8 count")
    out = io.BytesIO()
    out.write(struct.pack("<B", len(arrays)))
    for arr in arrays:
        write_array(out, arr)
    return out.getvalue()


def unpack_arrays(data: bytes) -> list[np.ndarray]:
    buf = io.BytesIO(data)
    head = buf.read(1)
    if not head:
        raise ValueError("truncated payload: array count")
    (n,) = struct.unpack("<B", head)
    return [read_array(buf) for _ in range(n)]


def read_array(buf: io.BytesIO) -> np.ndarray:
    head = buf.read(2)
    if len(head) < 2:
        raise ValueError("truncated payload: array header")
    code, ndim = struct.unpack("<BB", head)
    if code not in _DTYPES:
        raise ValueError(f"corrupt payload: unknown dtype code {code}")
    shape = tuple(np.frombuffer(buf.read(8 * ndim), dtype=np.uint64).astype(int))
    dtype = np.dtype(_DTYPES[code])
    nbytes = int(np.prod(shape)) * dtype.itemsize if ndim else dtype.itemsize
    raw = buf.read(nbytes)
    if len(raw) < nbytes:
        raise ValueError("truncated payload: array body")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------
def save_bytes(enc: Encoded) -> bytes:
    name = enc.codec_name.encode("ascii")
    if not name or len(name) > 255:
        raise ValueError(f"bad codec id {enc.codec_name!r}")
    body = enc.to_bytes()
    out = io.BytesIO()
    out.write(MAGIC)
    out.write(struct.pack("<HBB", VERSION, 0, len(name)))
    out.write(name)
    out.write(struct.pack("<QI", len(body), zlib.crc32(body) & 0xFFFFFFFF))
    out.write(body)
    return out.getvalue()


def load_bytes(data: bytes) -> Encoded:
    if len(data) < 4 or data[:4] != MAGIC:
        raise ValueError("not a TensorCodec container")
    if len(data) < 6:
        raise ValueError("truncated payload: version header")
    (version,) = struct.unpack("<H", data[4:6])
    if version == _LEGACY_NTTD_VERSION:
        # headerless NTTD blob from core/serialization.py (older checkpoints)
        from repro.codecs.adapters import NTTDEncoded

        return NTTDEncoded.from_bytes(data)
    if version != VERSION:
        raise ValueError(f"unsupported container version {version}")
    if len(data) < 8:
        raise ValueError("truncated payload: header")
    _flags, name_len = struct.unpack("<BB", data[6:8])
    off = 8
    if len(data) < off + name_len + 12:
        raise ValueError("truncated payload: codec id")
    name = data[off : off + name_len].decode("ascii")
    off += name_len
    body_len, crc = struct.unpack("<QI", data[off : off + 12])
    off += 12
    body = data[off : off + body_len]
    if len(body) < body_len:
        raise ValueError(
            f"truncated payload: body has {len(body)} of {body_len} bytes"
        )
    if zlib.crc32(body) & 0xFFFFFFFF != crc:
        raise ValueError("corrupt payload: body checksum mismatch")
    try:
        codec = get_codec(name)
    except KeyError:
        raise ValueError(f"unknown codec id {name!r} in container") from None
    return codec.encoded_cls.from_bytes(body)


def save_file(path: str, enc: Encoded) -> int:
    data = save_bytes(enc)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_file(path: str) -> Encoded:
    with open(path, "rb") as f:
        return load_bytes(f.read())
