"""Adapters wrapping the six existing compressors behind the Codec protocol.

Registered names (see base.register): ``nttd`` (the paper's TensorCodec),
``ttd``, ``tucker``, ``cpd``, ``tensor_ring`` (decomposition competitors),
and ``szlite`` (error-bounded entropy coder).  Each adapter translates the
shared byte ``budget`` into its native knob and implements batched
``decode_at`` at original indices so the serve layer can query entries
without densifying (SZ-lite, which is inherently a stream codec, caches
one dense reconstruction).

Example, end to end::

    from repro.codecs import get_codec

    enc = get_codec("nttd").fit(x, rank=8, hidden=16, epochs=30)
    blob = enc.save()                      # self-describing container
    enc2 = repro.codecs.load_bytes(blob)   # any codec id dispatches
    enc2.decode_at(np.array([[3, 1, 4]]))
"""
from __future__ import annotations

import dataclasses
import string
from typing import Any

import numpy as np

from repro.codecs import container
from repro.codecs.base import Codec, Encoded, register
from repro.core import codec as codec_lib
from repro.core import cpd, serialization, szlite, tensor_ring, ttd, tucker
from repro.core.folding import make_folding_spec


def _as_index_batch(indices: np.ndarray, d: int) -> np.ndarray:
    idx = np.asarray(indices)
    if idx.ndim != 2 or idx.shape[1] != d:
        raise ValueError(f"indices must be [B, {d}], got {idx.shape}")
    return idx


# ---------------------------------------------------------------------------
# NTTD (the paper's codec)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NTTDEncoded(Encoded):
    ct: codec_lib.CompressedTensor
    log: codec_lib.CompressionLog | None = None

    @property
    def pi(self) -> list[np.ndarray]:
        """Learned mode orderings (paper pi) — exposed for order-quality
        analysis (benchmarks/fig7)."""
        return self.ct.pi

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.ct.spec.shape)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = _as_index_batch(indices, len(self.ct.spec.shape))
        return self.ct.decode(idx)

    def to_dense(self) -> np.ndarray:
        return self.ct.to_dense()

    def fitness(self, x: np.ndarray) -> float:
        return self.ct.fitness(np.asarray(x, np.float32))

    def payload_bytes(self) -> int:
        return self.ct.payload_bytes(NTTDCodec.bytes_per_param)

    def to_bytes(self) -> bytes:
        # params are stored as fp32, so the fp32 body round-trips bit-exactly
        return serialization.save_bytes(self.ct, np.float32)

    @classmethod
    def from_bytes(cls, data: bytes) -> "NTTDEncoded":
        return cls(serialization.load_bytes(data))


@register("nttd")
class NTTDCodec(Codec):
    encoded_cls = NTTDEncoded

    def stream_fitter(
        self, shape: tuple[int, ...], budget: int | None = None, **opts: Any
    ):
        """Native streaming: warm-started minibatch SGD with reservoir
        replay (repro.stream.fit.NTTDStreamFitter).  Budget translates to
        (rank, hidden) exactly as in ``fit``."""
        from repro.stream.fit import NTTDStreamFitter

        if budget is not None and "rank" not in opts:
            rank = self._rank_for_budget(tuple(shape), int(budget), opts)
            opts = {**opts, "rank": rank, "hidden": opts.get("hidden", 2 * rank)}
        return NTTDStreamFitter(tuple(shape), **opts)

    def fit(self, x: np.ndarray, budget: int | None = None, **opts: Any) -> NTTDEncoded:
        """Options are :class:`repro.core.codec.CodecConfig` fields.  When a
        byte ``budget`` is given without an explicit ``rank``, the largest
        (rank, hidden=2*rank) architecture whose §V-A payload fits is used."""
        if budget is not None and "rank" not in opts:
            rank = self._rank_for_budget(x.shape, int(budget), opts)
            opts = {**opts, "rank": rank, "hidden": opts.get("hidden", 2 * rank)}
        ct, log = codec_lib.compress(np.asarray(x, np.float32),
                                     codec_lib.CodecConfig(**opts))
        return NTTDEncoded(ct, log)

    def _rank_for_budget(
        self, shape: tuple[int, ...], budget: int, opts: dict
    ) -> int:
        import jax

        from repro.core import nttd

        spec = make_folding_spec(shape, opts.get("d_prime"))
        best = 0
        floor = None
        for rank in range(1, 129):
            cfg = nttd.NTTDConfig(rank=rank, hidden=opts.get("hidden", 2 * rank))
            tmpl = jax.eval_shape(
                lambda key, _s=spec, _c=cfg: nttd.init_params(key, _s, _c),
                jax.random.PRNGKey(0),
            )
            n_params = sum(
                int(np.prod(leaf.shape)) for leaf in jax.tree_util.tree_leaves(tmpl)
            )
            bits = codec_lib.nttd_payload_bits(n_params, shape, self.bytes_per_param)
            nbytes = (bits + 7) // 8
            floor = nbytes if floor is None else floor
            if nbytes > budget:
                break
            best = rank
        if best == 0:
            raise ValueError(
                f"nttd cannot meet budget={budget}B: rank-1 payload is {floor}B"
            )
        return best


# ---------------------------------------------------------------------------
# TT-SVD
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TTEncoded(Encoded):
    tt: ttd.TTDecomposition

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(c.shape[1] for c in self.tt.cores)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = _as_index_batch(indices, len(self.tt.cores))
        v = np.ones((idx.shape[0], 1))
        for k, core in enumerate(self.tt.cores):
            v = np.einsum("br,rbs->bs", v, core[:, idx[:, k], :])
        return v[:, 0]

    def to_dense(self) -> np.ndarray:
        return self.tt.to_dense()

    def payload_bytes(self) -> int:
        return self.tt.payload_bytes(TTDCodec.bytes_per_param)

    def to_bytes(self) -> bytes:
        return container.pack_arrays(*self.tt.cores)

    @classmethod
    def from_bytes(cls, data: bytes) -> "TTEncoded":
        return cls(ttd.TTDecomposition(container.unpack_arrays(data)))


@register("ttd")
class TTDCodec(Codec):
    encoded_cls = TTEncoded

    def stream_fitter(
        self,
        shape: tuple[int, ...],
        budget: int | None = None,
        *,
        max_rank: int | None = None,
        rel_eps: float = 0.02,
    ):
        """Native streaming: TT-ICE-style incremental basis expansion over
        mode-0 slices (repro.stream.fit.TTICEStreamFitter)."""
        from repro.stream.fit import TTICEStreamFitter

        if max_rank is None:
            if budget is None:
                raise ValueError("ttd.stream_fitter needs a budget or max_rank")
            max_rank = max(
                ttd.tt_rank_for_budget(
                    tuple(shape), int(budget) // self.bytes_per_param
                ),
                1,
            )
        return TTICEStreamFitter(tuple(shape), max_rank=max_rank, rel_eps=rel_eps)

    def fit(
        self,
        x: np.ndarray,
        budget: int | None = None,
        *,
        max_rank: int | None = None,
        eps: float | None = None,
    ) -> TTEncoded:
        if max_rank is None and eps is None:
            if budget is None:
                raise ValueError("ttd.fit needs a budget, max_rank, or eps")
            max_rank = max(
                ttd.tt_rank_for_budget(x.shape, int(budget) // self.bytes_per_param), 1
            )
        return TTEncoded(ttd.tt_svd(x, max_rank=max_rank, eps=eps))


# ---------------------------------------------------------------------------
# Tucker (HOSVD + HOOI)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TuckerEncoded(Encoded):
    tk: tucker.TuckerDecomposition

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(f.shape[0] for f in self.tk.factors)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        d = self.tk.core.ndim
        idx = _as_index_batch(indices, d)
        letters = [c for c in string.ascii_letters if c != "i"]  # 'i' = batch
        if d > len(letters):
            raise ValueError(f"tucker decode_at supports up to {len(letters)} modes")
        subs = letters[:d]
        eq = "".join(subs) + "," + ",".join("i" + s for s in subs) + "->i"
        rows = [f[idx[:, k]] for k, f in enumerate(self.tk.factors)]
        return np.einsum(eq, self.tk.core, *rows, optimize=True)

    def to_dense(self) -> np.ndarray:
        return self.tk.to_dense()

    def payload_bytes(self) -> int:
        return self.tk.payload_bytes(TuckerCodec.bytes_per_param)

    def to_bytes(self) -> bytes:
        return container.pack_arrays(self.tk.core, *self.tk.factors)

    @classmethod
    def from_bytes(cls, data: bytes) -> "TuckerEncoded":
        core, *factors = container.unpack_arrays(data)
        return cls(tucker.TuckerDecomposition(core, factors))


@register("tucker")
class TuckerCodec(Codec):
    encoded_cls = TuckerEncoded

    def fit(
        self,
        x: np.ndarray,
        budget: int | None = None,
        *,
        ranks: list[int] | None = None,
        iters: int = 5,
    ) -> TuckerEncoded:
        if ranks is None:
            if budget is None:
                raise ValueError("tucker.fit needs a budget or ranks")
            ranks = tucker.tucker_ranks_for_budget(
                x.shape, int(budget) // self.bytes_per_param
            )
        return TuckerEncoded(tucker.tucker_hooi(x, ranks, iters=iters))


# ---------------------------------------------------------------------------
# CP (ALS)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CPEncoded(Encoded):
    cp: cpd.CPDecomposition

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(f.shape[0] for f in self.cp.factors)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = _as_index_batch(indices, len(self.cp.factors))
        prod = np.broadcast_to(
            self.cp.weights, (idx.shape[0], self.cp.weights.shape[0])
        ).copy()
        for k, f in enumerate(self.cp.factors):
            prod *= f[idx[:, k]]
        return prod.sum(axis=1)

    def to_dense(self) -> np.ndarray:
        return self.cp.to_dense()

    def payload_bytes(self) -> int:
        return self.cp.payload_bytes(CPDCodec.bytes_per_param)

    def to_bytes(self) -> bytes:
        return container.pack_arrays(self.cp.weights, *self.cp.factors)

    @classmethod
    def from_bytes(cls, data: bytes) -> "CPEncoded":
        weights, *factors = container.unpack_arrays(data)
        return cls(cpd.CPDecomposition(weights, factors))


@register("cpd")
class CPDCodec(Codec):
    encoded_cls = CPEncoded

    def fit(
        self,
        x: np.ndarray,
        budget: int | None = None,
        *,
        rank: int | None = None,
        iters: int = 25,
        seed: int = 0,
    ) -> CPEncoded:
        if rank is None:
            if budget is None:
                raise ValueError("cpd.fit needs a budget or rank")
            rank = cpd.cp_rank_for_budget(x.shape, int(budget) // self.bytes_per_param)
        return CPEncoded(cpd.cp_als(x, rank, iters=iters, seed=seed))


# ---------------------------------------------------------------------------
# Tensor-Ring (TR-SVD)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TREncoded(Encoded):
    tr: tensor_ring.TRDecomposition

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(c.shape[1] for c in self.tr.cores)

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = _as_index_batch(indices, len(self.tr.cores))
        v: np.ndarray | None = None
        for k, core in enumerate(self.tr.cores):
            slab = core[:, idx[:, k], :]  # [r_prev, B, r_next]
            if v is None:
                v = np.moveaxis(slab, 1, 0)  # [B, r0, r1]
            else:
                v = np.einsum("bpr,rbs->bps", v, slab)
        return np.trace(v, axis1=1, axis2=2)

    def to_dense(self) -> np.ndarray:
        return self.tr.to_dense()

    def payload_bytes(self) -> int:
        return self.tr.payload_bytes(TRCodec.bytes_per_param)

    def to_bytes(self) -> bytes:
        return container.pack_arrays(*self.tr.cores)

    @classmethod
    def from_bytes(cls, data: bytes) -> "TREncoded":
        return cls(tensor_ring.TRDecomposition(container.unpack_arrays(data)))


@register("tensor_ring")
class TRCodec(Codec):
    encoded_cls = TREncoded

    def fit(
        self,
        x: np.ndarray,
        budget: int | None = None,
        *,
        max_rank: int | None = None,
    ) -> TREncoded:
        if max_rank is None:
            if budget is None:
                raise ValueError("tensor_ring.fit needs a budget or max_rank")
            # a ring needs r >= 2 to be distinct from TT
            max_rank = max(
                tensor_ring.tr_rank_for_budget(
                    x.shape, int(budget) // self.bytes_per_param
                ),
                2,
            )
        return TREncoded(tensor_ring.tr_svd(x, max_rank))


# ---------------------------------------------------------------------------
# SZ-lite (error-bounded, entropy-coded)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SZEncoded(Encoded):
    sz: szlite.SZCompressed
    #: rebuilds vs reuses of the dense reconstruction cache; the serve
    #: layer's byte-budgeted LRU reads these and evicts via drop_caches()
    cache_hits: int = dataclasses.field(default=0, compare=False)
    cache_misses: int = dataclasses.field(default=0, compare=False)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.sz.shape)

    @property
    def _dense(self) -> np.ndarray:
        # stream codec: one cached full decompression backs decode_at;
        # droppable (and re-buildable) under a serve-side byte budget
        cached = getattr(self, "_dense_cache", None)
        if cached is None:
            self.cache_misses += 1
            cached = szlite.decompress(self.sz)
            self._dense_cache = cached
        else:
            self.cache_hits += 1
        return cached

    def cache_nbytes(self) -> int:
        cached = getattr(self, "_dense_cache", None)
        return int(cached.nbytes) if cached is not None else 0

    def drop_caches(self) -> None:
        self._dense_cache = None

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        idx = _as_index_batch(indices, len(self.sz.shape))
        return self._dense[tuple(idx[:, k] for k in range(idx.shape[1]))]

    def to_dense(self) -> np.ndarray:
        # copy: the cache also backs decode_at, so callers must not alias it
        return self._dense.copy()

    def payload_bytes(self) -> int:
        # entropy-coded: the payload IS the stored bytes, no fp convention
        return self.sz.payload_bytes()

    def to_bytes(self) -> bytes:
        # same shared framing as the decomposition codecs: shape, error
        # bound, and the entropy-coded stream as three arrays
        return container.pack_arrays(
            np.asarray(self.sz.shape, dtype=np.int64),
            np.asarray([self.sz.error_bound], dtype=np.float64),
            np.frombuffer(self.sz.data, dtype=np.uint8),
        )

    @classmethod
    def from_bytes(cls, data: bytes) -> "SZEncoded":
        shape, error_bound, stream = container.unpack_arrays(data)
        return cls(
            szlite.SZCompressed(
                stream.tobytes(), tuple(int(n) for n in shape), float(error_bound[0])
            )
        )


@register("szlite")
class SZLiteCodec(Codec):
    encoded_cls = SZEncoded

    def fit(
        self,
        x: np.ndarray,
        budget: int | None = None,
        *,
        error_bound: float | None = None,
        search_iters: int = 24,
    ) -> SZEncoded:
        """With an explicit ``error_bound``, compress directly.  With a byte
        ``budget``, bisect (on log error bound) for the tightest bound whose
        payload fits.  Raises if even the loosest bound overshoots the
        budget (the entropy-coded stream has a size floor that grows with
        the tensor) — a silently oversized payload would make
        budget-matched comparisons unfair."""
        if error_bound is not None:
            return SZEncoded(szlite.compress(x, error_bound))
        if budget is None:
            raise ValueError("szlite.fit needs a budget or error_bound")
        spread = float(np.ptp(x)) or 1.0
        lo, hi = np.log(spread * 1e-9), np.log(spread * 4.0)
        best = szlite.compress(x, float(np.exp(hi)))
        if best.payload_bytes() > budget:
            raise ValueError(
                f"szlite cannot meet budget={budget}B: stream floor is "
                f"{best.payload_bytes()}B for {x.size} entries"
            )
        for _ in range(search_iters):
            mid = (lo + hi) / 2
            cand = szlite.compress(x, float(np.exp(mid)))
            if cand.payload_bytes() <= budget:
                best, hi = cand, mid
            else:
                lo = mid
        return SZEncoded(best)
