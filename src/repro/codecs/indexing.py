"""Flat <-> multi index helpers shared across the codec stack.

Every layer that addresses tensor entries — codec adapters, slab sources,
the serve layer's decode tiles, and the fleet router — needs the same
row-major flat/multi conversion.  It lived in ``repro.core.nttd`` for
historical reasons; this module is the canonical home (numpy-only, no
codec imports, safe to import from anywhere).  ``repro.core.nttd``
re-exports ``flat_to_multi`` for compatibility.
"""
from __future__ import annotations

import numpy as np


def flat_to_multi(flat: np.ndarray, shape: tuple[int, ...]) -> np.ndarray:
    """Row-major flat index [N] -> multi-index [N, d] (numpy)."""
    dims = np.array(shape, dtype=np.int64)
    radix = np.concatenate([np.cumprod(dims[::-1])[::-1][1:], [1]])
    return (flat[:, None] // radix) % dims


def multi_to_flat(indices: np.ndarray, shape: tuple[int, ...]) -> np.ndarray:
    """Row-major multi-index [N, d] -> flat index [N] (numpy int64).

    Inverse of :func:`flat_to_multi`; the fleet router uses it to map a
    query batch onto the flat entry space that chunk ranges and decode
    tiles partition.
    """
    idx = np.asarray(indices)
    return np.ravel_multi_index(
        tuple(idx[:, k] for k in range(idx.shape[1])), shape
    ).astype(np.int64)


def validate_indices(
    name: str, shape: tuple[int, ...], indices: np.ndarray
) -> np.ndarray:
    """Reject a malformed query batch before it reaches any decode path.

    Shared by ``CodecService`` and the fleet frontend so both layers
    accept exactly the same requests: [B, d] integral indices inside
    ``shape``.  Returns the validated array."""
    idx = np.asarray(indices)
    if idx.ndim != 2 or idx.shape[1] != len(shape):
        raise ValueError(
            f"indices for {name!r} must be [B, {len(shape)}], got {idx.shape}"
        )
    if not np.issubdtype(idx.dtype, np.integer):
        raise ValueError(f"indices must be integral, got {idx.dtype}")
    if idx.size and ((idx < 0).any() or (idx >= np.asarray(shape)).any()):
        raise ValueError(f"indices out of range for shape {shape}")
    return idx
