"""Unified codec API: one protocol, registry, and container for all six
compressors (paper §V's comparison set behind a single interface).

    from repro.codecs import available, get_codec, load_bytes

    enc = get_codec("nttd").fit(x, rank=8, hidden=16, epochs=30)
    for name in available():          # budget-matched competitors
        rival = get_codec(name).fit(x, enc.payload_bytes())

    blob = enc.save()                 # versioned self-describing container
    load_bytes(blob).decode_at(idx)   # codec-id header dispatches decoding

Modules: ``base`` (protocol + registry), ``adapters`` (the six wrappers,
imported here so they self-register), ``container`` (on-disk format).
"""
from repro.codecs.base import Codec, Encoded, available, get_codec, register
from repro.codecs import adapters  # noqa: F401  (self-registers the codecs)
from repro.codecs.container import load_bytes, load_file, save_bytes, save_file

__all__ = [
    "Codec",
    "Encoded",
    "available",
    "get_codec",
    "register",
    "load_bytes",
    "load_file",
    "save_bytes",
    "save_file",
]
