"""The `Codec` protocol and string-keyed registry.

Every compressor in the repo — the paper's NTTD-based TensorCodec and the
five §V competitors (TT, Tucker, CP, TR, SZ-lite) — is exposed behind one
interface so benchmarks, checkpoint compression, and the serve layer can
treat them as interchangeable fit/query backends:

    from repro.codecs import get_codec, available

    enc = get_codec("nttd").fit(x, budget_bytes)   # or codec-specific opts
    enc.fitness(x)                 # 1 - ||x - x_hat|| / ||x||
    enc.decode_at(indices)         # entries at ORIGINAL indices, [B, d] -> [B]
    enc.to_dense()                 # full reconstruction
    enc.payload_bytes()            # paper §V-A accounting (one convention)
    blob = enc.save()              # self-describing container (container.py)

`budget` is a payload budget in BYTES under the shared accounting
convention (`Codec.bytes_per_param` = 8, the paper's fp64 convention);
each adapter translates it into its native knob (TT/TR/CP rank, Tucker
rank vector, SZ error bound, NTTD rank/hidden).  Codec-specific keyword
options bypass the budget translation when given explicitly.
"""
from __future__ import annotations

import abc
from typing import Any, ClassVar

import numpy as np


class Encoded(abc.ABC):
    """A fitted compressed payload: query, account, and serialize.

    ``codec_name`` is stamped by ``@register`` and is the id written into
    the container header, so a payload loaded from disk knows which codec
    decodes it.
    """

    codec_name: ClassVar[str] = "?"

    @property
    @abc.abstractmethod
    def shape(self) -> tuple[int, ...]:
        """Shape of the original tensor this payload encodes — the index
        space ``decode_at`` addresses."""

    # -- querying ------------------------------------------------------------
    @abc.abstractmethod
    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        """Approximate entries at ORIGINAL indices: [B, d] int -> [B]."""

    @abc.abstractmethod
    def to_dense(self) -> np.ndarray:
        """Full reconstruction in original index order."""

    def fitness(self, x: np.ndarray) -> float:
        """Paper Eq. 1: 1 - ||x - x_hat||_F / ||x||_F on the raw tensor."""
        x64 = np.asarray(x, dtype=np.float64)
        err = float(np.linalg.norm(x64 - np.asarray(self.to_dense(), np.float64)))
        return 1.0 - err / max(float(np.linalg.norm(x64)), 1e-30)

    # -- accounting ----------------------------------------------------------
    @abc.abstractmethod
    def payload_bytes(self) -> int:
        """Compressed size under the shared §V-A accounting convention."""

    # -- serialization (container body; header added by container.py) --------
    @abc.abstractmethod
    def to_bytes(self) -> bytes:
        """Codec-specific body bytes.  Bit-exact round-trip contract:
        ``from_bytes(to_bytes())`` decodes identically."""

    @classmethod
    @abc.abstractmethod
    def from_bytes(cls, data: bytes) -> "Encoded":
        """Inverse of ``to_bytes``."""

    def save(self) -> bytes:
        """Full self-describing container (header + body)."""
        from repro.codecs import container

        return container.save_bytes(self)

    # -- serve-layer cache hooks ---------------------------------------------
    def cache_nbytes(self) -> int:
        """Bytes of droppable decode acceleration state this payload holds
        (e.g. SZ-lite's cached dense reconstruction).  The serve layer's
        byte-budgeted LRU accounts and evicts through these two hooks."""
        return 0

    def drop_caches(self) -> None:
        """Release droppable decode state; decoding stays correct, the next
        query just pays the rebuild."""


class StreamFitter(abc.ABC):
    """Incremental fit state: feed slabs with ``update``, then ``finalize``.

    The streaming analogue of ``Codec.fit`` — a fitter is handed
    ``(indices, values)`` slabs one at a time (see ``repro.stream.source``)
    and must be deterministic in the slab sequence, so a fit resumed from a
    source cursor produces a bit-identical payload to an uninterrupted run.
    """

    @abc.abstractmethod
    def update(self, indices: np.ndarray, values: np.ndarray) -> None:
        """Incorporate one slab: original multi-indices [B, d] + values [B]."""

    @abc.abstractmethod
    def finalize(self) -> Encoded:
        """Produce the payload for everything seen so far."""


class AccumulatingFitter(StreamFitter):
    """Fallback for codecs without native streaming: scatter arriving slabs
    into a dense buffer, then run the one-shot ``fit``.  Correct for any
    codec but NOT out-of-core — the buffer is the full tensor."""

    def __init__(self, codec: "Codec", shape: tuple[int, ...],
                 budget: int | None, opts: dict[str, Any]):
        self._codec = codec
        self._budget = budget
        self._opts = opts
        self._x = np.zeros(shape, dtype=np.float32)

    def update(self, indices: np.ndarray, values: np.ndarray) -> None:
        idx = np.asarray(indices)
        self._x[tuple(idx[:, k] for k in range(idx.shape[1]))] = np.asarray(
            values, np.float32
        )

    def finalize(self) -> Encoded:
        return self._codec.fit(self._x, self._budget, **self._opts)


class Codec(abc.ABC):
    """A fit backend producing :class:`Encoded` payloads."""

    name: ClassVar[str] = "?"
    encoded_cls: ClassVar[type[Encoded]]
    #: the paper's §V-A size convention: every parameter is accounted as
    #: fp64 regardless of the dtype it is *stored* at.  All registered
    #: codecs share this value so budget-matched comparisons are fair;
    #: tests assert the conventions agree.
    bytes_per_param: ClassVar[int] = 8

    @abc.abstractmethod
    def fit(self, x: np.ndarray, budget: int | None = None, **opts: Any) -> Encoded:
        """Compress ``x`` to at most ``budget`` payload bytes (accounting
        convention), or per ``opts`` when codec-native knobs are given."""

    # -- streaming (optional hook; repro.stream drives it) -------------------
    def stream_fitter(
        self, shape: tuple[int, ...], budget: int | None = None, **opts: Any
    ) -> StreamFitter:
        """Return an incremental fitter for a tensor of ``shape``.  Codecs
        with native streaming (NTTD's warm-started SGD, TT's TT-ICE-style
        update) override this; the default accumulates then fits."""
        return AccumulatingFitter(self, tuple(int(s) for s in shape), budget, opts)

    def fit_stream(
        self,
        source: Any,
        budget: int | None = None,
        *,
        start: int = 0,
        stop: int | None = None,
        passes: int = 1,
        fitter: StreamFitter | None = None,
        **opts: Any,
    ) -> Encoded:
        """Fit over a :class:`repro.stream.SlabSource` cursor range.

        ``passes`` re-reads the cursor range that many times (the resumable
        source makes multi-epoch out-of-core training a re-read, not a
        materialization) — iterative fitters (NTTD) keep improving, one-shot
        fitters just see repeated data.  Pass a ``fitter`` (from
        ``stream_fitter``) to resume: processing slabs ``[0, k)`` then
        ``[k, n)`` on one fitter yields a payload bit-identical to
        processing ``[0, n)`` in one call.
        """
        if fitter is None:
            fitter = self.stream_fitter(tuple(source.shape), budget, **opts)
        elif opts or budget is not None:
            raise ValueError("budget/opts belong to stream_fitter, not resume")
        stop = source.n_slabs if stop is None else stop
        for _ in range(passes):
            for cursor in range(start, stop):
                slab = source.slab_at(cursor)
                fitter.update(slab.indices, slab.values)
        return fitter.finalize()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Codec] = {}


def register(name: str):
    """Class decorator: instantiate the codec and register it under ``name``."""

    def deco(cls: type[Codec]) -> type[Codec]:
        cls.name = name
        cls.encoded_cls.codec_name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {', '.join(available())}"
        ) from None


def available() -> list[str]:
    """Sorted names of all registered codecs."""
    return sorted(_REGISTRY)
