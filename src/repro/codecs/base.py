"""The `Codec` protocol and string-keyed registry.

Every compressor in the repo — the paper's NTTD-based TensorCodec and the
five §V competitors (TT, Tucker, CP, TR, SZ-lite) — is exposed behind one
interface so benchmarks, checkpoint compression, and the serve layer can
treat them as interchangeable fit/query backends:

    from repro.codecs import get_codec, available

    enc = get_codec("nttd").fit(x, budget_bytes)   # or codec-specific opts
    enc.fitness(x)                 # 1 - ||x - x_hat|| / ||x||
    enc.decode_at(indices)         # entries at ORIGINAL indices, [B, d] -> [B]
    enc.to_dense()                 # full reconstruction
    enc.payload_bytes()            # paper §V-A accounting (one convention)
    blob = enc.save()              # self-describing container (container.py)

`budget` is a payload budget in BYTES under the shared accounting
convention (`Codec.bytes_per_param` = 8, the paper's fp64 convention);
each adapter translates it into its native knob (TT/TR/CP rank, Tucker
rank vector, SZ error bound, NTTD rank/hidden).  Codec-specific keyword
options bypass the budget translation when given explicitly.
"""
from __future__ import annotations

import abc
from typing import Any, ClassVar

import numpy as np


class Encoded(abc.ABC):
    """A fitted compressed payload: query, account, and serialize.

    ``codec_name`` is stamped by ``@register`` and is the id written into
    the container header, so a payload loaded from disk knows which codec
    decodes it.
    """

    codec_name: ClassVar[str] = "?"

    @property
    @abc.abstractmethod
    def shape(self) -> tuple[int, ...]:
        """Shape of the original tensor this payload encodes — the index
        space ``decode_at`` addresses."""

    # -- querying ------------------------------------------------------------
    @abc.abstractmethod
    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        """Approximate entries at ORIGINAL indices: [B, d] int -> [B]."""

    @abc.abstractmethod
    def to_dense(self) -> np.ndarray:
        """Full reconstruction in original index order."""

    def fitness(self, x: np.ndarray) -> float:
        """Paper Eq. 1: 1 - ||x - x_hat||_F / ||x||_F on the raw tensor."""
        x64 = np.asarray(x, dtype=np.float64)
        err = float(np.linalg.norm(x64 - np.asarray(self.to_dense(), np.float64)))
        return 1.0 - err / max(float(np.linalg.norm(x64)), 1e-30)

    # -- accounting ----------------------------------------------------------
    @abc.abstractmethod
    def payload_bytes(self) -> int:
        """Compressed size under the shared §V-A accounting convention."""

    # -- serialization (container body; header added by container.py) --------
    @abc.abstractmethod
    def to_bytes(self) -> bytes:
        """Codec-specific body bytes.  Bit-exact round-trip contract:
        ``from_bytes(to_bytes())`` decodes identically."""

    @classmethod
    @abc.abstractmethod
    def from_bytes(cls, data: bytes) -> "Encoded":
        """Inverse of ``to_bytes``."""

    def save(self) -> bytes:
        """Full self-describing container (header + body)."""
        from repro.codecs import container

        return container.save_bytes(self)


class Codec(abc.ABC):
    """A fit backend producing :class:`Encoded` payloads."""

    name: ClassVar[str] = "?"
    encoded_cls: ClassVar[type[Encoded]]
    #: the paper's §V-A size convention: every parameter is accounted as
    #: fp64 regardless of the dtype it is *stored* at.  All registered
    #: codecs share this value so budget-matched comparisons are fair;
    #: tests assert the conventions agree.
    bytes_per_param: ClassVar[int] = 8

    @abc.abstractmethod
    def fit(self, x: np.ndarray, budget: int | None = None, **opts: Any) -> Encoded:
        """Compress ``x`` to at most ``budget`` payload bytes (accounting
        convention), or per ``opts`` when codec-native knobs are given."""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Codec] = {}


def register(name: str):
    """Class decorator: instantiate the codec and register it under ``name``."""

    def deco(cls: type[Codec]) -> type[Codec]:
        cls.name = name
        cls.encoded_cls.codec_name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {', '.join(available())}"
        ) from None


def available() -> list[str]:
    """Sorted names of all registered codecs."""
    return sorted(_REGISTRY)
