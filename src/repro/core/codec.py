"""TensorCodec: the end-to-end compressor (paper Alg. 1).

Alternating optimization:
  1. init pi (2-approx metric TSP, §IV-D) and theta (NTTD, §IV-B)
  2. minibatch-Adam epochs on theta over entries of the reordered, folded
     tensor
  3. every ``reorder_every`` epochs: Alg. 3 pi refinement, then Adam state
     re-initialization (paper: the loss surface changed)
  4. stop when fitness converges

The training step is a single pjit-able program (data-parallel over
sampled entries); ``shard_batch`` hooks it onto a mesh when one is active.

Prefer the unified API for new code — this module is the NTTD backend
behind ``repro.codecs.get_codec("nttd").fit(x, budget)``, which also
handles serialization and budget-matched comparisons against the other
registered codecs.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.codecs.indexing import flat_to_multi
from repro.core import nttd, reorder
from repro.core.folding import FoldingSpec, make_folding_spec
from repro.optim import optimizers


@dataclasses.dataclass
class CodecConfig:
    rank: int = 8
    hidden: int = 16
    d_prime: int | None = None
    epochs: int = 60
    batch_size: int = 16384
    lr: float = 5e-3
    init_reorder: bool = True      # TSP init (off => TensorCodec-T ablation)
    update_reorder: bool = True    # Alg.3 refinement (off => TensorCodec-R)
    reorder_every: int = 5         # epochs between Alg.3 sweeps
    reorder_warmup: int = 5        # epochs of theta fitting before first sweep
    reorder_samples: int = 4096    # sampled entries per slice for delta-loss
    normalize: bool = True         # standardize input (2 floats in payload)
    seed: int = 0
    kernel_impl: str = "ref"
    entries_per_epoch: int | None = None  # cap for very large tensors
    tol: float = 1e-4              # fitness convergence tolerance
    patience: int = 3
    eval_batch: int = 65536
    verbose: bool = False


@dataclasses.dataclass
class CompressedTensor:
    """The compressed payload D = (theta, pi) plus folding/norm metadata."""

    params: nttd.Params
    pi: list[np.ndarray]
    spec: FoldingSpec
    cfg: nttd.NTTDConfig
    norm_mean: float = 0.0
    norm_std: float = 1.0

    @functools.cached_property
    def inv_pi(self) -> list[np.ndarray]:
        """Per-mode inverse permutations (original index -> position).

        The argsort is O(N_k log N_k) per mode; computed once and reused by
        ``decode``, ``to_dense``, and the serve-layer decode path.
        """
        return [np.argsort(p) for p in self.pi]

    # -- reconstruction ------------------------------------------------------
    def decode(self, indices: np.ndarray) -> np.ndarray:
        """Approximate entries at ORIGINAL indices [B, d] -> [B]."""
        pos = self._orig_to_pos(indices)
        vals = nttd.apply_at_positions(
            self.params, jnp.asarray(pos, jnp.int32), self.spec, self.cfg
        )
        return np.asarray(vals) * self.norm_std + self.norm_mean

    def to_dense(self, batch: int = 65536) -> np.ndarray:
        """Full reconstruction in ORIGINAL index order."""
        approx = nttd.generate_tensor(self.params, self.spec, self.cfg, batch)
        approx = approx * self.norm_std + self.norm_mean
        return approx[np.ix_(*self.inv_pi)]

    def fitness(self, x: np.ndarray, batch: int = 65536) -> float:
        err = 0.0
        norm = float(np.linalg.norm(x.astype(np.float64)))
        approx = self.to_dense(batch)
        err = float(np.linalg.norm((x - approx).astype(np.float64)))
        return 1.0 - err / max(norm, 1e-30)

    def _orig_to_pos(self, indices: np.ndarray) -> np.ndarray:
        inv = self.inv_pi
        pos = np.empty_like(indices)
        for j in range(indices.shape[-1]):
            pos[..., j] = inv[j][indices[..., j]]
        return pos

    # -- payload accounting (paper §V-A conventions) ---------------------------
    def payload_bits(self, bytes_per_param: int = 8) -> int:
        return nttd_payload_bits(
            nttd.count_params(self.params), self.spec.shape, bytes_per_param
        )

    def payload_bytes(self, bytes_per_param: int = 8) -> int:
        return (self.payload_bits(bytes_per_param) + 7) // 8


def nttd_payload_bits(
    n_params: int, shape: tuple[int, ...], bytes_per_param: int = 8
) -> int:
    """Paper §V-A: theta at ``bytes_per_param``, pi at ceil(log2 N_k) bits
    per index, plus the two normalization floats."""
    theta_bits = n_params * bytes_per_param * 8
    pi_bits = sum(
        n * max(int(np.ceil(np.log2(n))), 1) if n > 1 else 0 for n in shape
    )
    norm_bits = 2 * bytes_per_param * 8
    return theta_bits + pi_bits + norm_bits


@dataclasses.dataclass
class CompressionLog:
    fitness_history: list[float]
    loss_history: list[float]
    reorder_stats: list[list[reorder.SwapStats]]
    seconds_init_order: float = 0.0
    seconds_train: float = 0.0
    seconds_reorder: float = 0.0
    epochs_run: int = 0


def _make_train_step(spec: FoldingSpec, cfg: nttd.NTTDConfig, opt):
    def loss_fn(params, positions, values):
        preds = nttd.apply_at_positions(params, positions, spec, cfg)
        return jnp.sum(jnp.square(preds - values))

    @jax.jit
    def step(params, opt_state, positions, values):
        loss, grads = jax.value_and_grad(loss_fn)(params, positions, values)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        return params, opt_state, loss

    return step


def _make_train_epoch(spec: FoldingSpec, cfg: nttd.NTTDConfig, opt):
    """Whole-epoch jitted step: lax.scan over minibatches.

    One device round-trip per epoch instead of per minibatch — this is both
    the CPU-speed fix and the shape the pjit program takes on the mesh
    (positions/values sharded on the batch axis).
    """

    def loss_fn(params, positions, values):
        preds = nttd.apply_at_positions(params, positions, spec, cfg)
        return jnp.sum(jnp.square(preds - values))

    @jax.jit
    def epoch(params, opt_state, positions, values):
        # positions: [S, B, d] int32; values: [S, B]
        def body(carry, xs):
            params, opt_state = carry
            pos, val = xs
            loss, grads = jax.value_and_grad(loss_fn)(params, pos, val)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = optimizers.apply_updates(params, updates)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), (positions, values)
        )
        return params, opt_state, jnp.sum(losses)

    return epoch


def compress(
    x: np.ndarray, config: CodecConfig | None = None
) -> tuple[CompressedTensor, CompressionLog]:
    config = config or CodecConfig()
    rng = np.random.default_rng(config.seed)
    x = np.asarray(x, dtype=np.float32)
    d = x.ndim
    spec = make_folding_spec(x.shape, config.d_prime)
    cfg = nttd.NTTDConfig(
        rank=config.rank, hidden=config.hidden, kernel_impl=config.kernel_impl
    )

    mean, std = 0.0, 1.0
    if config.normalize:
        mean = float(x.mean())
        std = float(x.std()) or 1.0
    xn = (x - mean) / std

    log = CompressionLog([], [], [])

    # ---- pi init ------------------------------------------------------------
    t0 = time.time()
    if config.init_reorder:
        pi = reorder.tsp_init(xn)
    else:
        pi = reorder.identity_orders(x.shape)
    log.seconds_init_order = time.time() - t0

    # ---- theta init ------------------------------------------------------------
    key = jax.random.PRNGKey(config.seed)
    params = nttd.init_params(key, spec, cfg)
    opt = optimizers.adam(config.lr)
    opt_state = opt.init(params)
    train_epoch = _make_train_epoch(spec, cfg, opt)
    predict_jit = nttd.make_predict(spec, cfg)

    n_entries = int(np.prod(x.shape))
    per_epoch = min(config.entries_per_epoch or n_entries, n_entries)
    bsz = min(config.batch_size, per_epoch)
    steps = max(per_epoch // bsz, 1)

    def epoch_positions() -> np.ndarray:
        if per_epoch == n_entries:
            flat = rng.permutation(n_entries)[: steps * bsz]
        else:
            flat = rng.integers(0, n_entries, size=steps * bsz)
        return flat_to_multi(flat, x.shape)  # [steps*bsz, d]

    def values_at(pos: np.ndarray) -> np.ndarray:
        orig = np.empty_like(pos)
        for j in range(d):
            orig[:, j] = pi[j][pos[:, j]]
        return xn[tuple(orig[:, j] for j in range(d))]

    # fitness in position space: ||X_pi - approx|| == ||X - approx_orig||
    eval_n = min(n_entries, 4_000_000)
    eval_exhaustive = eval_n == n_entries

    def eval_fitness() -> float:
        if eval_exhaustive:
            flat = np.arange(n_entries, dtype=np.int64)
        else:
            flat = rng.integers(0, n_entries, size=eval_n)
        err2 = 0.0
        norm2 = 0.0
        for s in range(0, eval_n, config.eval_batch):
            pos = flat_to_multi(flat[s : s + config.eval_batch], x.shape)
            truth = values_at(pos).astype(np.float64)
            pad = config.eval_batch - pos.shape[0]
            if pad:
                pos = np.pad(pos, ((0, pad), (0, 0)))
            preds = np.asarray(
                predict_jit(params, jnp.asarray(pos, jnp.int32))
            ).astype(np.float64)[: truth.shape[0]]
            # fitness is defined on the RAW tensor: un-normalize both sides
            err2 += float(((preds - truth) ** 2).sum()) * std * std
            norm2 += float(((truth * std + mean) ** 2).sum())
        return 1.0 - np.sqrt(err2) / max(np.sqrt(norm2), 1e-30)

    best_fit = -np.inf
    best_snapshot = None
    stall = 0
    for epoch in range(config.epochs):
        t0 = time.time()
        pos_all = epoch_positions()
        vals_all = values_at(pos_all)
        params, opt_state, total_loss = train_epoch(
            params,
            opt_state,
            jnp.asarray(pos_all.reshape(steps, bsz, d), jnp.int32),
            jnp.asarray(vals_all.reshape(steps, bsz)),
        )
        total_loss = float(total_loss)
        log.seconds_train += time.time() - t0
        log.loss_history.append(total_loss)
        log.epochs_run = epoch + 1

        # ---- Alg. 3 reorder + Adam reinit ------------------------------------
        if (
            config.update_reorder
            and epoch + 1 >= config.reorder_warmup
            and (epoch + 1) % config.reorder_every == 0
            and epoch != config.epochs - 1
        ):
            t0 = time.time()
            pi, stats = reorder.update_orders(
                xn, params, pi, spec, cfg, rng, config.reorder_samples,
                predict_fn=predict_jit,
            )
            log.reorder_stats.append(stats)
            opt_state = opt.init(params)  # paper: reinit optimizer after reorder
            log.seconds_reorder += time.time() - t0
            # the loss surface changed: restart the convergence tracker so a
            # transient post-reorder dip is not mistaken for a stall
            stall = 0
            best_fit = -np.inf

        fit = eval_fitness()
        log.fitness_history.append(fit)
        if config.verbose:
            print(f"epoch {epoch}: loss={total_loss:.5g} fitness={fit:.5f}")
        if best_snapshot is None or fit > best_snapshot[0]:
            best_snapshot = (fit, params, [p.copy() for p in pi])
        if fit > best_fit + config.tol:
            best_fit = fit
            stall = 0
        else:
            stall += 1
            if stall >= config.patience:
                break

    # return the best state seen (reorder sweeps can transiently regress)
    _, params, pi = best_snapshot
    return CompressedTensor(params, pi, spec, cfg, mean, std), log
