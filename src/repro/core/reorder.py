"""Mode-index reordering (paper §IV-D).

* ``tsp_init``: per-mode 2-approximation of metric TSP over slices
  (pairwise Frobenius distances -> Prim MST -> preorder walk = cycle,
  drop heaviest cycle edge -> path).  Minimizes Eq. (6).
* ``update_orders``: Alg. 3 — LSH-style random-projection bucketing over a
  sampled half of the indices, XOR-paired disjoint candidate pairs, swap
  accepted iff the (sampled) true-loss delta is negative.

Conventions: ``pi[k][pos] = original index``, i.e. X_pi(pos) = X(pi(pos)),
matching the paper's definition.  All heavy loss evaluations are batched
through a single jitted NTTD call so the step runs as one XLA program
(the GPU-parallel structure of the paper, mapped to pjit).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nttd
from repro.core.folding import FoldingSpec


# ---------------------------------------------------------------------------
# Eq. (6) objective and TSP-based initialization
# ---------------------------------------------------------------------------
def _slice_matrix(x: np.ndarray, mode: int) -> np.ndarray:
    """[N_k, prod other dims] matrix of vectorized mode-k slices."""
    return np.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def order_objective(x: np.ndarray, mode: int, perm: np.ndarray) -> float:
    """Eq. (6): sum of Frobenius distances between consecutive slices."""
    m = _slice_matrix(x, mode)[perm]
    return float(np.sqrt(((m[1:] - m[:-1]) ** 2).sum(axis=1)).sum())


def _pairwise_dist(m: np.ndarray, chunk: int = 1024) -> np.ndarray:
    """Pairwise Euclidean distances via the Gram trick (f64 accumulate)."""
    m = m.astype(np.float64)
    sq = (m * m).sum(axis=1)
    n = m.shape[0]
    d2 = np.empty((n, n), dtype=np.float64)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        d2[s:e] = sq[s:e, None] + sq[None, :] - 2.0 * (m[s:e] @ m.T)
    np.clip(d2, 0.0, None, out=d2)
    return np.sqrt(d2)


def _prim_mst(dist: np.ndarray) -> np.ndarray:
    """Prim's MST, O(N^2).  Returns parent[i] (parent[0] == -1)."""
    n = dist.shape[0]
    in_tree = np.zeros(n, dtype=bool)
    best = np.full(n, np.inf)
    parent = np.full(n, -1, dtype=np.int64)
    best[0] = 0.0
    for _ in range(n):
        u = int(np.argmin(np.where(in_tree, np.inf, best)))
        in_tree[u] = True
        upd = (~in_tree) & (dist[u] < best)
        best[upd] = dist[u][upd]
        parent[upd] = u
    return parent


def _preorder(parent: np.ndarray, dist: np.ndarray) -> np.ndarray:
    """Preorder DFS of the MST (children visited nearest-first)."""
    n = parent.shape[0]
    children: list[list[int]] = [[] for _ in range(n)]
    for v in range(1, n):
        children[parent[v]].append(v)
    for u in range(n):
        children[u].sort(key=lambda v: dist[u, v])
    order, stack = [], [0]
    while stack:
        u = stack.pop()
        order.append(u)
        stack.extend(reversed(children[u]))
    return np.array(order, dtype=np.int64)


def tsp_order_mode(x: np.ndarray, mode: int) -> np.ndarray:
    """2-approx metric-TSP order for mode-k slices -> permutation array."""
    m = _slice_matrix(x, mode)
    n = m.shape[0]
    if n <= 2:
        return np.arange(n, dtype=np.int64)
    dist = _pairwise_dist(m)
    tour = _preorder(_prim_mst(dist), dist)
    # tour is a Hamiltonian cycle (implicit wrap) — drop heaviest edge
    edge_w = dist[tour, np.roll(tour, -1)]
    cut = int(np.argmax(edge_w))
    return np.roll(tour, -(cut + 1))


def tsp_init(x: np.ndarray) -> list[np.ndarray]:
    return [tsp_order_mode(x, k) for k in range(x.ndim)]


def identity_orders(shape: tuple[int, ...]) -> list[np.ndarray]:
    return [np.arange(n, dtype=np.int64) for n in shape]


# ---------------------------------------------------------------------------
# Alg. 3: LSH-paired swap refinement
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SwapStats:
    mode: int
    pairs: int
    accepted: int
    delta_sum: float


def _build_pairs(proj: dict[int, float], n: int, rng: np.random.Generator) -> np.ndarray:
    """Lines 11-21 of Alg. 3: bucket the projected points, XOR-pair within
    buckets, randomly pair the leftovers.  Returns [P, 2] disjoint pairs."""
    num_buckets = max(n // 8, 1)
    idx = np.array(sorted(proj.keys()), dtype=np.int64)
    vals = np.array([proj[i] for i in idx])
    lo, hi = vals.min(), vals.max()
    width = (hi - lo) / num_buckets if hi > lo else 1.0
    bucket = np.minimum(((vals - lo) / width).astype(np.int64), num_buckets - 1)

    pairs: list[tuple[int, int]] = []
    leftovers: list[int] = []
    for b in np.unique(bucket):
        members = list(idx[bucket == b])
        rng.shuffle(members)
        while len(members) > 1:
            i1, i2 = members.pop(), members.pop()
            pairs.append((i1, i2 ^ 1))
            pairs.append((i1 ^ 1, i2))
        leftovers.extend(members)
    # line 19-21: leftovers plus their XOR partners, paired randomly
    rest = list({j for i in leftovers for j in (i, i ^ 1) if j < n})
    rng.shuffle(rest)
    while len(rest) > 1:
        pairs.append((rest.pop(), rest.pop()))
    # keep pairs disjoint and in-range
    seen: set[int] = set()
    out = []
    for a, b in pairs:
        if a >= n or b >= n or a == b or a in seen or b in seen:
            continue
        seen.add(a)
        seen.add(b)
        out.append((a, b))
    return np.array(out, dtype=np.int64).reshape(-1, 2)


def _sample_half(n: int, rng: np.random.Generator) -> np.ndarray:
    """Lines 3-5: from each (2t, 2t+1) pair keep one index u.a.r."""
    base = np.arange(0, n - 1, 2, dtype=np.int64)
    return base + (rng.random(base.shape[0]) < 0.5)


def update_orders(
    x: np.ndarray,
    params: nttd.Params,
    pi: list[np.ndarray],
    spec: FoldingSpec,
    cfg: nttd.NTTDConfig,
    rng: np.random.Generator,
    samples_per_slice: int = 512,
    predict_fn=None,
    t_threshold: float = 2.0,
) -> tuple[list[np.ndarray], list[SwapStats]]:
    """One Alg. 3 sweep over all modes.  Mutates a copy of ``pi``.

    Deviation from the paper (recorded in DESIGN.md §2/§9): when a slice is
    larger than ``samples_per_slice`` the loss delta is *estimated* on
    sampled entries, and a swap is accepted only if the paired t-statistic
    of the per-sample deltas clears ``t_threshold`` — plain sign acceptance
    on noisy estimates scrambles a good order early in training.  For
    slices within the sample budget the delta is exact and plain Δ<0
    acceptance (the paper's rule) is used.
    """
    d = x.ndim
    pi = [p.copy() for p in pi]
    stats: list[SwapStats] = []

    if predict_fn is None:

        @jax.jit
        def predict_fn(p, positions):
            return nttd.apply_at_positions(p, positions, spec, cfg)
    predict = predict_fn

    for k in range(d):
        n_k = x.shape[k]
        if n_k < 4:
            stats.append(SwapStats(k, 0, 0, 0.0))
            continue
        # ---- project sampled slices of the *current reordered* tensor -----
        sampled = _sample_half(n_k, rng)
        slices = _slice_matrix(x, k)  # rows indexed by ORIGINAL index
        r_vec = rng.standard_normal(slices.shape[1])
        r_vec /= np.linalg.norm(r_vec) + 1e-12
        proj: dict[int, float] = {}
        for pos in sampled:
            v = slices[pi[k][pos]].astype(np.float64)
            nv = np.linalg.norm(v)
            proj[int(pos)] = float(v @ r_vec / nv) if nv > 0 else 0.0
        pairs = _build_pairs(proj, n_k, rng)
        if pairs.shape[0] == 0:
            stats.append(SwapStats(k, 0, 0, 0.0))
            continue
        # ---- sampled positions for the loss delta --------------------------
        other_dims = [x.shape[j] for j in range(d) if j != k]
        slice_size = int(np.prod(other_dims))
        s = min(samples_per_slice, slice_size)
        exact = s == slice_size
        n_pairs = pairs.shape[0]
        if exact:
            grids = np.indices(other_dims).reshape(d - 1, -1).T  # [S, d-1]
            rest = np.broadcast_to(grids, (n_pairs,) + grids.shape)
        else:
            rest = np.stack(
                [rng.integers(0, dim, size=(n_pairs, s)) for dim in other_dims],
                axis=-1,
            )  # [P, S, d-1]
        # positions (in reordered coords) for both slices of each pair
        def full_pos(slice_pos: np.ndarray) -> np.ndarray:
            out = np.empty((n_pairs, s, d), dtype=np.int64)
            oi = 0
            for j in range(d):
                if j == k:
                    out[:, :, j] = slice_pos[:, None]
                else:
                    out[:, :, j] = rest[:, :, oi]
                    oi += 1
            return out

        pos_a = full_pos(pairs[:, 0])
        pos_b = full_pos(pairs[:, 1])
        # model predictions depend only on positions (reordered coords)
        all_pos = np.concatenate([pos_a, pos_b]).reshape(-1, d)
        preds = np.asarray(predict(params, jnp.asarray(all_pos, jnp.int32)))
        preds = preds.reshape(2, n_pairs, s).astype(np.float64)
        # data values under current assignment and under the swap
        def gather(positions: np.ndarray) -> np.ndarray:
            orig = np.empty_like(positions)
            for j in range(d):
                orig[:, :, j] = pi[j][positions[:, :, j]]
            return x[tuple(orig[:, :, j] for j in range(d))].astype(np.float64)

        val_a = gather(pos_a)  # X at slice a's current original index
        val_b = gather(pos_b)
        # swap exchanges the data that sits at positions a and b
        cur = (preds[0] - val_a) ** 2 + (preds[1] - val_b) ** 2
        swp = (preds[0] - val_b) ** 2 + (preds[1] - val_a) ** 2
        dsamp = swp - cur  # [P, S] per-sample deltas
        delta = dsamp.sum(axis=1)  # [P]
        if exact:
            accept = delta < 0.0
        else:
            sd = dsamp.std(axis=1) + 1e-12
            tstat = dsamp.mean(axis=1) / (sd / np.sqrt(s))
            accept = tstat < -t_threshold
        for t in np.nonzero(accept)[0]:
            a, b = pairs[t]
            pi[k][a], pi[k][b] = pi[k][b], pi[k][a]
        stats.append(
            SwapStats(k, n_pairs, int(accept.sum()), float(delta[accept].sum()))
        )
    return pi, stats
