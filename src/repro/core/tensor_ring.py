"""Tensor-Ring decomposition baseline (TR-SVD, Zhao et al.) — paper competitor.

Approximates X(i_1..i_d) = Trace( G_1(i_1) G_2(i_2) ... G_d(i_d) ) with
cores G_k in R^{r_{k-1} x N_k x r_k}, r_0 = r_d = r (ring closure).
TR-SVD: first SVD splits its rank between r_0 and r_1; the rest follows
TT-SVD.  Pure numpy.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TRDecomposition:
    cores: list[np.ndarray]  # [r_{k-1}, N_k, r_k], ring-closed

    @property
    def n_params(self) -> int:
        return sum(c.size for c in self.cores)

    def payload_bytes(self, bytes_per_param: int = 8) -> int:
        return self.n_params * bytes_per_param

    def to_dense(self) -> np.ndarray:
        out = self.cores[0]  # [r0, N1, r1]
        for core in self.cores[1:]:
            out = np.tensordot(out, core, axes=([out.ndim - 1], [0]))
        # out: [r0, N1, ..., Nd, r0] -> trace over (first, last)
        return np.trace(out, axis1=0, axis2=out.ndim - 1)

    def fitness(self, x: np.ndarray) -> float:
        err = np.linalg.norm((x - self.to_dense()).astype(np.float64))
        return 1.0 - err / max(np.linalg.norm(x.astype(np.float64)), 1e-30)


def tr_svd(x: np.ndarray, max_rank: int) -> TRDecomposition:
    shape = x.shape
    d = x.ndim
    x64 = x.astype(np.float64)
    # first unfolding: split rank between r0 and r1
    c = x64.reshape(shape[0], -1)
    u, s, vt = np.linalg.svd(c, full_matrices=False)
    r01 = min(len(s), max_rank * max_rank)
    r0 = min(max_rank, int(np.ceil(np.sqrt(r01))))
    r1 = min(max_rank, (r01 + r0 - 1) // r0)
    r01 = r0 * r1
    u, s, vt = u[:, :r01], s[:r01], vt[:r01]
    g1 = u.reshape(shape[0], r0, r1)  # split the rank index
    cores = [np.moveaxis(g1, 0, 1)]   # [r0, N1, r1]
    c = (s[:, None] * vt).reshape(r0, r1, -1)
    c = np.moveaxis(c, 0, -1).reshape(r1, -1, 1) if False else c
    # remaining cores via TT-SVD on [r1, N2...Nd, r0]
    c = np.moveaxis(c, 0, -1)  # [r1, rest..., -> (r1, prod rest, r0)] handled below
    c = c.reshape(r1, -1, r0)
    r_prev = r1
    for k in range(1, d - 1):
        mat = c.reshape(r_prev * shape[k], -1)
        u, s, vt = np.linalg.svd(mat, full_matrices=False)
        r = min(len(s), max_rank)
        cores.append(u[:, :r].reshape(r_prev, shape[k], r))
        c = (s[:r, None] * vt[:r]).reshape(r, -1, r0)
        r_prev = r
    cores.append(c.reshape(r_prev, shape[-1], r0))
    return TRDecomposition(cores)


def tr_rank_for_budget(shape: tuple[int, ...], budget_params: int) -> int:
    r = 1
    while True:
        nxt = r + 1
        n = sum(nxt * n_k * nxt for n_k in shape)
        if n > budget_params:
            return max(r, 1)
        r = nxt
