"""TensorCodec core: NTTD + folding + reordering, competitor baselines,
and the real serializer.  See DESIGN.md §3-4."""
from repro.core.codec import CodecConfig, CompressedTensor, CompressionLog, compress
from repro.core.folding import FoldingSpec, make_folding_spec
from repro.core.nttd import NTTDConfig

__all__ = [
    "CodecConfig",
    "CompressedTensor",
    "CompressionLog",
    "compress",
    "FoldingSpec",
    "make_folding_spec",
    "NTTDConfig",
]
