"""TensorCodec core: NTTD + folding + reordering, competitor baselines,
and the real serializer.  See DESIGN.md §3-4.

All compressors here are also exposed behind the unified registry —
``repro.codecs.get_codec("nttd").fit(x, budget)`` — which is the
preferred entry point for fitting, querying, and on-disk payloads."""
from repro.core.codec import CodecConfig, CompressedTensor, CompressionLog, compress
from repro.core.folding import FoldingSpec, make_folding_spec
from repro.core.nttd import NTTDConfig

__all__ = [
    "CodecConfig",
    "CompressedTensor",
    "CompressionLog",
    "compress",
    "FoldingSpec",
    "make_folding_spec",
    "NTTDConfig",
]
