"""Real on-disk serialization of the compressed payload D = (theta, pi).

Layout (little-endian):
  magic 'TCDC' | u16 version | u8 d | u8 d' | u8 dtype | u8 flags
  u32 rank | u32 hidden | f64 mean | f64 std
  d  x u64   original shape
  d*d' x u8  folding factors
  theta: arrays in sorted-key traversal order, raw bytes at `dtype`
  pi:    per mode, N_k indices bit-packed at ceil(log2 N_k) bits each

The pi encoding matches the paper's size accounting exactly
(N_k * ceil(log2 N_k) bits, §V-A); round-trip is bit-exact.

This v2 layout is now the NTTD *body* inside the multi-codec container
(``repro.codecs.container``, v3), which prefixes a codec-id header so any
registered codec round-trips through one format.  ``load_bytes`` there
still accepts bare v2 blobs; use ``repro.codecs.save_bytes/load_bytes``
for new code.
"""
from __future__ import annotations

import io
import os
import struct

import jax.numpy as jnp
import numpy as np

from repro.core import codec as codec_mod
from repro.core import nttd
from repro.core.folding import FoldingSpec

MAGIC = b"TCDC"
VERSION = 2
_DTYPES = {0: np.float16, 1: np.float32, 2: np.float64}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}


# ---------------------------------------------------------------------------
# bit packing
# ---------------------------------------------------------------------------
def pack_permutation(perm: np.ndarray) -> bytes:
    """Pack N integers in [0, N) at ceil(log2 N) bits each."""
    n = perm.shape[0]
    if n <= 1:
        return b""
    bits = max(int(np.ceil(np.log2(n))), 1)
    total = n * bits
    buf = np.zeros((total + 7) // 8, dtype=np.uint8)
    bitpos = np.arange(n, dtype=np.int64) * bits
    for b in range(bits):
        p = bitpos + b
        bit = (perm >> (bits - 1 - b)) & 1
        np.bitwise_or.at(buf, p // 8, (bit << (7 - (p % 8))).astype(np.uint8))
    return buf.tobytes()


def unpack_permutation(data: bytes, n: int) -> np.ndarray:
    if n <= 1:
        return np.arange(n, dtype=np.int64)
    bits = max(int(np.ceil(np.log2(n))), 1)
    buf = np.frombuffer(data, dtype=np.uint8)
    out = np.zeros(n, dtype=np.int64)
    bitpos = np.arange(n, dtype=np.int64) * bits
    for b in range(bits):
        p = bitpos + b
        bit = (buf[p // 8] >> (7 - (p % 8))) & 1
        out |= bit.astype(np.int64) << (bits - 1 - b)
    return out


# ---------------------------------------------------------------------------
# theta traversal (stable order)
# ---------------------------------------------------------------------------
def _theta_items(params: nttd.Params):
    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k in sorted(node):
                yield from walk(f"{prefix}/{k}", node[k])
        else:
            yield prefix, node

    yield from walk("", params)


def save_bytes(ct: codec_mod.CompressedTensor, dtype=np.float32) -> bytes:
    spec = ct.spec
    out = io.BytesIO()
    code = _DTYPE_CODES[np.dtype(dtype)]
    out.write(MAGIC)
    out.write(
        struct.pack(
            "<HBBBBII dd",
            VERSION,
            spec.d,
            spec.d_prime,
            code,
            0,
            ct.cfg.rank,
            ct.cfg.hidden,
            ct.norm_mean,
            ct.norm_std,
        )
    )
    out.write(np.asarray(spec.shape, dtype=np.uint64).tobytes())
    out.write(spec.factors.astype(np.uint8).tobytes())
    for _, arr in _theta_items(ct.params):
        out.write(np.asarray(arr, dtype=dtype).tobytes())
    for k in range(spec.d):
        out.write(pack_permutation(ct.pi[k]))
    return out.getvalue()


def load_bytes(
    data: bytes, kernel_impl: str | None = None
) -> codec_mod.CompressedTensor:
    """Rebuild a CompressedTensor from its v2 body.

    ``kernel_impl`` picks the decode backend of the rebuilt payload (the
    wire format carries no impl — it is an execution choice, not data).
    Default is "ref" for historical bit-stability; ``REPRO_DECODE_IMPL``
    overrides it process-wide, which is how serving benches opt whole
    worker fleets into the fused decode path without touching payloads.
    """
    from repro.core.folding import make_folding_spec

    buf = io.BytesIO(data)
    if buf.read(4) != MAGIC:
        raise ValueError("not a TensorCodec payload")
    version, d, d_prime, code, _flags, rank, hidden, mean, std = struct.unpack(
        "<HBBBBII dd", buf.read(struct.calcsize("<HBBBBII dd"))
    )
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    shape = tuple(np.frombuffer(buf.read(8 * d), dtype=np.uint64).astype(int))
    factors = np.frombuffer(buf.read(d * d_prime), dtype=np.uint8).reshape(d, d_prime)
    spec = make_folding_spec(shape, d_prime)
    if not np.array_equal(spec.factors, factors.astype(np.int64)):
        # factor chooser changed between versions: rebuild spec from factors
        spec = _spec_from_factors(shape, factors.astype(np.int64))
    cfg = nttd.NTTDConfig(
        rank=rank,
        hidden=hidden,
        kernel_impl=kernel_impl or os.environ.get("REPRO_DECODE_IMPL", "ref"),
    )
    dtype = _DTYPES[code]
    # rebuild an abstract params tree to know the shapes, then fill
    import jax

    template = jax.eval_shape(
        lambda key: nttd.init_params(key, spec, cfg), jax.random.PRNGKey(0)
    )
    params = _fill(template, buf, dtype)
    pi = []
    for k in range(d):
        n = shape[k]
        bits = max(int(np.ceil(np.log2(n))), 1) if n > 1 else 0
        nbytes = (n * bits + 7) // 8
        pi.append(unpack_permutation(buf.read(nbytes), n))
    return codec_mod.CompressedTensor(params, pi, spec, cfg, mean, std)


def _fill(template, buf: io.BytesIO, dtype):
    if isinstance(template, dict):
        return {k: _fill(template[k], buf, dtype) for k in sorted(template)}
    n = int(np.prod(template.shape))
    raw = np.frombuffer(buf.read(n * np.dtype(dtype).itemsize), dtype=dtype)
    return jnp.asarray(raw.reshape(template.shape), template.dtype)


def _spec_from_factors(shape, factors: np.ndarray) -> FoldingSpec:
    d, d_prime = factors.shape
    strides = np.ones((d, d_prime), dtype=np.int64)
    for j in range(d_prime - 2, -1, -1):
        strides[:, j] = strides[:, j + 1] * factors[:, j + 1]
    fstrides = np.ones((d, d_prime), dtype=np.int64)
    for k in range(d - 2, -1, -1):
        fstrides[k, :] = fstrides[k + 1, :] * factors[k + 1, :]
    return FoldingSpec(
        shape=tuple(int(s) for s in shape),
        factors=factors,
        strides=strides,
        fstrides=fstrides,
        folded_shape=tuple(int(x) for x in factors.prod(axis=0)),
    )


def save_file(path: str, ct: codec_mod.CompressedTensor, dtype=np.float32) -> int:
    data = save_bytes(ct, dtype)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load_file(path: str) -> codec_mod.CompressedTensor:
    with open(path, "rb") as f:
        return load_bytes(f.read())
