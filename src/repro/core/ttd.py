"""Tensor-Train Decomposition baseline (Oseledets 2011) — paper competitor.

TT-SVD with either a prescribed-accuracy eps (the classical formulation)
or fixed max rank R (the paper's size-matched comparisons).  Pure numpy.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TTDecomposition:
    cores: list[np.ndarray]  # core k: [r_{k-1}, N_k, r_k]

    @property
    def ranks(self) -> list[int]:
        return [c.shape[0] for c in self.cores] + [self.cores[-1].shape[2]]

    @property
    def n_params(self) -> int:
        return sum(c.size for c in self.cores)

    def payload_bytes(self, bytes_per_param: int = 8) -> int:
        return self.n_params * bytes_per_param

    def to_dense(self) -> np.ndarray:
        out = self.cores[0]  # [1, N_1, r_1]
        for core in self.cores[1:]:
            out = np.tensordot(out, core, axes=([out.ndim - 1], [0]))
        return out.squeeze(axis=(0, out.ndim - 1))

    def fitness(self, x: np.ndarray) -> float:
        err = np.linalg.norm((x - self.to_dense()).astype(np.float64))
        return 1.0 - err / max(np.linalg.norm(x.astype(np.float64)), 1e-30)


def tt_svd(
    x: np.ndarray, max_rank: int | None = None, eps: float | None = None
) -> TTDecomposition:
    """TT-SVD.  If eps is given, ranks are chosen so the total error is
    <= eps * ||x||_F (delta = eps * ||x|| / sqrt(d-1) per truncation)."""
    shape = x.shape
    d = x.ndim
    delta = None
    if eps is not None:
        delta = eps * np.linalg.norm(x.astype(np.float64)) / max(np.sqrt(d - 1), 1)
    cores = []
    c = x.astype(np.float64).reshape(shape[0], -1)
    r_prev = 1
    for k in range(d - 1):
        c = c.reshape(r_prev * shape[k], -1)
        u, s, vt = np.linalg.svd(c, full_matrices=False)
        r = len(s)
        if delta is not None:
            # truncate so the tail energy is <= delta^2
            tail = np.cumsum((s**2)[::-1])[::-1]
            keep = np.nonzero(tail > delta**2)[0]
            r = int(keep[-1]) + 1 if keep.size else 1
        if max_rank is not None:
            r = min(r, max_rank)
        r = max(r, 1)
        cores.append(u[:, :r].reshape(r_prev, shape[k], r))
        c = (s[:r, None] * vt[:r])
        r_prev = r
    cores.append(c.reshape(r_prev, shape[-1], 1))
    return TTDecomposition(cores)


def tt_rank_for_budget(shape: tuple[int, ...], budget_params: int) -> int:
    """Largest uniform TT rank whose parameter count fits the budget."""
    r = 1
    while True:
        nxt = r + 1
        n = _tt_params(shape, nxt)
        if n > budget_params:
            return r
        r = nxt


def _tt_params(shape: tuple[int, ...], r: int) -> int:
    d = len(shape)
    total = shape[0] * r + shape[-1] * r
    for k in range(1, d - 1):
        total += r * shape[k] * r
    return total
