"""Tucker decomposition baseline (HOSVD init + HOOI) — paper competitor."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TuckerDecomposition:
    core: np.ndarray              # [r_1..r_d]
    factors: list[np.ndarray]     # mode k: [N_k, r_k]

    @property
    def n_params(self) -> int:
        return int(self.core.size + sum(f.size for f in self.factors))

    def payload_bytes(self, bytes_per_param: int = 8) -> int:
        return self.n_params * bytes_per_param

    def to_dense(self) -> np.ndarray:
        out = self.core
        for k, f in enumerate(self.factors):
            out = np.tensordot(out, f, axes=([0], [1]))
        # tensordot cycles axes; after d products the order is restored
        return out

    def fitness(self, x: np.ndarray) -> float:
        err = np.linalg.norm((x - self.to_dense()).astype(np.float64))
        return 1.0 - err / max(np.linalg.norm(x.astype(np.float64)), 1e-30)


def _unfold(x: np.ndarray, mode: int) -> np.ndarray:
    return np.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def _leading_svd(m: np.ndarray, r: int) -> np.ndarray:
    if m.shape[0] <= m.shape[1]:
        u, _, _ = np.linalg.svd(m, full_matrices=False)
    else:
        # tall matrix: eig of the small gram
        g = m.T @ m
        w, v = np.linalg.eigh(g)
        v = v[:, ::-1]
        u = m @ v
        u /= np.maximum(np.linalg.norm(u, axis=0, keepdims=True), 1e-30)
    return u[:, :r]


def tucker_hooi(
    x: np.ndarray, ranks: list[int] | tuple[int, ...], iters: int = 10
) -> TuckerDecomposition:
    x64 = x.astype(np.float64)
    d = x.ndim
    ranks = [min(r, x.shape[k]) for k, r in enumerate(ranks)]
    # HOSVD init
    factors = [_leading_svd(_unfold(x64, k), ranks[k]) for k in range(d)]
    for _ in range(iters):
        for mode in range(d):
            # project on all modes except `mode`
            y = x64
            for k in range(d):
                if k == mode:
                    continue
                y = np.moveaxis(
                    np.tensordot(y, factors[k], axes=([k], [0])), -1, k
                )
            factors[mode] = _leading_svd(_unfold(y, mode), ranks[mode])
    core = x64
    for k in range(d):
        core = np.moveaxis(np.tensordot(core, factors[k], axes=([k], [0])), -1, k)
    return TuckerDecomposition(core, factors)


def tucker_ranks_for_budget(shape: tuple[int, ...], budget_params: int) -> list[int]:
    """Uniform-fraction ranks that meet the parameter budget."""
    lo, hi = 1e-4, 1.0
    best = [1] * len(shape)
    for _ in range(40):
        mid = (lo + hi) / 2
        ranks = [max(int(n * mid), 1) for n in shape]
        n = int(np.prod(ranks)) + sum(n * r for n, r in zip(shape, ranks))
        if n <= budget_params:
            best = ranks
            lo = mid
        else:
            hi = mid
    return best
