"""TT-tensor folding (paper Eq. 4).

Folds a d-order tensor of shape (N_1, ..., N_d) into a d'-order tensor whose
l-th mode has length prod_k n_{k,l}, where the factor matrix ``n[k, l]``
satisfies ``prod_l n[k, l] >= N_k``.  Original mode-k indices are decomposed
into big-endian mixed-radix digits ``i_{k,l}``; folded mode-l indices are the
big-endian mixed-radix composition of the l-th digit of every original mode.

The folded tensor is never materialized: all consumers work through
``fold_indices`` / ``unfold_indices``.  Positions whose digit expansion maps
outside the original shape ("padding", paper: values disregarded) are simply
never addressed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp
import numpy as np

MAX_FACTOR = 5  # paper: "modify some of them using integers at most 5"


def choose_factors(dim: int, d_prime: int) -> list[int]:
    """Pick d' factors in [1, MAX_FACTOR] with product >= dim, close to dim.

    Mirrors the paper's recipe: start from all-2, bump factors (<=5) while the
    product is short of ``dim``, then shrink 2 -> 1 from the right while the
    product stays >= dim.
    """
    if dim <= 0:
        raise ValueError(f"mode length must be positive, got {dim}")
    if MAX_FACTOR**d_prime < dim:
        raise ValueError(f"d'={d_prime} too small for mode length {dim}")
    factors = [2] * d_prime
    prod = 2**d_prime
    # Grow: bump the smallest factor (leftmost among ties) until prod >= dim.
    while prod < dim:
        j = min(range(d_prime), key=lambda t: (factors[t], t))
        if factors[j] >= MAX_FACTOR:
            raise AssertionError("unreachable: growth exhausted")
        prod = prod // factors[j] * (factors[j] + 1)
        factors[j] += 1
    # Shrink: drop 2 -> 1 from the right while we can stay >= dim.
    for j in reversed(range(d_prime)):
        if factors[j] == 2 and prod // 2 >= dim:
            factors[j] = 1
            prod //= 2
    assert prod >= dim
    return factors


def default_d_prime(shape: Sequence[int]) -> int:
    """Paper: d' > d and d' = O(log N_max)."""
    n_max = max(shape)
    return max(len(shape) + 1, math.ceil(math.log2(max(n_max, 2))))


@dataclasses.dataclass(frozen=True)
class FoldingSpec:
    """Precomputed index maps between the original and folded tensors."""

    shape: tuple[int, ...]            # original (N_1..N_d)
    factors: np.ndarray               # [d, d'] int64, n_{k,l}
    # strides[k, l] = prod_{l' > l} n[k, l']   (digit extraction, original)
    strides: np.ndarray               # [d, d'] int64
    # fstrides[k, l] = prod_{k' > k} n[k', l]  (digit composition, folded)
    fstrides: np.ndarray              # [d, d'] int64
    folded_shape: tuple[int, ...]     # (m_1..m_d'), m_l = prod_k n[k, l]

    @property
    def d(self) -> int:
        return len(self.shape)

    @property
    def d_prime(self) -> int:
        return len(self.folded_shape)

    @property
    def n_entries(self) -> int:
        return int(np.prod(self.shape))

    @property
    def padded_entries(self) -> int:
        return int(np.prod(self.folded_shape))

    def fold_indices(self, idx):
        """[..., d] original indices -> [..., d'] folded indices."""
        xp = jnp if isinstance(idx, jnp.ndarray) else np
        digits = (idx[..., :, None] // self.strides) % self.factors
        return xp.sum(digits * self.fstrides, axis=-2)

    def unfold_indices(self, fidx):
        """[..., d'] folded indices -> [..., d] original indices.

        Inverse of ``fold_indices`` on the image of valid indices; for padded
        folded positions the result may exceed ``shape`` (callers mask).
        """
        xp = jnp if isinstance(fidx, jnp.ndarray) else np
        digits = (fidx[..., None, :] // self.fstrides) % self.factors
        return xp.sum(digits * self.strides, axis=-1)


def make_folding_spec(shape: Sequence[int], d_prime: int | None = None) -> FoldingSpec:
    shape = tuple(int(s) for s in shape)
    if d_prime is None:
        d_prime = default_d_prime(shape)
    d = len(shape)
    factors = np.array([choose_factors(n, d_prime) for n in shape], dtype=np.int64)
    strides = np.ones((d, d_prime), dtype=np.int64)
    for j in range(d_prime - 2, -1, -1):
        strides[:, j] = strides[:, j + 1] * factors[:, j + 1]
    fstrides = np.ones((d, d_prime), dtype=np.int64)
    for k in range(d - 2, -1, -1):
        fstrides[k, :] = fstrides[k + 1, :] * factors[k + 1, :]
    folded_shape = tuple(int(x) for x in factors.prod(axis=0))
    return FoldingSpec(
        shape=shape,
        factors=factors,
        strides=strides,
        fstrides=fstrides,
        folded_shape=folded_shape,
    )
