"""SZ-like error-bounded lossy compressor ("SZ-lite") — smooth-data
comparison point (paper competitor SZ3, simplified).

Pipeline: uniform scalar quantization of every value at a prescribed
absolute error bound -> delta encoding of the *integer* codes along the
flattened (row-major) order -> DEFLATE entropy coding (zlib = LZ77 +
Huffman; SZ3 uses Huffman + a lossless backend, same family).

The integer deltas make the scheme drift-free (cumsum of int32 diffs is
exact) while still exploiting smoothness: smooth data yields near-zero
deltas that entropy-code to a fraction of a bit each.

This is deliberately a *simplified* stand-in: it preserves the defining
property (error-bounded, smoothness-exploiting, entropy-coded) without
reproducing SZ3's full interpolation stack; see DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SZCompressed:
    data: bytes
    shape: tuple[int, ...]
    error_bound: float

    def payload_bytes(self) -> int:
        # data + error bound + shape header
        return len(self.data) + 8 + 8 * len(self.shape)


def compress(x: np.ndarray, error_bound: float) -> SZCompressed:
    import zlib

    flat = x.astype(np.float64).reshape(-1)
    step = 2.0 * max(error_bound, 1e-300)
    q = np.round(flat / step).astype(np.int64)
    if np.abs(q).max(initial=0) >= 2**31 - 1:
        raise ValueError("error bound too small for value range (int32 overflow)")
    dq = np.diff(q, prepend=np.int64(0)).astype(np.int32)
    data = zlib.compress(dq.tobytes(), 6)
    return SZCompressed(data, x.shape, error_bound)


def decompress(c: SZCompressed) -> np.ndarray:
    import zlib

    dq = np.frombuffer(zlib.decompress(c.data), dtype=np.int32).astype(np.int64)
    q = np.cumsum(dq)
    step = 2.0 * max(c.error_bound, 1e-300)
    return (q.astype(np.float64) * step).reshape(c.shape)


def fitness(x: np.ndarray, recon: np.ndarray) -> float:
    err = np.linalg.norm((x - recon).astype(np.float64).reshape(-1))
    return 1.0 - err / max(np.linalg.norm(x.astype(np.float64).reshape(-1)), 1e-30)
