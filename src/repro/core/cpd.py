"""CP decomposition baseline (CP-ALS) — paper competitor.  Pure numpy."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CPDecomposition:
    weights: np.ndarray          # [R]
    factors: list[np.ndarray]    # mode k: [N_k, R]

    @property
    def n_params(self) -> int:
        return int(self.weights.size + sum(f.size for f in self.factors))

    def payload_bytes(self, bytes_per_param: int = 8) -> int:
        return self.n_params * bytes_per_param

    def to_dense(self) -> np.ndarray:
        d = len(self.factors)
        subs = [f"{chr(ord('a') + k)}r" for k in range(d)]
        eq = ",".join(["r"] + subs) + "->" + "".join(chr(ord("a") + k) for k in range(d))
        return np.einsum(eq, self.weights, *self.factors, optimize=True)

    def fitness(self, x: np.ndarray) -> float:
        err = np.linalg.norm((x - self.to_dense()).astype(np.float64))
        return 1.0 - err / max(np.linalg.norm(x.astype(np.float64)), 1e-30)


def _khatri_rao(mats: list[np.ndarray]) -> np.ndarray:
    out = mats[0]
    for m in mats[1:]:
        out = (out[:, None, :] * m[None, :, :]).reshape(-1, out.shape[1])
    return out


def _unfold(x: np.ndarray, mode: int) -> np.ndarray:
    return np.moveaxis(x, mode, 0).reshape(x.shape[mode], -1)


def cp_als(
    x: np.ndarray, rank: int, iters: int = 50, seed: int = 0, tol: float = 1e-7
) -> CPDecomposition:
    rng = np.random.default_rng(seed)
    d = x.ndim
    x64 = x.astype(np.float64)
    factors = [rng.standard_normal((n, rank)) for n in x.shape]
    weights = np.ones(rank)
    norm_x = np.linalg.norm(x64)
    prev_err = np.inf
    for _ in range(iters):
        for mode in range(d):
            others = [factors[k] for k in range(d) if k != mode]
            # gram of khatri-rao product = hadamard of grams
            g = np.ones((rank, rank))
            for f in others:
                g *= f.T @ f
            # row-major unfolding (last axis fastest) -> KR in original order
            kr = _khatri_rao(others)
            mttkrp = _unfold(x64, mode) @ kr
            sol = np.linalg.lstsq(g, mttkrp.T, rcond=None)[0].T
            weights = np.linalg.norm(sol, axis=0)
            weights[weights == 0] = 1.0
            factors[mode] = sol / weights
        # convergence check on relative error
        dec = CPDecomposition(weights, factors)
        err = np.linalg.norm(x64 - dec.to_dense()) / max(norm_x, 1e-30)
        if abs(prev_err - err) < tol:
            break
        prev_err = err
    return CPDecomposition(weights, factors)


def cp_rank_for_budget(shape: tuple[int, ...], budget_params: int) -> int:
    per_rank = sum(shape) + 1
    return max(budget_params // per_rank, 1)
