"""Neural Tensor-Train Decomposition (paper §IV-B, Alg. 2).

TT cores are generated per entry by an auto-regressive network:

    mode indices --embedding--> e_1..e_d' --LSTM--> h_1..h_d'
    T_1 = W1 h_1 + b1 (1xR);  T_k = W h_k + b (RxR, shared k=2..d'-1);
    T_d' = Wd h_d' + bd (Rx1);  value = T_1 T_2 ... T_d'

Embedding tables are shared across folded modes of equal length (paper
footnote 2).  Params are a plain pytree; ``apply`` is pure and jit/pjit
friendly (folded indices in, scalar approximations out).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.folding import FoldingSpec
from repro.kernels import ops

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class NTTDConfig:
    rank: int = 8            # R, TT rank
    hidden: int = 16         # h, LSTM hidden == embedding dim
    dtype: Any = jnp.float32
    kernel_impl: str = "ref"  # see kernels.ops


def _mode_table_names(folded_shape: tuple[int, ...]) -> list[str]:
    """One embedding table per distinct folded mode length."""
    return [f"embed_{m}" for m in folded_shape]


def init_params(key: jax.Array, spec: FoldingSpec, cfg: NTTDConfig) -> Params:
    h, r = cfg.hidden, cfg.rank
    keys = jax.random.split(key, 8)
    params: Params = {}
    # shared embedding tables (by folded-mode length)
    for m in sorted(set(spec.folded_shape)):
        k = jax.random.fold_in(keys[0], m)
        params[f"embed_{m}"] = (
            jax.random.normal(k, (m, h), cfg.dtype) * (1.0 / np.sqrt(h))
        )
    glorot = lambda k, shape: jax.random.normal(k, shape, cfg.dtype) * jnp.sqrt(  # noqa: E731
        2.0 / (shape[0] + shape[-1])
    )
    params["lstm"] = {
        "wi": glorot(keys[1], (h, 4 * h)),
        "wh": glorot(keys[2], (h, 4 * h)),
        "b": jnp.zeros((4 * h,), cfg.dtype),
    }
    # Bias init keeps the initial chain product at O(1) scale for any d':
    # mid cores start at the identity, first/last at 1/sqrt(R), so the
    # initial prediction is ~1 and gradients reach every head.
    inv_sqrt_r = (jnp.ones((r,), cfg.dtype) / np.sqrt(r)).astype(cfg.dtype)
    params["head_first"] = {"w": glorot(keys[3], (h, r)), "b": inv_sqrt_r}
    params["head_mid"] = {
        "w": glorot(keys[4], (h, r * r)),
        "b": jnp.eye(r).reshape(r * r).astype(cfg.dtype),
    }
    params["head_last"] = {"w": glorot(keys[5], (h, r)), "b": inv_sqrt_r}
    return params


def count_params(params: Params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def fused_decode_inputs(
    params: Params, spec: FoldingSpec, cfg: NTTDConfig
) -> tuple[jax.Array, ...]:
    """Stack params into the flat operand layout of the fused decode kernel.

    Embedding tables (shared per folded-mode length) are stacked per step
    and zero-padded to ``M = max(folded_shape)`` rows, giving one dense
    [T, M, H] operand that the kernel broadcasts once per core.  Returns
    ``(emb, wi, wh, b, w_first, b_first, w_mid, b_mid, w_last, b_last)``.
    """
    m_max = max(spec.folded_shape)
    steps = []
    for m in spec.folded_shape:
        tab = params[f"embed_{m}"]
        if m < m_max:
            tab = jnp.concatenate(
                [tab, jnp.zeros((m_max - m, tab.shape[1]), tab.dtype)], axis=0
            )
        steps.append(tab)
    emb = jnp.stack(steps, axis=0)  # [T, M, H]
    lstm = params["lstm"]
    return (
        emb,
        lstm["wi"],
        lstm["wh"],
        lstm["b"],
        params["head_first"]["w"],
        params["head_first"]["b"],
        params["head_mid"]["w"],
        params["head_mid"]["b"],
        params["head_last"]["w"],
        params["head_last"]["b"],
    )


def apply(
    params: Params,
    folded_idx: jax.Array,  # [B, d'] int32
    spec: FoldingSpec,
    cfg: NTTDConfig,
) -> jax.Array:
    """Approximate entries at the given folded indices.  Returns [B]."""
    d_prime = spec.d_prime
    r = cfg.rank
    if cfg.kernel_impl == "fused" and d_prime >= 2:
        # single-program decode: whole chain in one kernel / one XLA program
        # (Pallas on TPU, jitted oracle on CPU — see kernels.ops)
        return ops.nttd_decode_tile(
            folded_idx.astype(jnp.int32),
            *fused_decode_inputs(params, spec, cfg),
            impl="fused",
        )
    # --- embedding lookup (shared tables by mode length) -------------------
    embeds = [
        params[f"embed_{m}"][folded_idx[:, j]] for j, m in enumerate(spec.folded_shape)
    ]
    x = jnp.stack(embeds, axis=1)  # [B, d', h]
    # --- LSTM encoder -------------------------------------------------------
    lstm = params["lstm"]
    hs = ops.lstm_scan(x, lstm["wi"], lstm["wh"], lstm["b"], impl=cfg.kernel_impl)
    # --- TT-core heads --------------------------------------------------------
    first = hs[:, 0] @ params["head_first"]["w"] + params["head_first"]["b"]  # [B, R]
    last = hs[:, -1] @ params["head_last"]["w"] + params["head_last"]["b"]    # [B, R]
    if d_prime > 2:
        mids = (
            hs[:, 1:-1] @ params["head_mid"]["w"] + params["head_mid"]["b"]
        ).reshape(-1, d_prime - 2, r, r)  # [B, d'-2, R, R]
    else:
        mids = jnp.zeros((folded_idx.shape[0], 0, r, r), cfg.dtype)
    # --- chain contraction ----------------------------------------------------
    return ops.tt_contract(first, mids, last, impl=cfg.kernel_impl)


def apply_at_positions(
    params: Params,
    positions: jax.Array,  # [B, d] indices in the *reordered* tensor
    spec: FoldingSpec,
    cfg: NTTDConfig,
) -> jax.Array:
    """Convenience: fold positions on device then apply."""
    folded = spec.fold_indices(positions)
    return apply(params, folded, spec, cfg)


def make_predict(spec: FoldingSpec, cfg: NTTDConfig):
    """Jitted (params, positions[B, d]) -> values[B].  Cache and reuse —
    every call site holding its own instance avoids recompilation."""

    @jax.jit
    def predict(params: Params, positions: jax.Array) -> jax.Array:
        return apply_at_positions(params, positions, spec, cfg)

    return predict


# canonical home is repro.codecs.indexing; re-exported here for the many
# historical call sites (and external users) that import it from nttd
from repro.codecs.indexing import flat_to_multi  # noqa: E402, F401


def generate_tensor(
    params: Params,
    spec: FoldingSpec,
    cfg: NTTDConfig,
    batch: int = 65536,
    predict_fn=None,
) -> np.ndarray:
    """Materialize the full approximated tensor (reordered coordinates).

    Used for fitness evaluation on small/medium tensors and for the
    expressiveness experiment (Fig. 8).
    """
    n = spec.n_entries
    out = np.empty((n,), dtype=np.float32)
    fn = predict_fn or make_predict(spec, cfg)
    # fixed batch (pad the tail) so the jitted fn compiles exactly once
    for start in range(0, n, batch):
        stop = min(start + batch, n)
        flat = np.arange(start, stop, dtype=np.int64)
        if stop - start < batch:
            flat = np.pad(flat, (0, batch - (stop - start)))
        pos = flat_to_multi(flat, spec.shape)
        got = np.asarray(fn(params, jnp.asarray(pos, jnp.int32)))
        out[start:stop] = got[: stop - start]
    return out.reshape(spec.shape)
