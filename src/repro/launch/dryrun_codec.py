"""Dry-run cell for the paper's own workload: the NTTD compression
training step, data-parallel over sampled tensor entries on the
production mesh.

    PYTHONPATH=src python -m repro.launch.dryrun_codec \
        [--mesh single|multi] [--impl ref|ref_unrolled] \
        [--batch 65536] [--steps 8] [--rank 8] [--hidden 16]

Reports the same three-term roofline as the LM cells.  This is the
Perf-C hillclimb target (EXPERIMENTS.md §Perf).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import codec as codec_lib
from repro.core import nttd
from repro.core.folding import make_folding_spec
from repro.launch import dryrun, mesh as mesh_lib
from repro.optim import optimizers

# the paper's largest tensor family, scaled to a production-sized workload:
# compressing a (16384, 4096, 1024) dense tensor (~0.5 TB fp64)
DEFAULT_SHAPE = (16384, 4096, 1024)


def run(mesh_name: str, impl: str, batch: int, steps: int, rank: int,
        hidden: int, shape=DEFAULT_SHAPE, verbose: bool = True) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=mesh_name == "multi")
    spec = make_folding_spec(shape)
    cfg = nttd.NTTDConfig(rank=rank, hidden=hidden, kernel_impl=impl)
    opt = optimizers.adam(1e-2)
    epoch_fn = codec_lib._make_train_epoch(spec, cfg, opt)

    ab_params = jax.eval_shape(
        lambda k: nttd.init_params(k, spec, cfg), jax.random.PRNGKey(0)
    )
    ab_opt = jax.eval_shape(opt.init, ab_params)
    pos = jax.ShapeDtypeStruct((steps, batch, len(shape)), jnp.int32)
    vals = jax.ShapeDtypeStruct((steps, batch), jnp.float32)
    repl = NamedSharding(mesh, P())
    dp_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dp = NamedSharding(mesh, P(None, dp_axes))

    lowered = jax.jit(
        epoch_fn,
        in_shardings=(
            jax.tree.map(lambda _: repl, ab_params),
            jax.tree.map(lambda _: repl, ab_opt),
            dp,
            dp,
        ),
        donate_argnums=(0, 1),
    ).lower(ab_params, ab_opt, pos, vals)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = dryrun.cost_dict(compiled)
    coll = dryrun.collective_bytes_per_device(compiled.as_text())

    # cost_analysis under-counts the steps-loop (while); per-step numbers
    # are what matter — divide by the scan length is unnecessary since the
    # scan body is counted once: numbers below are PER STEP already.
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    n_entries = batch  # per step
    # useful flops per entry: LSTM (8h^2 per step x d') + heads + chain,
    # x3 for fwd+bwd
    d_prime = spec.d_prime
    per_entry = d_prime * (8 * hidden * hidden + 2 * hidden * rank * rank) + (
        d_prime - 2
    ) * 2 * rank * rank
    mf = 3.0 * per_entry * n_entries
    terms = {
        "compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": bytes_ / mesh_lib.HBM_BW,
        "collective_s": coll["total"] / mesh_lib.ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    ideal = max(
        (mf / mesh.size) / mesh_lib.PEAK_FLOPS_BF16,
        mem.argument_size_in_bytes / mesh_lib.HBM_BW,
    )
    res = {
        "arch": "tensorcodec-codec",
        "shape": f"entries{batch}x{steps}_impl-{impl}",
        "mesh": mesh_name,
        "rules": "dp",
        "status": "ok",
        "n_devices": mesh.size,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "flops_per_device": flops,
        "hlo_bytes_per_device": bytes_,
        "collective_bytes_per_device": coll,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(flops * mesh.size, 1.0),
        "roofline": dict(
            terms,
            dominant=dominant,
            bound_s=max(terms.values()),
            ideal_s=ideal,
            roofline_fraction=ideal / max(terms.values()),
        ),
    }
    if verbose:
        print(f"[codec x {mesh_name} x impl={impl} x batch={batch}]")
        print(f"  memory: args={mem.argument_size_in_bytes/1e6:.1f}MB "
              f"temp={mem.temp_size_in_bytes/1e6:.1f}MB")
        print(f"  flops/dev={flops:.3e} bytes/dev={bytes_:.3e} "
              f"coll/dev={coll['total']:.3e}")
        print("  roofline: " + " ".join(f"{k}={v:.6f}s" for k, v in terms.items())
              + f" dominant={dominant} fraction={res['roofline']['roofline_fraction']:.3f}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--impl", default="ref", choices=["ref", "ref_unrolled"])
    ap.add_argument("--batch", type=int, default=1 << 20)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--hidden", type=int, default=16)
    args = ap.parse_args()
    res = run(args.mesh, args.impl, args.batch, args.steps, args.rank, args.hidden)
    path = dryrun.cell_path("tensorcodec-codec", f"b{args.batch}-{args.impl}",
                            args.mesh, "dp")
    with open(path, "w") as f:
        json.dump(res, f, indent=2)


if __name__ == "__main__":
    main()
