"""Serving launcher: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, args.slots, args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        engine.submit(
            Request(
                uid=uid,
                prompt=rng.integers(0, cfg.vocab, size=args.prompt_len),
                max_new_tokens=args.max_new,
            )
        )
    results = engine.run()
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: {r.tokens[:8]}...")
    print(
        f"served {len(results)} requests, {total_new} tokens in {dt:.2f}s "
        f"({total_new/dt:.1f} tok/s)"
    )
    return results


if __name__ == "__main__":
    main()
