"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-coder-33b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        [--skip-done] [--rules base|fsdp]

Each cell writes benchmarks/results/dryrun/<arch>__<shape>__<mesh>__<rules>.json
with memory analysis, per-device HLO flops/bytes, per-device collective
bytes (parsed from the optimized HLO), and the three roofline terms.
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices; jax
# locks the device count at first init, so this precedes every import.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES
from repro.dist import sharding
from repro.launch import mesh as mesh_lib
from repro.models import model
from repro.optim import optimizers
from repro.train import step as step_lib

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun"
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# bytes multiplier per collective kind (ring algorithms, per-device traffic)
_COLL_FACTOR = {
    "all-reduce": 2.0,        # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128)\[([\d,]*)\]"
)
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_per_device(hlo_text: str, by_dtype: bool = False) -> dict[str, float]:
    """Parse optimized (post-SPMD) HLO; shapes are per-partition.

    ``by_dtype=True`` adds 'kind:dtype' keys (diagnosis: are the FSDP
    gathers moving bf16 or f32?)."""
    out: dict[str, float] = {k: 0.0 for k in _COLL_FACTOR}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind, _start = m.groups()
        out[kind] += _shape_bytes(type_str) * _COLL_FACTOR[kind]
        if by_dtype:
            for dtype, dims in _SHAPE_RE.findall(type_str):
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                key = f"{kind}:{dtype}"
                out[key] = out.get(key, 0.0) + n * _DTYPE_BYTES[dtype] * _COLL_FACTOR[kind]
    out["total"] = sum(v for k, v in out.items() if ":" not in k)
    return out


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on old."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs (6ND train, 2ND inference) on ACTIVE params."""
    n_active = model.param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def auto_rules(cfg, shape) -> str:
    """Weights + optimizer must fit 16GB/chip alongside activations: big
    models shard weights over the DP axes too (FSDP rules)."""
    n = model.param_count(cfg)
    if shape.kind == "train":
        return "fsdp" if n >= 10e9 else "base"
    return "fsdp" if n * 2 / 16 >= 12e9 else "base"  # bf16 over 16-way TP


def should_skip(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: long_500k requires sub-quadratic decode (DESIGN.md §6)"
    return None


def build_cell(arch: str, shape_name: str, mesh, rules_name: str = "base",
               remat: str | None = None, seq_shard: bool | None = None,
               depth_blocks: int | None = None):
    """Lower one cell.  Returns (lowered, cfg, shape).

    ``depth_blocks`` builds a depth-reduced UNROLLED variant: XLA's
    cost_analysis does not multiply while-loop bodies by trip count, so the
    scanned production program under-reports FLOPs/collectives
    ~n_layers-fold.  measure_cell compiles unrolled 1- and 3-block programs
    and extrapolates linearly (blocks are identical); memory comes from the
    scanned full-depth program, which is also the fits-on-chip proof.
    """
    import dataclasses as _dc

    cfg = configs.get(arch)
    shape_cfg = SHAPES[shape_name]
    if shape_cfg.kind != "train":
        # serving runs bf16 weights (no optimizer master copies)
        cfg = _dc.replace(cfg, param_dtype="bfloat16")
    if depth_blocks is not None:
        cfg = _dc.replace(
            cfg, n_layers=cfg.block_size * depth_blocks, scan_layers=False
        )
    if remat:
        cfg = _dc.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    if rules_name == "auto":
        rules_name = auto_rules(cfg, shape)
    base = sharding.BASE_RULES if rules_name == "base" else sharding.FSDP_RULES
    rules = step_lib.effective_rules(mesh, shape, base, cfg)
    if seq_shard is not None:
        rules["seq"] = "model" if seq_shard else None
    ab_params = model.abstract_params(cfg)
    ps = step_lib.param_shardings(mesh, cfg, rules)
    batch_spec = step_lib.input_specs(cfg, shape)
    bs = step_lib.batch_shardings(mesh, cfg, batch_spec, rules)
    long_ctx = rules.get("batch") is None

    with sharding.sharding_ctx(mesh, rules):
        if shape.kind == "train":
            opt = optimizers.adamw(1e-4, weight_decay=0.1, max_grad_norm=1.0)
            fn = step_lib.make_train_step(cfg, opt)
            ab_opt = step_lib.abstract_opt_state(cfg)
            os_ = step_lib.opt_shardings(mesh, cfg, rules)
            lowered = jax.jit(
                fn,
                in_shardings=(ps, os_, bs),
                donate_argnums=(0, 1),
            ).lower(ab_params, ab_opt, batch_spec)
        elif shape.kind == "prefill":
            fn = step_lib.make_prefill_step(cfg)
            ab_cache = model.abstract_cache(
                cfg, shape.global_batch, shape.seq_len, long_ctx
            )
            cs = step_lib.cache_shardings(
                mesh, cfg, shape.global_batch, shape.seq_len, long_ctx, rules
            )
            lowered = jax.jit(
                fn, in_shardings=(ps, cs, bs), donate_argnums=(1,)
            ).lower(ab_params, ab_cache, batch_spec)
        else:  # decode
            fn = step_lib.make_decode_step(cfg)
            ab_cache = model.abstract_cache(
                cfg, shape.global_batch, shape.seq_len, long_ctx
            )
            cs = step_lib.cache_shardings(
                mesh, cfg, shape.global_batch, shape.seq_len, long_ctx, rules
            )
            lowered = jax.jit(
                fn, in_shardings=(ps, cs, bs, step_lib.replicated(mesh)),
                donate_argnums=(1,),
            ).lower(
                ab_params, ab_cache, batch_spec,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
    return lowered, cfg, shape


def run_cell(arch: str, shape_name: str, mesh_name: str, rules_name: str = "base",
             verbose: bool = True, remat: str | None = None,
             seq_shard: bool | None = None) -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    result: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "rules": rules_name,
    }
    if skip:
        result["status"] = "skip"
        result["reason"] = skip
        return result

    mesh = mesh_lib.make_production_mesh(multi_pod=mesh_name == "multi")
    n_dev = mesh.size

    # --- pass 1: scanned full-depth production program -> memory proof -----
    t0 = time.time()
    lowered, cfg, shape = build_cell(arch, shape_name, mesh, rules_name, remat, seq_shard)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # --- pass 2: unrolled depth-1/3 programs -> exact per-block costs ---------
    def costs(depth):
        low, dcfg, _ = build_cell(
            arch, shape_name, mesh, rules_name, remat, seq_shard, depth_blocks=depth
        )
        comp = low.compile()
        cost = cost_dict(comp)
        coll = collective_bytes_per_device(comp.as_text())
        return (
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll,
        )

    # depth-1 programs get anomalous partitioning choices; depths >= 2 are
    # stable (validated: per-block deltas from (2,3) and (4,6) agree <1%).
    # Wide blocks (jamba: 8 mixed sublayers/block) use (1,2) — a depth-4
    # unrolled hybrid program (32 layers) takes >30 min to compile on this
    # container; the depth-1 anomaly is small relative to an 8-sublayer
    # block (validated on the hybrid smoke config).
    t0 = time.time()
    d_lo, d_hi = (1, 2) if cfg.block_size >= 8 else (2, 4)
    f2, b2, c2 = costs(d_lo)
    f4, b4, c4 = costs(d_hi)
    t_cost = time.time() - t0
    nb = cfg.n_blocks
    span = d_hi - d_lo
    extrap = lambda v2, v4: v2 + (nb - d_lo) * (v4 - v2) / span  # noqa: E731
    flops_dev = extrap(f2, f4)
    bytes_dev = extrap(b2, b4)
    coll = {k: extrap(c2[k], c4[k]) for k in c2}
    mf = model_flops(cfg, shape)

    compute_s = flops_dev / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_dev / mesh_lib.HBM_BW
    collective_s = coll["total"] / mesh_lib.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    # roofline fraction: ideal step time / modelled step time.  Ideal is the
    # max of the compute-side bound (useful FLOPs at peak) and the memory-
    # side bound (every resident argument byte read once per step) — the
    # latter is what decode is limited by.
    ideal_compute_s = (mf / n_dev) / mesh_lib.PEAK_FLOPS_BF16
    ideal_memory_s = mem.argument_size_in_bytes / mesh_lib.HBM_BW
    ideal_s = max(ideal_compute_s, ideal_memory_s)
    result.update(
        status="ok",
        n_devices=n_dev,
        n_blocks=nb,
        seconds_lower=round(t_lower, 2),
        seconds_compile=round(t_compile, 2),
        seconds_cost_passes=round(t_cost, 2),
        remat=remat or cfg.remat,
        seq_shard=seq_shard,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "peak_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        flops_per_device=flops_dev,
        hlo_bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll,
        model_flops=mf,
        hlo_flops_total=flops_dev * n_dev,
        useful_flops_ratio=mf / max(flops_dev * n_dev, 1.0),
        roofline=dict(
            terms,
            dominant=dominant,
            bound_s=bound_s,
            ideal_compute_s=ideal_compute_s,
            ideal_memory_s=ideal_memory_s,
            ideal_s=ideal_s,
            roofline_fraction=ideal_s / bound_s if bound_s > 0 else 0.0,
        ),
    )
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name} x {rules_name}]")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s cost-passes {t_cost:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(
            "  cost_analysis: flops/dev={:.3e} bytes/dev={:.3e}".format(
                flops_dev, bytes_dev
            )
        )
        print(
            "  collectives/dev: "
            + " ".join(f"{k}={v:.3e}" for k, v in coll.items() if v)
        )
        print(
            "  roofline: compute={compute_s:.4f}s memory={memory_s:.4f}s "
            "collective={collective_s:.4f}s".format(**terms)
            + f" dominant={dominant} fraction={result['roofline']['roofline_fraction']:.3f}"
        )
    return result


def cell_path(arch, shape, mesh, rules):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}__{rules}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="auto", choices=["auto", "base", "fsdp"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "none", "dots", "full"])
    ap.add_argument("--seq-shard", default=None, type=int, choices=[0, 1])
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [
            (a, s, m)
            for a in configs.ARCH_IDS
            for s in SHAPES
            for m in meshes
        ]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, mesh_name in cells:
        path = cell_path(arch, shape, mesh_name, args.rules)
        if args.skip_done and os.path.exists(path):
            print(f"skip (done): {arch} x {shape} x {mesh_name}")
            continue
        try:
            res = run_cell(
                arch, shape, mesh_name, args.rules,
                remat=args.remat,
                seq_shard=None if args.seq_shard is None else bool(args.seq_shard),
            )
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            traceback.print_exc()
            res = {
                "arch": arch, "shape": shape, "mesh": mesh_name,
                "rules": args.rules, "status": "error", "error": f"{type(e).__name__}: {e}",
            }
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
    print(f"done: {len(cells)} cells, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
