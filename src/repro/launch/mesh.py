"""Production mesh builders.

TPU v5e pod targets: single pod = 16x16 (256 chips) with (data, model)
axes; multi-pod = 2 pods x 256 chips with a leading 'pod' axis (DCN
data-parallel dimension).  Functions, not module constants — importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax

# TPU v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~45-50 GB/s on v5e)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for subprocess tests (forced host devices)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
