"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto

Runs the real pjit train loop on whatever mesh fits the local devices
(the production mesh shape comes from launch.mesh on a real pod).
Includes: WSD/cosine schedules, grad clipping, async checkpointing with
auto-resume, SIGTERM -> final checkpoint, straggler watchdog (p95
step-time outliers logged), optional gradient compression, optional
NTTD-compressed checkpoint export.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import PipelineConfig, SyntheticSource
from repro.dist import sharding
from repro.models import model
from repro.optim import optimizers, schedules
from repro.train import checkpoint as ckpt_lib
from repro.train import step as step_lib


class StragglerWatchdog:
    """Flags steps slower than ``factor`` x the trailing median."""

    def __init__(self, window: int = 50, factor: float = 2.0):
        self.times: list[float] = []
        self.window = window
        self.factor = factor
        self.flagged = 0

    def record(self, dt: float) -> bool:
        hist = self.times[-self.window :]
        slow = len(hist) >= 10 and dt > self.factor * float(np.median(hist))
        self.times.append(dt)
        if slow:
            self.flagged += 1
        return slow


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd", choices=["wsd", "cosine", "constant"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--grad-compress", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--data", default=None, help="path to int32 token file (mmap)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default=None,
                    help="DxM, e.g. 2x2 (default: all devices data-parallel)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    n_dev = len(jax.devices())
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    else:
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    sched = {
        "wsd": schedules.wsd(args.lr, args.steps, warmup=min(20, args.steps // 10)),
        "cosine": schedules.cosine(args.lr, args.steps, warmup=min(20, args.steps // 10)),
        "constant": schedules.constant(args.lr),
    }[args.schedule]
    opt = optimizers.adamw(sched, weight_decay=0.1, max_grad_norm=1.0)

    # ---- grad compression hook ------------------------------------------------
    comp = None
    if args.grad_compress != "none":
        from repro.dist import grad_compress

        comp = (
            grad_compress.ErrorFeedbackInt8()
            if args.grad_compress == "int8"
            else grad_compress.TopK(0.05)
        )

    rules = sharding.BASE_RULES
    ps = step_lib.param_shardings(mesh, cfg, rules)
    os_sh = step_lib.opt_shardings(mesh, cfg, rules)

    key = jax.random.PRNGKey(0)
    with sharding.sharding_ctx(mesh, rules):
        params = jax.jit(
            lambda k: model.init_params(k, cfg), out_shardings=ps
        )(key)
        opt_state = jax.jit(opt.init, out_shardings=os_sh)(params)
        comp_state = comp.init(params) if comp else None

        if comp is None:
            raw_step = step_lib.make_train_step(cfg, opt)
            train_step = jax.jit(raw_step, donate_argnums=(0, 1))
        else:

            def step_with_comp(params, opt_state, comp_state, batch):
                def loss(p):
                    return model.loss_fn(p, cfg, batch)

                (loss_val, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
                grads, comp_state = comp.transform(grads, comp_state)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = optimizers.apply_updates(params, updates)
                m = dict(metrics)
                m["loss"] = loss_val
                return params, opt_state, comp_state, m

            train_step = jax.jit(step_with_comp, donate_argnums=(0, 1, 2))

        # ---- data ------------------------------------------------------------------
        pcfg = PipelineConfig(
            batch_size=args.batch, seq_len=args.seq, vocab=cfg.vocab, seed=0
        )
        if args.data:
            from repro.data.pipeline import MMapSource

            source = MMapSource(args.data, pcfg)
        else:
            source = SyntheticSource(pcfg)

        # ---- checkpointing / resume ----------------------------------------------
        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = ckpt_lib.Checkpointer(args.ckpt_dir)
            if args.resume == "auto":
                state, start_step = ckpt_lib.auto_resume(
                    ckpt, {"params": params, "opt": opt_state}, {"params": ps, "opt": os_sh}
                )
                if state is not None:
                    params, opt_state = state["params"], state["opt"]
                    print(f"resumed from step {start_step}")

        stop = {"flag": False}

        def on_sigterm(signum, frame):
            stop["flag"] = True

        signal.signal(signal.SIGTERM, on_sigterm)

        watchdog = StragglerWatchdog()
        losses = []
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch_np = source.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if comp is None:
                params, opt_state, metrics = train_step(params, opt_state, batch)
            else:
                params, opt_state, comp_state, metrics = train_step(
                    params, opt_state, comp_state, batch
                )
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if watchdog.record(dt):
                print(f"[watchdog] step {step} straggled: {dt:.3f}s")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step}: loss={loss:.4f} ({dt*1000:.0f} ms)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
            if stop["flag"]:
                print("SIGTERM: writing final checkpoint")
                break

        if ckpt:
            ckpt.save(args.steps if not stop["flag"] else step + 1,
                      {"params": params, "opt": opt_state})
            ckpt.wait()
    print(f"done. first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
