"""Pallas TPU kernel: causal GQA flash attention (online softmax).

Used by the LM serving/training path on TPU.  Classic three-loop flash
structure: grid = (batch*q_heads, q_tiles, kv_tiles) with the kv axis
innermost; running (m, l, acc) live in VMEM scratch and persist across the
sequential TPU grid, so each q tile streams over kv tiles with no HBM
round-trips for the softmax state.  GQA is handled in the BlockSpec index
maps (q head -> kv head = h // group), so no head replication ever
materializes.

Block sizes default to (128, 128): MXU-aligned on both matmuls
(q @ k^T and p @ v).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_Q = 128
DEFAULT_TILE_KV = 128
NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
    causal: bool, q_offset: int, scale: float, tile_q: int, tile_kv: int,
    kv_valid: int | None,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [TQ, D]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [TKV, D]
    v = v_ref[0, :, 0, :].astype(jnp.float32)           # [TKV, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [TQ, TKV]

    if causal or kv_valid is not None:
        kpos = ki * tile_kv + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_kv), 1)
        keep = None
        if causal:
            qpos = qi * tile_q + jax.lax.broadcasted_iota(
                jnp.int32, (tile_q, tile_kv), 0
            )
            keep = qpos + q_offset >= kpos
        if kv_valid is not None:
            # kv padded to the tile boundary: mask the padded columns
            pad_keep = kpos < kv_valid
            keep = pad_keep if keep is None else jnp.logical_and(keep, pad_keep)
        s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)[:, None]                # [TQ, 1]
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next)                            # [TQ, TKV]
    l_next = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
    m_scr[...] = m_next
    l_scr[...] = l_next
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _finish():
        denom = l_scr[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)  # fully-masked rows -> 0 output
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "q_offset", "tile_q", "tile_kv", "interpret", "kv_valid"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    tile_q: int = DEFAULT_TILE_Q,
    tile_kv: int = DEFAULT_TILE_KV,
    interpret: bool = False,
    kv_valid: int | None = None,
) -> jax.Array:
    """q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].

    ``kv_valid``: static count of real kv positions when k/v were padded up
    to ``tile_kv`` — columns >= kv_valid are masked out of the softmax.
    """
    bsz, sq, hq, dim = q.shape
    _, skv, hkv, _ = k.shape
    if sq % tile_q or skv % tile_kv:
        raise ValueError(f"seq lengths ({sq},{skv}) not multiples of tiles")
    group = hq // hkv
    grid = (bsz * hq, sq // tile_q, skv // tile_kv)
    scale = 1.0 / (dim**0.5)

    kv_index = lambda bh, qi, ki: (bh // hq, ki, (bh % hq) // group, 0)  # noqa: E731
    return pl.pallas_call(
        functools.partial(
            _kernel,
            causal=causal,
            q_offset=q_offset,
            scale=scale,
            tile_q=tile_q,
            tile_kv=tile_kv,
            kv_valid=kv_valid,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, 1, dim), lambda bh, qi, ki: (bh // hq, qi, bh % hq, 0)),
            pl.BlockSpec((1, tile_kv, 1, dim), kv_index),
            pl.BlockSpec((1, tile_kv, 1, dim), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, tile_q, 1, dim), lambda bh, qi, ki: (bh // hq, qi, bh % hq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, 1), jnp.float32),
            pltpu.VMEM((tile_q, dim), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
