"""Pallas TPU kernel: batched TT-core chain contraction.

The NTTD reconstruction hot spot (paper Alg. 2 line 8) multiplies, per
sampled entry, a 1xR row vector through K RxR matrices and a final Rx1
column.  R is small (4..32), so a 128x128 MXU pass would be >94% idle —
this is restructured as a *lane-parallel batched matvec*: the batch is
tiled into VMEM blocks of TILE_B rows (sublane axis), and the per-step
contraction v[b,s] = sum_r v[b,r] * M[b,k,r,s] is an unrolled VPU
multiply-accumulate over the tiny R axis.

HBM traffic: each core tensor is read exactly once; the running vector
stays in registers/VMEM across all K steps (the fusion the XLA path
cannot guarantee across scan iterations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 256


def _kernel(first_ref, mid_ref, last_ref, out_ref, *, k_steps: int):
    v = first_ref[...].astype(jnp.float32)  # [TB, R]

    def body(k, v):
        m = mid_ref[:, k].astype(jnp.float32)  # [TB, R, R]
        # lane-parallel batched matvec on the VPU (R is tiny)
        return jnp.sum(v[:, :, None] * m, axis=1)

    if k_steps > 0:
        v = jax.lax.fori_loop(0, k_steps, body, v)
    out_ref[...] = jnp.sum(v * last_ref[...].astype(jnp.float32), axis=1).astype(
        out_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def tt_contract(
    first: jax.Array,
    mid: jax.Array,
    last: jax.Array,
    *,
    tile_b: int = DEFAULT_TILE_B,
    interpret: bool = False,
) -> jax.Array:
    """first: [B, R], mid: [B, K, R, R], last: [B, R] -> [B].

    B must be a multiple of ``tile_b`` (callers pad; ``ops.tt_contract``
    handles padding automatically).
    """
    bsz, r = first.shape
    _, k_steps, _, _ = mid.shape
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} not a multiple of tile_b {tile_b}")
    grid = (bsz // tile_b,)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, r), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, k_steps, r, r), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((tile_b, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), first.dtype),
        interpret=interpret,
    )(first, mid, last)
