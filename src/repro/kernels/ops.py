"""Backend-dispatching wrappers around the Pallas kernels.

``impl`` selects the execution path:
  * "ref"               — pure-jnp oracle (XLA).  Default on CPU.
  * "pallas"            — compiled Pallas kernel.  Default on TPU.
  * "pallas_interpret"  — Pallas kernel body interpreted in Python
                          (correctness validation on CPU).
  * "auto"              — "pallas" on TPU else "ref".

Wrappers also handle batch padding so callers never worry about tile
divisibility.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import attention as _attention
from repro.kernels import lstm as _lstm
from repro.kernels import ref as _ref
from repro.kernels import tt_contract as _tt


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _pad_batch(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    bsz = x.shape[0]
    pad = (-bsz) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, bsz


def tt_contract(
    first: jax.Array,
    mid: jax.Array,
    last: jax.Array,
    *,
    impl: str = "auto",
    tile_b: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.tt_contract(first, mid, last)
    if impl == "ref_unrolled":
        return _ref.tt_contract_unrolled(first, mid, last)
    if mid.shape[1] == 0:
        # degenerate 2-core chain: no mid tensor to tile (zero-size blocks
        # break pallas); the contraction is a plain row dot
        return jnp.sum(first * last, axis=-1)
    tile = tile_b or min(_tt.DEFAULT_TILE_B, max(8, first.shape[0]))
    f, bsz = _pad_batch(first, tile)
    m, _ = _pad_batch(mid, tile)
    lp, _ = _pad_batch(last, tile)
    out = _tt.tt_contract(f, m, lp, tile_b=tile, interpret=impl == "pallas_interpret")
    return out[:bsz]


def lstm_scan(
    x: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    *,
    impl: str = "auto",
    tile_b: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.lstm_scan(x, wi, wh, b)
    if impl == "ref_unrolled":
        # XLA-path fusion lever: unrolling the d' ~ 8..12 steps lets XLA
        # fuse gate math across steps instead of round-tripping the carry
        # through the while-loop boundary (the same motivation as the
        # Pallas kernel, achievable without Pallas)
        return _ref.lstm_unrolled(x, wi, wh, b)
    tile = tile_b or min(_lstm.DEFAULT_TILE_B, max(8, x.shape[0]))
    xp, bsz = _pad_batch(x, tile)
    out = _lstm.lstm_scan(xp, wi, wh, b, tile_b=tile, interpret=impl == "pallas_interpret")
    return out[:bsz]


CHUNKED_THRESHOLD = 2048  # switch the XLA path to q-chunked attention


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    impl = _resolve(impl)
    if impl in ("ref", "chunked") or kv_len is not None or q.shape[1] % 128 or k.shape[1] % 128:
        # variable-length and non-tile-aligned cases use the oracle path
        if kv_len is None and (
            impl == "chunked" or q.shape[1] >= CHUNKED_THRESHOLD
        ):
            return _ref.mha_attention_chunked(q, k, v, causal=causal, q_offset=q_offset)
        return _ref.mha_attention(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    return _attention.flash_attention(
        q, k, v, causal=causal, q_offset=q_offset,
        interpret=impl == "pallas_interpret",
    )
