"""Backend-dispatching wrappers around the Pallas kernels.

``impl`` selects the execution path:
  * "ref"               — pure-jnp oracle (XLA).  Default on CPU.
  * "pallas"            — compiled Pallas kernel.  Default on TPU.
  * "pallas_interpret"  — Pallas kernel body interpreted in Python
                          (correctness validation on CPU).
  * "fused"             — one-program decode (``nttd_decode_tile`` only):
                          the Pallas kernel on TPU, the jitted oracle on
                          CPU.  Either way the whole decode chain runs as
                          a single compiled program instead of a chain of
                          separately dispatched ops.
  * "auto"              — "pallas" on TPU else "ref" ("fused" for
                          ``nttd_decode_tile``, where the jitted oracle is
                          the fast CPU path).

Wrappers also handle batch padding so callers never worry about tile
divisibility.  Silent fallback to the oracle on shapes a kernel cannot
take is reserved for ``impl="auto"``; an explicitly requested backend is
honored by padding+masking instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import attention as _attention
from repro.kernels import decode_tile as _dt
from repro.kernels import lstm as _lstm
from repro.kernels import ref as _ref
from repro.kernels import tt_contract as _tt


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _pad_batch(x: jax.Array, mult: int) -> tuple[jax.Array, int]:
    bsz = x.shape[0]
    pad = (-bsz) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x, bsz


def tt_contract(
    first: jax.Array,
    mid: jax.Array,
    last: jax.Array,
    *,
    impl: str = "auto",
    tile_b: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.tt_contract(first, mid, last)
    if impl == "ref_unrolled":
        return _ref.tt_contract_unrolled(first, mid, last)
    if mid.shape[1] == 0:
        # degenerate 2-core chain: no mid tensor to tile (zero-size blocks
        # break pallas); the contraction is a plain row dot
        return jnp.sum(first * last, axis=-1)
    tile = tile_b or min(_tt.DEFAULT_TILE_B, max(8, first.shape[0]))
    f, bsz = _pad_batch(first, tile)
    m, _ = _pad_batch(mid, tile)
    lp, _ = _pad_batch(last, tile)
    out = _tt.tt_contract(f, m, lp, tile_b=tile, interpret=impl == "pallas_interpret")
    return out[:bsz]


def lstm_scan(
    x: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    *,
    impl: str = "auto",
    tile_b: int | None = None,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.lstm_scan(x, wi, wh, b)
    if impl == "ref_unrolled":
        # XLA-path fusion lever: unrolling the d' ~ 8..12 steps lets XLA
        # fuse gate math across steps instead of round-tripping the carry
        # through the while-loop boundary (the same motivation as the
        # Pallas kernel, achievable without Pallas)
        return _ref.lstm_unrolled(x, wi, wh, b)
    tile = tile_b or min(_lstm.DEFAULT_TILE_B, max(8, x.shape[0]))
    xp, bsz = _pad_batch(x, tile)
    out = _lstm.lstm_scan(xp, wi, wh, b, tile_b=tile, interpret=impl == "pallas_interpret")
    return out[:bsz]


CHUNKED_THRESHOLD = 2048  # switch the XLA path to q-chunked attention


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,
    impl: str = "auto",
) -> jax.Array:
    requested = impl
    impl = _resolve(impl)
    misaligned = q.shape[1] % _attention.DEFAULT_TILE_Q or (
        k.shape[1] % _attention.DEFAULT_TILE_KV
    )
    if (
        impl in ("ref", "chunked")
        or kv_len is not None
        or (requested == "auto" and misaligned)
    ):
        # variable-length cases use the oracle path; silent fallback on
        # non-tile-aligned shapes is reserved for impl="auto" — an explicit
        # "pallas"/"pallas_interpret" request is honored via pad+mask below
        if kv_len is None and (
            impl == "chunked" or q.shape[1] >= CHUNKED_THRESHOLD
        ):
            return _ref.mha_attention_chunked(q, k, v, causal=causal, q_offset=q_offset)
        return _ref.mha_attention(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len)
    sq, skv = q.shape[1], k.shape[1]
    pad_q = (-sq) % _attention.DEFAULT_TILE_Q
    pad_kv = (-skv) % _attention.DEFAULT_TILE_KV
    kv_valid = None
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_valid = skv  # static: mask the padded kv columns in-kernel
    out = _attention.flash_attention(
        q, k, v, causal=causal, q_offset=q_offset,
        interpret=impl == "pallas_interpret",
        kv_valid=kv_valid,
    )
    return out[:, :sq] if pad_q else out


# Fused NTTD decode: jitted oracle = the single-program CPU path (the whole
# chain compiles to one XLA executable instead of per-op dispatches).
_fused_oracle = jax.jit(_ref.nttd_decode_tile)


def nttd_decode_tile(
    idx: jax.Array,
    emb: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    w_first: jax.Array,
    b_first: jax.Array,
    w_mid: jax.Array,
    b_mid: jax.Array,
    w_last: jax.Array,
    b_last: jax.Array,
    *,
    impl: str = "auto",
    tile_b: int | None = None,
) -> jax.Array:
    """Fused NTTD decode of a [B, T] tile of folded indices -> [B] values.

    See ``decode_tile.decode_tile`` for operand layout.  Batch padding to
    the Pallas tile is handled here; B == 0 short-circuits (a zero-size
    grid is invalid in Pallas).
    """
    if idx.shape[0] == 0:
        return jnp.zeros((0,), emb.dtype)
    if impl in ("auto", "fused"):
        impl = "pallas" if jax.default_backend() == "tpu" else "fused"
    heads = (w_first, b_first, w_mid, b_mid, w_last, b_last)
    with obs.span("kernel_decode", impl=impl, b=int(idx.shape[0])):
        if impl == "ref":
            return _ref.nttd_decode_tile(idx, emb, wi, wh, b, *heads)
        if impl == "fused":
            return _fused_oracle(idx, emb, wi, wh, b, *heads)
        tile = tile_b or min(_dt.DEFAULT_TILE_B, max(8, idx.shape[0]))
        idx_p, bsz = _pad_batch(idx, tile)
        out = _dt.decode_tile(
            idx_p, emb, wi, wh, b, *heads,
            tile_b=tile, interpret=impl == "pallas_interpret",
        )
        return out[:bsz]
