"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel is validated
against the function of the same name here (interpret=True on CPU,
compiled on TPU).  They are also the execution path used on non-TPU
backends (tests, benches, the CPU dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# TT-core chain contraction (NTTD, Alg. 2 line 8)
# ----------------------------------------------------------------------------
def tt_contract(first: jax.Array, mid: jax.Array, last: jax.Array) -> jax.Array:
    """Chain product  T1 @ T2 @ ... @ Td  per batch element.

    first: [B, R]        (T1, shape 1xR squeezed)
    mid:   [B, K, R, R]  (T2..T_{d-1}); K may be 0
    last:  [B, R]        (Td, shape Rx1 squeezed)
    returns [B]
    """
    def step(v, m):
        # v: [B, R], m: [B, R, R] -> [B, R]
        return jnp.einsum("br,brs->bs", v, m), None

    if mid.shape[1] == 0:
        v = first
    else:
        v, _ = jax.lax.scan(step, first, jnp.moveaxis(mid, 1, 0))
    return jnp.sum(v * last, axis=-1)


def tt_contract_unrolled(first: jax.Array, mid: jax.Array, last: jax.Array) -> jax.Array:
    """Chain product with the K loop unrolled (K is tiny for NTTD); XLA
    fuses the whole chain into one kernel instead of K loop iterations."""
    v = first
    for k in range(mid.shape[1]):
        v = jnp.einsum("br,brs->bs", v, mid[:, k])
    return jnp.sum(v * last, axis=-1)


# ----------------------------------------------------------------------------
# Fused LSTM scan (NTTD, Alg. 2 line 3)
# ----------------------------------------------------------------------------
def lstm_scan(
    x: jax.Array, wi: jax.Array, wh: jax.Array, b: jax.Array
) -> jax.Array:
    """Single-layer LSTM over a short sequence.

    x:  [B, T, H]  input embeddings
    wi: [H, 4H]    input->gates
    wh: [H, 4H]    hidden->gates
    b:  [4H]       gate bias
    returns hidden states [B, T, H] in ``x.dtype``

    Gate layout along the 4H axis: (i, f, g, o).  Carries and gate math
    run in f32 regardless of ``x.dtype`` — the Pallas kernel computes in
    f32 and casts back, so the oracle must too or bf16 parity tests
    compare unlike against unlike.
    """
    bsz, _, hid = x.shape
    xf = x.astype(jnp.float32)
    wif = wi.astype(jnp.float32)
    whf = wh.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(carry, xt):
        h, c = carry
        gates = xt @ wif + h @ whf + bf
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    init = (
        jnp.zeros((bsz, hid), dtype=jnp.float32),
        jnp.zeros((bsz, hid), dtype=jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(xf, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)


def lstm_unrolled(
    x: jax.Array, wi: jax.Array, wh: jax.Array, b: jax.Array
) -> jax.Array:
    """Same semantics as lstm_scan with the time loop unrolled in Python
    (T is tiny for NTTD); XLA fuses across steps.  f32 internally, like
    lstm_scan and the Pallas kernel."""
    bsz, t_steps, hid = x.shape
    xf = x.astype(jnp.float32)
    wif = wi.astype(jnp.float32)
    whf = wh.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    h = jnp.zeros((bsz, hid), dtype=jnp.float32)
    c = jnp.zeros((bsz, hid), dtype=jnp.float32)
    outs = []
    for t in range(t_steps):
        gates = xf[:, t] @ wif + h @ whf + bf
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        outs.append(h)
    return jnp.stack(outs, axis=1).astype(x.dtype)


# ----------------------------------------------------------------------------
# Fused NTTD decode tile (paper Alg. 2, the whole per-entry chain)
# ----------------------------------------------------------------------------
def nttd_decode_tile(
    idx: jax.Array,
    emb: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    w_first: jax.Array,
    b_first: jax.Array,
    w_mid: jax.Array,
    b_mid: jax.Array,
    w_last: jax.Array,
    b_last: jax.Array,
) -> jax.Array:
    """Oracle for ``decode_tile.decode_tile``: embedding gather -> T-step
    LSTM -> first/mid/last head projections -> R-wide chain contraction,
    all in one expression.

    idx: [B, T] int32 folded indices; emb: [T, M, H] stacked per-step
    embedding tables (padded to M rows); heads as in decode_tile.
    Returns [B] in ``emb.dtype``.

    All math is f32 internally (matching the kernel), with the chain
    contracted step-interleaved in the exact order the kernel uses so
    interpret-mode parity is bitwise, not merely close.
    """
    bsz, t_steps = idx.shape
    if t_steps < 2:
        raise ValueError(f"nttd_decode_tile needs T >= 2 steps, got {t_steps}")
    rank = b_first.shape[0]
    hid = emb.shape[-1]
    embf = emb.astype(jnp.float32)
    wif = wi.astype(jnp.float32)
    whf = wh.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    h = jnp.zeros((bsz, hid), jnp.float32)
    c = jnp.zeros((bsz, hid), jnp.float32)
    v = None
    out = None
    for t in range(t_steps):
        xt = jnp.take(embf[t], idx[:, t], axis=0)  # [B, H]
        gates = xt @ wif + h @ whf + bf
        i = jax.nn.sigmoid(gates[:, :hid])
        f = jax.nn.sigmoid(gates[:, hid : 2 * hid])
        g = jnp.tanh(gates[:, 2 * hid : 3 * hid])
        o = jax.nn.sigmoid(gates[:, 3 * hid :])
        c = f * c + i * g
        h = o * jnp.tanh(c)
        if t == 0:
            v = h @ w_first.astype(jnp.float32) + b_first.astype(jnp.float32)
        elif t == t_steps - 1:
            last = h @ w_last.astype(jnp.float32) + b_last.astype(jnp.float32)
            out = jnp.sum(v * last, axis=-1)
        else:
            mid = (
                h @ w_mid.astype(jnp.float32) + b_mid.astype(jnp.float32)
            ).reshape(bsz, rank, rank)
            v = jnp.sum(v[:, :, None] * mid, axis=1)
    return out.astype(emb.dtype)


# ----------------------------------------------------------------------------
# Causal GQA attention (LM serving/training path)
# ----------------------------------------------------------------------------
def mha_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Grouped-query attention oracle.

    q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: cache length so far).
    ``kv_len``: optional [B] valid kv lengths (entries beyond are masked).
    Softmax in f32; output in q.dtype.
    """
    bq, sq, hq, dim = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    qf = q.astype(jnp.float32) / jnp.sqrt(dim).astype(jnp.float32)
    qg = qf.reshape(bq, sq, hkv, group, dim)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    mask = None
    if causal:
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]  # [Sq, Skv]
        mask = mask[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(skv)[None, :] < kv_len[:, None]  # [B, Skv]
        valid = valid[:, None, None, None, :]
        mask = valid if mask is None else jnp.logical_and(mask, valid)
    if mask is not None:
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(bq, sq, hq, dim).astype(q.dtype)


def mha_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    chunk: int = 512,
) -> jax.Array:
    """Memory-bounded exact attention: scan over q chunks, rematerialized.

    The [B, H, chunk, Skv] score block is the peak transient instead of the
    full [B, H, Sq, Skv] — this is the XLA-path equivalent of the flash
    kernel's working-set bound and the configuration the dry-run lowers for
    long sequences.  Ragged sequences (sq % chunk != 0) scan the aligned
    prefix and attend the tail chunk separately, so the memory bound holds
    for every length, not just multiples of ``chunk``.
    """
    bq, sq, hq, dim = q.shape
    if sq <= chunk:
        return mha_attention(q, k, v, causal=causal, q_offset=q_offset)

    nq, tail = divmod(sq, chunk)
    aligned = nq * chunk

    def body(carry, qc_and_off):
        qc, off = qc_and_off
        out = mha_attention(qc, k, v, causal=causal, q_offset=off)
        return carry, out

    body = jax.checkpoint(body)
    qs = jnp.moveaxis(
        q[:, :aligned].reshape(bq, nq, chunk, hq, dim), 1, 0
    )  # [nq,B,chunk,H,D]
    offs = q_offset + jnp.arange(nq) * chunk
    _, outs = jax.lax.scan(body, (), (qs, offs))
    out = jnp.moveaxis(outs, 0, 1).reshape(bq, aligned, hq, dim)
    if tail:
        tail_out = mha_attention(
            q[:, aligned:], k, v, causal=causal, q_offset=q_offset + aligned
        )
        out = jnp.concatenate([out, tail_out], axis=1)
    return out
