"""Pallas TPU kernel: the whole NTTD decode for one tile, fused.

The serving hot path (paper Alg. 2) reconstructs a batch of entries as

    folded indices --embedding--> e_1..e_T --LSTM--> h_1..h_T
    T_1 = h_1 W_f + b_f (1xR); T_t = h_t W_m + b_m (RxR); T_T = h_T W_l + b_l
    value = T_1 T_2 ... T_T

which previously crossed four separately dispatched ops per decode tile
(gather, ``lstm.py``, three head matmuls, ``tt_contract.py``).  This kernel
runs the entire chain in ONE ``pl.pallas_call``: the batch is tiled on the
sublane axis, ``(h, c)`` and the running TT row vector stay resident in
VMEM/registers across all T steps, and every weight tensor (the stacked
embedding tables included) is broadcast once per core via constant index
maps — each HBM operand is read exactly once per core regardless of how
many batch tiles stream through.

The embedding gather is a one-hot matmul (``[TB, M] @ [M, H]``), the
standard TPU formulation of a row gather: it hits the MXU, needs no
dynamic indexing, and is exact in f32 (one 1.0 coefficient, the rest
0.0).  The T-step loop is unrolled at trace time (T = d' is ~4..12 for
NTTD), so the mid-core head projection and the R-wide chain contraction
of step t fuse directly with step t's gate math.

All internal math is f32 regardless of the parameter dtype (matching
``lstm.py``/``tt_contract.py`` and the promoted oracles in ``ref.py``);
the output is cast back to the embedding dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 256


def _kernel(
    idx_ref,
    emb_ref,
    wi_ref,
    wh_ref,
    b_ref,
    wf_ref,
    bf_ref,
    wm_ref,
    bm_ref,
    wl_ref,
    bl_ref,
    out_ref,
    *,
    t_steps: int,
    hid: int,
    rank: int,
    m: int,
):
    tb = idx_ref.shape[0]
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    h = jnp.zeros((tb, hid), jnp.float32)
    c = jnp.zeros((tb, hid), jnp.float32)
    v = None  # running TT row vector [TB, R]
    out = None
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tb, m), 1)
    for t in range(t_steps):
        onehot = (idx_ref[:, t][:, None] == lanes).astype(jnp.float32)
        xt = jnp.dot(
            onehot, emb_ref[t, :, :].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )  # [TB, H]
        gates = (
            jnp.dot(xt, wi, preferred_element_type=jnp.float32)
            + jnp.dot(h, wh, preferred_element_type=jnp.float32)
            + b
        )
        i = jax.nn.sigmoid(gates[:, :hid])
        f = jax.nn.sigmoid(gates[:, hid : 2 * hid])
        g = jnp.tanh(gates[:, 2 * hid : 3 * hid])
        o = jax.nn.sigmoid(gates[:, 3 * hid :])
        c = f * c + i * g
        h = o * jnp.tanh(c)
        if t == 0:
            v = (
                jnp.dot(h, wf_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
                + bf_ref[...].astype(jnp.float32)
            )
        elif t == t_steps - 1:
            last = (
                jnp.dot(h, wl_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
                + bl_ref[...].astype(jnp.float32)
            )
            out = jnp.sum(v * last, axis=-1)
        else:
            mid = (
                jnp.dot(h, wm_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
                + bm_ref[...].astype(jnp.float32)
            ).reshape(tb, rank, rank)
            # lane-parallel batched matvec on the VPU (R is tiny)
            v = jnp.sum(v[:, :, None] * mid, axis=1)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def decode_tile(
    idx: jax.Array,
    emb: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    w_first: jax.Array,
    b_first: jax.Array,
    w_mid: jax.Array,
    b_mid: jax.Array,
    w_last: jax.Array,
    b_last: jax.Array,
    *,
    tile_b: int = DEFAULT_TILE_B,
    interpret: bool = False,
) -> jax.Array:
    """Fused NTTD decode of one tile of folded indices.

    idx:      [B, T] int32 folded indices (T = d')
    emb:      [T, M, H] per-step embedding tables, padded to M rows
    wi, wh:   [H, 4H] LSTM gate weights; b: [4H]
    w_first:  [H, R],   b_first: [R]
    w_mid:    [H, R*R], b_mid:   [R*R]   (unused when T == 2)
    w_last:   [H, R],   b_last:  [R]
    returns   [B] in ``emb.dtype``

    B must be a multiple of ``tile_b``; ``ops.nttd_decode_tile`` pads.
    """
    bsz, t_steps = idx.shape
    _, m, hid = emb.shape
    rank = b_first.shape[0]
    if t_steps < 2:
        raise ValueError(f"decode_tile needs T >= 2 steps, got {t_steps}")
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} not a multiple of tile_b {tile_b}")
    grid = (bsz // tile_b,)
    return pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, hid=hid, rank=rank, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, t_steps), lambda i: (i, 0)),
            pl.BlockSpec((t_steps, m, hid), lambda i: (0, 0, 0)),
            pl.BlockSpec((hid, 4 * hid), lambda i: (0, 0)),
            pl.BlockSpec((hid, 4 * hid), lambda i: (0, 0)),
            pl.BlockSpec((4 * hid,), lambda i: (0,)),
            pl.BlockSpec((hid, rank), lambda i: (0, 0)),
            pl.BlockSpec((rank,), lambda i: (0,)),
            pl.BlockSpec((hid, rank * rank), lambda i: (0, 0)),
            pl.BlockSpec((rank * rank,), lambda i: (0,)),
            pl.BlockSpec((hid, rank), lambda i: (0, 0)),
            pl.BlockSpec((rank,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), emb.dtype),
        interpret=interpret,
    )(idx, emb, wi, wh, b, w_first, b_first, w_mid, b_mid, w_last, b_last)
