"""Pallas TPU kernel: fused single-layer LSTM scan.

The NTTD encoder runs an LSTM over d' ~ 8..12 steps for every sampled
entry.  On TPU the naive path costs 8 small HBM-bound matmul launches per
step; this kernel keeps (h, c) resident in VMEM across all T steps and
fuses the two gate matmuls with the elementwise gate math.  Batch is tiled
on the sublane axis; both gate matmuls ([TB,H] x [H,4H]) hit the MXU when
H >= 64 and the VPU otherwise (H is small for the codec; correctness is
identical either way).

Weights are broadcast to every grid step via constant index maps (one HBM
-> VMEM copy per core, reused across the batch tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 256


def _kernel(x_ref, wi_ref, wh_ref, b_ref, out_ref, *, t_steps: int, hid: int):
    wi = wi_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    tb = x_ref.shape[0]

    def step(t, carry):
        h, c = carry
        xt = x_ref[:, t, :].astype(jnp.float32)  # [TB, H]
        gates = (
            jnp.dot(xt, wi, preferred_element_type=jnp.float32)
            + jnp.dot(h, wh, preferred_element_type=jnp.float32)
            + b
        )
        i = jax.nn.sigmoid(gates[:, :hid])
        f = jax.nn.sigmoid(gates[:, hid : 2 * hid])
        g = jnp.tanh(gates[:, 2 * hid : 3 * hid])
        o = jax.nn.sigmoid(gates[:, 3 * hid :])
        c = f * c + i * g
        h = o * jnp.tanh(c)
        out_ref[:, t, :] = h.astype(out_ref.dtype)
        return (h, c)

    init = (jnp.zeros((tb, hid), jnp.float32), jnp.zeros((tb, hid), jnp.float32))
    jax.lax.fori_loop(0, t_steps, step, init)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def lstm_scan(
    x: jax.Array,
    wi: jax.Array,
    wh: jax.Array,
    b: jax.Array,
    *,
    tile_b: int = DEFAULT_TILE_B,
    interpret: bool = False,
) -> jax.Array:
    """x: [B, T, H], wi: [H, 4H], wh: [H, 4H], b: [4H] -> hs [B, T, H]."""
    bsz, t_steps, hid = x.shape
    if bsz % tile_b:
        raise ValueError(f"batch {bsz} not a multiple of tile_b {tile_b}")
    grid = (bsz // tile_b,)
    return pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, hid=hid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, t_steps, hid), lambda i: (i, 0, 0)),
            pl.BlockSpec((hid, 4 * hid), lambda i: (0, 0)),
            pl.BlockSpec((hid, 4 * hid), lambda i: (0, 0)),
            pl.BlockSpec((4 * hid,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b, t_steps, hid), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, t_steps, hid), x.dtype),
        interpret=interpret,
    )(x, wi, wh, b)
