from repro.optim.optimizers import (
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedules import constant, cosine, wsd

__all__ = [
    "adam",
    "adamw",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "constant",
    "cosine",
    "wsd",
]
