"""Learning-rate schedules: constant, cosine, and WSD.

WSD (warmup-stable-decay) is included because the assigned ``minicpm-2b``
architecture trains with it (arXiv:2404.06395).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, min_ratio: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return sched


def wsd(lr: float, total_steps: int, warmup: int = 0, decay_frac: float = 0.1,
        min_ratio: float = 0.01):
    """Warmup -> stable plateau -> linear decay over the last decay_frac."""
    decay_steps = max(int(total_steps * decay_frac), 1)
    decay_start = total_steps - decay_steps

    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - decay_start) / decay_steps, 0, 1)
        dec = lr * (1 - (1 - min_ratio) * frac)
        out = jnp.where(step < warmup, warm, jnp.asarray(lr, jnp.float32))
        return jnp.where(step > decay_start, dec, out)

    return sched
