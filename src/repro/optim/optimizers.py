"""Adam / AdamW from scratch (optax is not available in the container).

The optimizer is a (init, update) pair over arbitrary pytrees, mirroring
the optax GradientTransformation contract so the trainer composes hooks
(grad clipping, compression, schedules) the usual way.  All state lives in
a pytree so it shards/pjits/donates like the params.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def adamw(
    lr: float | Schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float | None = None,
) -> Optimizer:
    sched: Schedule = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamState, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        mu_hat_scale = 1.0 / (1.0 - b1**stepf)
        nu_hat_scale = 1.0 / (1.0 - b2**stepf)
        lr_t = sched(step)

        def upd(m, v, p):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def adam(lr: float | Schedule, **kw) -> Optimizer:
    return adamw(lr, weight_decay=0.0, **kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
