"""TensorCodec's own compression configs (paper SS V): R/h presets used by
the benchmarks and the codec dry-run cell."""
from repro.core.codec import CodecConfig

SMALL = CodecConfig(rank=6, hidden=12, epochs=120, batch_size=4096, lr=1e-2)
MEDIUM = CodecConfig(rank=10, hidden=18, epochs=200, batch_size=8192, lr=1e-2)
CONFIG = MEDIUM
SMOKE = SMALL
