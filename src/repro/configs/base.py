"""Model and shape configuration dataclasses (single source of truth)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                # dense | moe | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1         # every n-th layer has an MoE FFN (1 = all)
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # --- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 1        # hybrid: 1 attention sublayer per n sublayers
    # --- misc -------------------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    param_dtype: str = "float32"    # master weights (train); bf16 for serve
    compute_dtype: str = "bfloat16"
    remat: str = "dots"        # none | dots | full (scan-block remat policy)
    input_kind: str = "tokens"  # tokens | embeddings (vlm/audio stub frontends)
    scan_layers: bool = True
    attn_impl: str = "ref"     # kernels.ops impl selector
    # annotate why long_500k is skipped (full-attention archs)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def block_size(self) -> int:
        """Layers per scan block (hybrid: attn_every; moe-interleave: moe_every)."""
        if self.family == "hybrid":
            return self.attn_every
        if self.family == "moe" and self.moe_every > 1:
            return self.moe_every
        return 1

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_size == 0
        return self.n_layers // self.block_size

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND roofline math)."""
        from repro.models import model as model_lib

        return model_lib.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import model as model_lib

        return model_lib.param_count(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
