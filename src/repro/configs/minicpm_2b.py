"""minicpm-2b [dense]: 40L d2304 36H MHA(kv=36) ff5760 v122753.
WSD schedule, tied embeddings, llama-like arch [arXiv:2404.06395; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64, tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="minicpm-2b-smoke", family="dense",
    n_layers=3, d_model=48, n_heads=6, n_kv_heads=6,
    d_ff=120, vocab=256, head_dim=8, tie_embeddings=True, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
