"""qwen1.5-4b [dense]: 40L d2560 20H MHA(kv=20) ff6912 v151936.
QKV bias [hf:Qwen/Qwen1.5-4B].  The 152k vocab is the NTTD-embedding
compression showcase (see repro.models.nttd_embed)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936, head_dim=128, qkv_bias=True,
)

SMOKE = ModelConfig(
    arch_id="qwen1.5-4b-smoke", family="dense",
    n_layers=3, d_model=48, n_heads=6, n_kv_heads=6,
    d_ff=128, vocab=512, head_dim=8, qkv_bias=True, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
