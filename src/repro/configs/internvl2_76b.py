"""internvl2-76b [vlm]: 80L d8192 64H GQA-kv8 ff28672 v128256.
InternViT frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings (input_kind='embeddings' for prefill).
Backbone = llama-3-70b-style dense decoder [arXiv:2404.16821; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128, input_kind="embeddings",
)

SMOKE = ModelConfig(
    arch_id="internvl2-76b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256, head_dim=8, input_kind="embeddings", remat="none",
    param_dtype="float32", compute_dtype="float32",
)
