"""deepseek-coder-33b [dense]: 62L d7168 56H GQA-kv8 ff19200 v32256.
Llama-arch (RMSNorm, RoPE, SwiGLU, GQA) [arXiv:2401.14196; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, head_dim=128,
)

SMOKE = ModelConfig(
    arch_id="deepseek-coder-33b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256, head_dim=8, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
