"""musicgen-medium [audio]: 48L d1536 24H MHA(kv=24) ff6144 v2048.
Decoder-only over EnCodec tokens; the EnCodec frontend is a STUB per
assignment (input_specs() provides frame embeddings) [arXiv:2306.05284; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium", family="dense",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048, head_dim=64, input_kind="embeddings",
)

SMOKE = ModelConfig(
    arch_id="musicgen-medium-smoke", family="dense",
    n_layers=3, d_model=48, n_heads=6, n_kv_heads=6,
    d_ff=96, vocab=128, head_dim=8, input_kind="embeddings", remat="none",
    param_dtype="float32", compute_dtype="float32",
)
