"""mamba2-1.3b [ssm]: 48L d2048 attn-free, ssm_state=128, SSD
[arXiv:2405.21060; unverified].  Sub-quadratic -> runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    subquadratic=True,
)

SMOKE = ModelConfig(
    arch_id="mamba2-1.3b-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=256, head_dim=0,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
    ssm_chunk=16, subquadratic=True, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
