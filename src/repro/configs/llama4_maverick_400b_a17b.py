"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H GQA-kv8 ff8192 v202048,
128 experts top-1, alternating dense/MoE layers (early fusion backbone)
[hf:meta-llama/Llama-4-Maverick; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    moe_experts=128, moe_top_k=1, moe_every=2,
)

SMOKE = ModelConfig(
    arch_id="llama4-maverick-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab=256, head_dim=8,
    moe_experts=8, moe_top_k=1, moe_every=2, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
