"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H GQA-kv8 ff24576 v65536,
MoE 16e top-2.  Mamba:attn 7:1 interleave, MoE every other layer
[arXiv:2403.19887; hf].  Sub-quadratic -> runs long_500k."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, head_dim=128,
    moe_experts=16, moe_top_k=2, moe_every=2,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=8,
    attn_every=8, subquadratic=True,
)

SMOKE = ModelConfig(
    arch_id="jamba-1.5-large-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=8,
    moe_experts=4, moe_top_k=2, moe_every=2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=2,
    attn_every=8, ssm_chunk=16, subquadratic=True, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
