"""Architecture registry: ``get(arch_id)`` returns the full-size ModelConfig,
``get_smoke(arch_id)`` a reduced same-family config for CPU tests."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

_ARCH_MODULES = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "minicpm-2b": "minicpm_2b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen1.5-4b": "qwen1_5_4b",
    "grok-1-314b": "grok_1_314b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-1.3b": "mamba2_1_3b",
    "internvl2-76b": "internvl2_76b",
    "musicgen-medium": "musicgen_medium",
    "tensorcodec-paper": "tensorcodec_paper",
}

ARCH_IDS = [a for a in _ARCH_MODULES if a != "tensorcodec-paper"]


def get(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.SMOKE


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get", "get_smoke"]
