"""starcoder2-15b [dense]: 40L d6144 48H GQA-kv4 ff24576 v49152.
GQA + RoPE [arXiv:2402.19173; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, head_dim=128,
)

SMOKE = ModelConfig(
    arch_id="starcoder2-15b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=256, head_dim=8, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
