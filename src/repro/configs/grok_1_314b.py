"""grok-1-314b [moe]: 64L d6144 48H GQA-kv8 ff32768 v131072, 8 experts top-2.
Every layer MoE [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, head_dim=128,
    moe_experts=8, moe_top_k=2, moe_every=1,
)

SMOKE = ModelConfig(
    arch_id="grok-1-314b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=8,
    moe_experts=4, moe_top_k=2, moe_every=1, remat="none",
    param_dtype="float32", compute_dtype="float32",
)
