"""Fault-tolerant sharded checkpointing.

Design (DESIGN.md §5):
  * atomic: write to ``<dir>/tmp.<step>`` then rename to ``<dir>/step_<n>``
  * async: the serialize+write runs on a background thread; ``wait()``
    joins before the next save (bounded queue of 1)
  * elastic: the manifest stores logical metadata only (paths, shapes,
    dtypes); ``restore`` device_puts each leaf with the CURRENT mesh's
    sharding, so a checkpoint written on mesh A restores onto mesh B
  * NTTD-compressed (optional): large >=2D leaves are compressed with the
    paper's codec at save time (lossy, fitness-gated) — the TensorCodec
    integration for checkpoint shipping (see repro.compress)

On a real multi-host pod each host writes only the shards it owns
(``process_index`` prefix); in this single-process container that
degenerates to one writer, but the layout is the multi-host one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def _unflatten_into(template, values: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap vs device compute)
        host = [(k, np.asarray(v)) for k, v in _flatten(tree)]
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: list, extra: dict) -> None:
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        final = os.path.join(self.directory, f"step_{step:010d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "extra": extra, "leaves": {}}
        for key, arr in host:
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"))

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, template, shardings=None):
        """Load a checkpoint; reshard onto the current mesh (elastic).

        ``template`` supplies the tree structure; ``shardings`` (optional,
        same structure) the target shardings — different mesh than the one
        that wrote the checkpoint is fine.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        shard_flat = dict(_flatten(shardings)) if shardings is not None else {}
        values = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if key in shard_flat and shard_flat[key] is not None:
                values[key] = jax.device_put(arr, shard_flat[key])
            else:
                values[key] = jax.numpy.asarray(arr)
        tree = _unflatten_into(template, values)
        return tree, manifest


def auto_resume(ckpt: Checkpointer, template, shardings=None):
    """Resume from the latest checkpoint if one exists (crash recovery)."""
    step = ckpt.latest_step()
    if step is None:
        return None, 0
    tree, manifest = ckpt.restore(step, template, shardings)
    return tree, manifest["step"]
