"""Step factories: train / prefill / decode, with sharding trees for pjit.

These are shared by the real trainer (launch/train.py), the serving engine
(repro.serve), and the multi-pod dry-run (launch/dryrun.py) — the dry-run
lowers exactly the program production would run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding
from repro.models import layers, model
from repro.optim import optimizers
from repro.optim.optimizers import AdamState


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt, grad_transform=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_transform(grads) -> grads`` hooks gradient compression (see
    repro.dist.grad_compress) between backprop and the optimizer.
    """

    compute_dt = layers.dtype_of(cfg.compute_dtype)
    param_dt = layers.dtype_of(cfg.param_dtype)

    def train_step(params, opt_state, batch):
        def loss_with_cast(p, batch):
            if param_dt != compute_dt:
                # cast the SHARDED master weights once; every downstream
                # FSDP all-gather then moves bf16, not f32 (2x less ICI
                # traffic and 2x smaller gathered live set)
                p = jax.tree.map(lambda w: w.astype(compute_dt), p)
            return model.loss_fn(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_with_cast, has_aux=True)(
            params, batch
        )
        if grad_transform is not None:
            grads = grad_transform(grads)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optimizers.apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = optimizers.global_norm(grads)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, cache, batch):
        logits, cache = model.prefill(
            params,
            cfg,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache=cache,
        )
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, batch, cache_len):
        logits, cache = model.decode_step(
            params,
            cfg,
            token=batch.get("tokens"),
            embeds=batch.get("embeds"),
            cache=cache,
            cache_len=cache_len,
        )
        return logits, cache

    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract model inputs for one (arch x shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    ct = layers.dtype_of(cfg.compute_dtype)
    if shape.kind == "train":
        batch: dict[str, Any] = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.input_kind == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), ct)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.input_kind == "embeddings":
            return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), ct)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    # decode: one new token against a cache of length s
    if cfg.input_kind == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model), ct)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def abstract_opt_state(cfg: ModelConfig) -> AdamState:
    ab = model.abstract_params(cfg)
    f32 = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t
    )
    return AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32(ab), nu=f32(ab)
    )


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------
def effective_rules(
    mesh: Mesh,
    shape: ShapeConfig,
    base: dict | None = None,
    cfg: ModelConfig | None = None,
) -> dict:
    """Adjust the rules table to the cell:
    * global batch cannot fill the DP axes (long-context decode) ->
      replicate batch, spread the KV length over 'data' (SP flash-decode);
    * head count cannot take the TP axis -> context-parallel attention
      (q/scores sharded on 'seq_attn' -> 'model')."""
    rules = dict(base or sharding.BASE_RULES)
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    if shape.global_batch % dp != 0:
        rules["batch"] = None
        rules["kv_seq"] = "data"
    elif shape.kind in ("prefill", "decode") and "model" in mesh.axis_names:
        # flash-decode sharding: no assigned arch has KV heads divisible by
        # the 16-way TP axis, so the cache shards its LENGTH over 'model'
        # and XLA partitions the softmax reduction (partial-max/denominator
        # combine).  Without this a 32k x 128-seq cache replicates ~33GB/dev.
        rules["kv_seq"] = "model"
    if (
        cfg is not None
        and cfg.n_heads
        and "model" in mesh.axis_names
        and cfg.n_heads % mesh.shape["model"] != 0
    ):
        rules["seq_attn"] = "model"
        if shape.kind == "train":
            # Megatron-style sequence parallelism on the residual stream:
            # required to fit the activation working set when attention
            # cannot be head-sharded (see EXPERIMENTS.md §Dry-run)
            rules["seq"] = "model"
    return rules


def batch_shardings(mesh: Mesh, cfg: ModelConfig, batch_spec: dict, rules: dict):
    def spec_for(name, leaf):
        if name == "embeds":
            logical = ("batch", "seq", "act_embed")
        else:
            logical = ("batch", "seq")
        return NamedSharding(mesh, sharding.logical_pspec(logical, rules, mesh))

    return {k: spec_for(k, v) for k, v in batch_spec.items()}


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def param_shardings(mesh: Mesh, cfg: ModelConfig, rules: dict):
    return sharding.tree_shardings(mesh, model.param_specs(cfg), rules)


def opt_shardings(mesh: Mesh, cfg: ModelConfig, rules: dict):
    ps = param_shardings(mesh, cfg, rules)
    return AdamState(step=replicated(mesh), mu=ps, nu=ps)


def cache_shardings(
    mesh: Mesh, cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool, rules: dict
):
    return sharding.tree_shardings(
        mesh, model.cache_specs(cfg, batch, max_len, long_ctx), rules
    )
