"""Compressed-tensor serving: batched ``decode_at`` over codec payloads.

The serve layer's first compressed-tensor endpoint.  A service instance
hosts any number of named :class:`repro.codecs.Encoded` payloads (loaded
from container bytes or handed over in memory) and answers entry queries
at ORIGINAL indices without ever densifying the tensors it serves
(except SZ-lite, which is a stream codec and caches one reconstruction).

Two query paths:

- ``decode_at(name, indices)`` — direct, chunked at ``max_batch`` so a
  multi-million-entry request never materializes one giant device batch;
- ``submit(name, indices) -> ticket`` + ``flush()`` — request coalescing:
  queued requests are grouped per payload and decoded in ONE batched
  ``decode_at`` call each, then split back per ticket.  This is the
  serve-side analogue of continuous batching for entry lookups.

Malformed requests (wrong index width, out-of-range indices, unknown
payload) are rejected at ``submit`` time so they can never poison a
coalesced batch; if a decode still fails at flush, only that payload's
tickets land in ``failed`` — every other queued request completes.

Per-payload state is kept warm across requests: the Encoded object stays
loaded, so NTTD's cached inverse permutations
(``CompressedTensor.inv_pi``) are computed once at first decode and
reused for every subsequent query.

    svc = CodecService()
    svc.load("embed", blob)              # container bytes, any codec id
    t0 = svc.submit("embed", idx_a)
    t1 = svc.submit("embed", idx_b)
    out = svc.flush()                    # {t0: values_a, t1: values_b}
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import codecs


@dataclasses.dataclass
class PayloadInfo:
    codec: str
    payload_bytes: int
    requests: int = 0
    entries_decoded: int = 0
    decode_calls: int = 0


class CodecService:
    def __init__(self, max_batch: int = 65536):
        self.max_batch = max_batch
        self._payloads: dict[str, codecs.Encoded] = {}
        self._info: dict[str, PayloadInfo] = {}
        self._queue: list[tuple[int, str, np.ndarray]] = []
        self._next_ticket = 0
        #: tickets whose payload group raised during the LAST flush,
        #: ticket -> error (reset at the start of each flush)
        self.failed: dict[int, Exception] = {}

    # ------------------------------------------------------------------ load
    def load(self, name: str, payload: bytes | codecs.Encoded) -> PayloadInfo:
        """Register a payload under ``name``; bytes go through the container
        loader so the codec-id header picks the decoder."""
        enc = codecs.load_bytes(payload) if isinstance(payload, bytes) else payload
        self._payloads[name] = enc
        self._info[name] = PayloadInfo(enc.codec_name, enc.payload_bytes())
        return self._info[name]

    def unload(self, name: str) -> None:
        self._payloads.pop(name, None)
        self._info.pop(name, None)

    def payloads(self) -> list[str]:
        return sorted(self._payloads)

    def info(self, name: str) -> PayloadInfo:
        return self._info[name]

    def _get(self, name: str) -> codecs.Encoded:
        try:
            return self._payloads[name]
        except KeyError:
            raise KeyError(
                f"no payload {name!r}; loaded: {', '.join(self.payloads())}"
            ) from None

    def _validate(self, name: str, enc: codecs.Encoded,
                  indices: np.ndarray) -> np.ndarray:
        idx = np.asarray(indices)
        shape = enc.shape
        if idx.ndim != 2 or idx.shape[1] != len(shape):
            raise ValueError(
                f"indices for {name!r} must be [B, {len(shape)}], got {idx.shape}"
            )
        if not np.issubdtype(idx.dtype, np.integer):
            raise ValueError(f"indices must be integral, got {idx.dtype}")
        if idx.size and ((idx < 0).any() or (idx >= np.asarray(shape)).any()):
            raise ValueError(f"indices out of range for shape {shape}")
        return idx

    # ---------------------------------------------------------------- direct
    def decode_at(self, name: str, indices: np.ndarray) -> np.ndarray:
        """Chunked decode so arbitrarily large requests stream through
        fixed-size batches.  Indices are validated up front; stats count
        only work that actually decoded."""
        enc = self._get(name)
        idx = self._validate(name, enc, indices)
        if idx.shape[0] <= self.max_batch:
            out, calls = np.asarray(enc.decode_at(idx)), 1
        else:
            parts = [
                np.asarray(enc.decode_at(idx[s : s + self.max_batch]))
                for s in range(0, idx.shape[0], self.max_batch)
            ]
            out, calls = np.concatenate(parts), len(parts)
        info = self._info[name]
        info.requests += 1
        info.entries_decoded += idx.shape[0]
        info.decode_calls += calls
        return out

    # --------------------------------------------------------------- batched
    def submit(self, name: str, indices: np.ndarray) -> int:
        """Queue a request; returns a ticket resolved by the next flush().

        Validates eagerly — a malformed request raises HERE and never
        enters the queue, so it cannot sink the coalesced batch."""
        idx = self._validate(name, self._get(name), indices)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, name, idx))
        return ticket

    def flush(self) -> dict[int, np.ndarray]:
        """Decode all queued requests, one coalesced batch per payload.

        A payload group that still fails is isolated: its tickets go to
        ``self.failed`` (ticket -> exception, reset each flush) and the
        other groups' results are returned normally."""
        self.failed = {}
        by_payload: dict[str, list[tuple[int, np.ndarray]]] = {}
        for ticket, name, idx in self._queue:
            by_payload.setdefault(name, []).append((ticket, idx))
        self._queue.clear()
        results: dict[int, np.ndarray] = {}
        for name, reqs in by_payload.items():
            merged = np.concatenate([idx for _, idx in reqs], axis=0)
            try:
                values = self.decode_at(name, merged)
            except Exception as e:  # noqa: BLE001 — isolate the bad group
                for ticket, _ in reqs:
                    self.failed[ticket] = e
                continue
            self._info[name].requests += len(reqs) - 1  # decode_at counted one
            off = 0
            for ticket, idx in reqs:
                results[ticket] = values[off : off + idx.shape[0]]
                off += idx.shape[0]
        return results
