"""Compressed-tensor serving: batched ``decode_at`` over codec payloads.

A service instance hosts any number of named :class:`repro.codecs.Encoded`
payloads and answers entry queries at ORIGINAL indices without ever
densifying the tensors it serves (except SZ-lite, which is a stream codec
and caches one reconstruction — bounded, see below).

Three load paths:

- ``load(name, blob_or_encoded)`` — resident payload, as before;
- ``load_stream(name, path)`` — LAZY: the container-v3 file is mmapped
  and only its header + footer chunk index are parsed; chunk bytes are
  materialized on first decode and can be evicted again under the cache
  budget, so an instance can host more payload bytes than RAM;
- ``load_stream(name, path, tile_entries=T)`` — additionally routes
  queries through a decode-tile cache: the flat index space is cut into
  T-entry tiles, each decoded once and reused across overlapping queries
  (hit/miss counters per payload, byte-budgeted with everything else).

``load_stream`` also accepts v4 DELTA containers (versioned payloads
written by ``repro.temporal.VersionedStore``): queries take a
``version=`` argument (default: latest), the service resolves the
keyframe→delta chain from the file's version index, and every answer is
the float64 sum of the chain components' decodes — the same convention
as ``repro.temporal.ChainEncoded``, so eager and lazy reads agree
bit-for-bit.  Per-version component payloads live in the LRU as
``("venc", name, v)`` entries; decode tiles are keyed by COMPOSITE tile
id ``version * n_tiles + tile``, so a keyframe's tiles are shared by
every version that chains through it instead of being re-decoded per
version.

``cache_bytes`` is one LRU byte budget over all droppable decode state:
materialized lazy payload bodies, SZ-lite dense reconstructions (via the
``Encoded.cache_nbytes``/``drop_caches`` hooks), and decode tiles.
Accounting happens after each decode, so the payload answering the
current query is never yanked mid-decode; ``cache_stats`` totals
hits/misses/evictions/resident bytes across the instance.

Two query paths, unchanged from the first version of this service:

- ``decode_at(name, indices)`` — direct, chunked at ``max_batch``;
- ``submit(name, indices) -> ticket`` + ``flush()`` — request coalescing:
  queued requests are grouped per payload and decoded in ONE batched
  ``decode_at`` call each, then split back per ticket.

Malformed requests (wrong index width, out-of-range indices, unknown
payload) are rejected at ``submit`` time so they can never poison a
coalesced batch; if a decode still fails at flush, only that payload's
tickets land in ``failed`` — every other queued request completes.

ONLINE FITNESS CANARIES (``canary_fraction > 0``): containers whose
footer carries a ``TCDQ`` held-out block (ground-truth original-tensor
entries recorded at fit time) are spot-checked on the serve path — a
deterministic, seeded fraction of ``decode_at`` calls re-decodes a
bounded sample of the held-out indices and scores fitness
``1 - ||truth - approx|| / ||truth||`` (the paper's §4.2 metric), feeding
a per-payload rolling gauge in ``self.metrics`` and, below
``canary_min_fitness``, a ``quality_breach`` event naming the chunk that
routes the worst entry.  Served ANSWERS are bit-identical with canaries
on or off — the check is a side decode through the same batched funnel,
never a rewrite of the response; only stats differ.  Payloads without a
``TCDQ`` block (all legacy files) and versioned payloads skip canaries
cleanly.

    svc = CodecService(cache_bytes=1 << 28)
    svc.load_stream("embed", "embed.tcdc")      # mmap + chunk index only
    svc.decode_at("embed", idx)                 # materializes on demand
"""
from __future__ import annotations

import collections
import concurrent.futures
import contextlib
import dataclasses
import zlib
from typing import Callable

import numpy as np

from repro import codecs, obs
from repro.codecs import container
from repro.codecs.indexing import flat_to_multi, multi_to_flat, validate_indices
from repro.temporal.delta import resolve_chain


@dataclasses.dataclass
class PayloadInfo:
    codec: str
    payload_bytes: int
    requests: int = 0
    entries_decoded: int = 0
    decode_calls: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    #: number of versions for a v4 delta payload; None = single tensor
    n_versions: int | None = None


@dataclasses.dataclass
class PayloadCacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    resident_bytes: int = 0


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    resident_bytes: int = 0
    #: same four counters broken down by payload name — the fleet metrics
    #: roll-up consumes this to show where an instance's budget goes
    per_payload: dict[str, PayloadCacheStats] = dataclasses.field(
        default_factory=dict
    )

    def for_payload(self, name: str) -> PayloadCacheStats:
        return self.per_payload.setdefault(name, PayloadCacheStats())

    def hit(self, name: str) -> None:
        self.hits += 1
        self.for_payload(name).hits += 1

    def miss(self, name: str) -> None:
        self.misses += 1
        self.for_payload(name).misses += 1

    def as_dict(self) -> dict:
        """JSON-able snapshot — the shape the fleet transport layer ships
        across process boundaries (``Transport.stats``) and the metrics
        roll-up consumes, so remote and in-process instances report
        identically."""
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "resident_bytes": self.resident_bytes,
            "per_payload": {
                name: {
                    "hits": p.hits,
                    "misses": p.misses,
                    "evictions": p.evictions,
                    "resident_bytes": p.resident_bytes,
                }
                for name, p in self.per_payload.items()
            },
        }


class NotOwnedError(KeyError):
    """Raised when a query lands on an instance whose ownership filter
    excludes the whole payload — the fleet frontend routes so this never
    fires after a drain barrier; seeing it means a routing bug, not a
    corrupt payload."""


class ChunkCorruptError(ValueError):
    """A chunk's bytes failed their CRC at materialization time.

    The chunk is QUARANTINED on this instance (marked for repair, rides
    ``stats()['quarantine']``) instead of poisoning the payload forever:
    the error fails only the queries that needed the body NOW, the fleet
    frontend re-routes them to a replica that still holds a materialized
    body, and a later :meth:`CodecService.refresh` — issued by the repair
    controller once the file is fixed — clears the quarantine.  Carries
    the repair target so controllers need not parse the message."""

    def __init__(self, payload: str, chunk: int, path: str, reason: str):
        super().__init__(reason)
        self.payload = payload
        self.chunk = chunk
        self.path = path


@dataclasses.dataclass
class Ownership:
    """An instance's shard of one payload, installed by the fleet router.

    ``chunk_ids`` filters the chunk-materialization path: an instance
    owning NO chunk of a payload refuses to materialize it (so payload
    bodies only become resident on their owners).  ``tile_ids`` filters
    the decode-tile cache: unowned tiles are still decodable (decode-
    through, keeps mid-rebalance queries correct) but are never cached,
    so each instance's resident tile bytes stay its shard of the whole.
    Both are precomputed sets (the router enumerates the ring once per
    ownership epoch), so the hot decode path pays set lookups, not ring
    hashes.
    """

    chunk_ids: frozenset[int] | None = None  # None = owns every chunk
    tile_ids: frozenset[int] | None = None  # None = owns every tile

    def owns_chunk(self, i: int) -> bool:
        return self.chunk_ids is None or i in self.chunk_ids

    def owns_tile(self, tid: int) -> bool:
        return self.tile_ids is None or tid in self.tile_ids

    def owns_payload(self) -> bool:
        """May this instance materialize the payload body at all?  True
        when it owns any chunk, or serves a non-empty tile shard (tile
        decode needs the body even when every chunk hashed elsewhere)."""
        if self.chunk_ids is None or self.chunk_ids:
            return True
        return bool(self.tile_ids)


@dataclasses.dataclass
class _CanaryState:
    """Per-payload canary bookkeeping: check/breach counts plus a bounded
    window of recent fitness scores for the rolling gauge."""

    checks: int = 0
    breaches: int = 0
    last_fitness: float | None = None
    window: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=32)
    )
    #: detail of the most recent breach (fitness, worst_index, chunk,
    #: entry range) — the repair controller's polling view of the same
    #: facts the quality_breach event carries; None until a breach
    last_breach: dict | None = None

    def rolling_fitness(self) -> float | None:
        return sum(self.window) / len(self.window) if self.window else None

    def as_dict(self) -> dict:
        return {
            "checks": self.checks,
            "breaches": self.breaches,
            "last_fitness": self.last_fitness,
            "rolling_fitness": self.rolling_fitness(),
            "last_breach": self.last_breach,
        }


@dataclasses.dataclass
class _CacheEntry:
    nbytes: int
    value: np.ndarray | None  # decode tiles live here; payloads evict via fn
    on_evict: Callable[[], None] | None = None


@dataclasses.dataclass
class _StreamPayload:
    path: str
    codec: str
    chunks: list[container.ChunkEntry]
    view: memoryview
    tile_entries: int | None
    body_nbytes: int
    enc: codecs.Encoded | None = None
    ownership: Ownership | None = None
    #: v4 version index; None = plain single-tensor payload
    versions: list[container.VersionEntry] | None = None
    #: per-version component payloads (versioned payloads only), each an
    #: evictable ("venc", name, v) LRU entry
    vencs: dict[int, codecs.Encoded] = dataclasses.field(default_factory=dict)
    #: geometry learned from the first materialized component
    shape: tuple[int, ...] | None = None
    n_tiles: int | None = None
    #: held-out ground truth from the container's TCDQ block; None for
    #: legacy files — those simply never canary
    heldout: container.HeldoutEntries | None = None
    #: read-repair overlays from the container's TCDP block (empty for
    #: unpatched files); the base payload is ``chunks[:n_base]``
    patches: list[container.PatchEntry] = dataclasses.field(default_factory=list)
    #: number of BASE (non-patch) chunks; None = every chunk is base
    n_base: int | None = None
    #: chunk id -> error message for chunks whose bytes failed their CRC —
    #: set once at first failed read, cleared only by refresh(); rides
    #: stats()["quarantine"] so the repair controller can find it
    quarantine: dict[int, str] = dataclasses.field(default_factory=dict)
    #: in-flight background warm (prefetch): joined by _get before use
    warm: concurrent.futures.Future | None = None
    #: True after a background warm materialized the body: the NEXT counted
    #: access is the one the warm's miss already paid for, so it must not
    #: also count a hit (keeps counters identical to the synchronous path,
    #: where materialization absorbs the first access)
    warm_credit: bool = False


def _n_base(sp: _StreamPayload) -> int:
    return sp.n_base if sp.n_base is not None else len(sp.chunks)


def _hash_noise(flat: np.ndarray, sigma: float, seed: int) -> np.ndarray:
    """Deterministic per-entry pseudo-noise in ``[-sigma, sigma)`` — a pure
    function of (flat index, seed), so every replica injected with the same
    spec serves the SAME degraded values regardless of batch composition."""
    t = np.sin(flat.astype(np.float64) * 12.9898 + seed * 78.233) * 43758.5453
    return (t - np.floor(t) - 0.5) * (2.0 * sigma)


class _NoisyEncoded:
    """DEBUG-ONLY decode-side fault (``inject_fault`` kind
    ``fitness_noise``): wraps a materialized payload so served values
    inside one flat entry range pick up deterministic seeded noise.  Every
    decode path — direct, tiled, coalesced, and the canary's side decode —
    funnels through ``decode_at``, so the fitness canary observes exactly
    the degradation clients do.  The file and the payload bytes are
    untouched: ``to_bytes`` delegates to the clean inner payload."""

    def __init__(self, inner, entry_start: int, entry_stop: int,
                 sigma: float, seed: int = 0):
        self.inner = inner
        self.entry_start = int(entry_start)
        self.entry_stop = int(entry_stop)
        self.sigma = float(sigma)
        self.seed = int(seed)

    @property
    def shape(self):
        return self.inner.shape

    @property
    def codec_name(self) -> str:
        return self.inner.codec_name

    def payload_bytes(self) -> int:
        return self.inner.payload_bytes()

    def cache_nbytes(self) -> int:
        return self.inner.cache_nbytes()

    def drop_caches(self) -> None:
        self.inner.drop_caches()

    def to_bytes(self) -> bytes:
        return self.inner.to_bytes()

    def decode_at(self, indices: np.ndarray) -> np.ndarray:
        vals = np.asarray(self.inner.decode_at(indices))
        idx = np.asarray(indices)
        if idx.shape[0] == 0:
            return vals
        shape = tuple(int(s) for s in self.shape)
        flat = np.ravel_multi_index(tuple(idx.T), shape)
        mask = (flat >= self.entry_start) & (flat < self.entry_stop)
        if not mask.any():
            return vals
        out = np.array(vals, dtype=np.float64)
        out[mask] += _hash_noise(flat[mask], self.sigma, self.seed)
        return out

    def to_dense(self) -> np.ndarray:
        x = np.array(self.inner.to_dense(), dtype=np.float64)
        flat = np.arange(self.entry_start, self.entry_stop, dtype=np.int64)
        x.reshape(-1)[flat] += _hash_noise(flat, self.sigma, self.seed)
        return x


class CodecService:
    def __init__(
        self,
        max_batch: int = 65536,
        cache_bytes: int | None = None,
        prefetch: bool = False,
        canary_fraction: float = 0.0,
        canary_seed: int = 0,
        canary_min_fitness: float | None = None,
        canary_max_entries: int = 256,
    ):
        self.max_batch = max_batch
        #: fraction of decode_at calls (per payload, deterministic in the
        #: call sequence) that run an online fitness canary; 0 = off
        if not 0.0 <= canary_fraction <= 1.0:
            raise ValueError(
                f"canary_fraction must be in [0, 1], got {canary_fraction}"
            )
        self.canary_fraction = float(canary_fraction)
        self.canary_seed = int(canary_seed)
        self.canary_min_fitness = canary_min_fitness
        self.canary_max_entries = int(canary_max_entries)
        #: per-payload canary call counter (sampling position) and state
        self._canary_calls: dict[str, int] = {}
        self._canary: dict[str, _CanaryState] = {}
        #: instrument registry (canary gauges today; service-local so two
        #: services in one process never share a gauge)
        self.metrics = obs.MetricsRegistry()
        #: byte budget for droppable decode state; None = unbounded (legacy)
        self.cache_bytes = cache_bytes
        #: overlap I/O with compute on a single background thread:
        #: load_stream pre-warms payload bodies (mmap page-in + CRC +
        #: parse) ahead of the query stream, chunk reads run ahead of the
        #: joining copy, and tile k+1's index block is built while tile k
        #: decodes.  Answers and cache counters are bit-identical with
        #: prefetching off — the pipeline only reorders WHEN input-side
        #: work happens, never what is decoded or how it is counted.
        self.prefetch = prefetch
        self._prefetch_pool: concurrent.futures.ThreadPoolExecutor | None = None
        self._payloads: dict[str, codecs.Encoded] = {}
        self._streams: dict[str, _StreamPayload] = {}
        self._info: dict[str, PayloadInfo] = {}
        self._cache: collections.OrderedDict[tuple, _CacheEntry] = (
            collections.OrderedDict()
        )
        self._enc_counters_seen: dict[str, tuple[int, int]] = {}
        self.cache_stats = CacheStats()
        #: per-payload DEBUG faults installed by inject_fault(); cleared by
        #: refresh().  {"corrupt_chunks": set[int], "noise": tuple | None}
        self._faults: dict[str, dict] = {}
        self._queue: list[tuple[int, str, np.ndarray, int | None]] = []
        self._next_ticket = 0
        #: tickets whose payload group raised during the LAST flush,
        #: ticket -> error (reset at the start of each flush)
        self.failed: dict[int, Exception] = {}

    # ------------------------------------------------------------------ load
    def load(self, name: str, payload: bytes | codecs.Encoded) -> PayloadInfo:
        """Register a resident payload under ``name``; bytes go through the
        container loader so the codec-id header picks the decoder."""
        enc = codecs.load_bytes(payload) if isinstance(payload, bytes) else payload
        self._drop_named_cache_entries(name)
        self._streams.pop(name, None)
        self._enc_counters_seen.pop(name, None)
        self._payloads[name] = enc
        self._info[name] = PayloadInfo(enc.codec_name, enc.payload_bytes())
        return self._info[name]

    def load_stream(
        self, name: str, path: str, *, tile_entries: int | None = None
    ) -> PayloadInfo:
        """Register a container v3/v4 file lazily: mmap it, parse only the
        header and footer.  Payload bodies are materialized at first
        decode and are evictable under ``cache_bytes`` thereafter.  With
        ``tile_entries``, queries go through the decode-tile cache.  v4
        delta files register as VERSIONED payloads, queried with
        ``decode_at(..., version=)``."""
        oc = container.open_container(path)
        codec_name, chunks, view = oc.codec, oc.chunks, oc.view
        try:  # reject unknown codec ids at LOAD time, exactly like load()
            codecs.get_codec(codec_name)
        except KeyError:
            view.release()
            raise ValueError(
                f"unknown codec id {codec_name!r} in container {path}"
            ) from None
        self._drop_named_cache_entries(name)
        self._enc_counters_seen.pop(name, None)
        self._payloads.pop(name, None)
        body_nbytes = sum(c.length for c in chunks)
        sp = _StreamPayload(
            path, codec_name, chunks, view, tile_entries, body_nbytes,
            versions=oc.versions, heldout=oc.heldout,
            patches=list(oc.patches), n_base=oc.n_base,
        )
        self._streams[name] = sp
        self._info[name] = PayloadInfo(
            codec_name, body_nbytes,
            n_versions=len(oc.versions) if oc.versions is not None else None,
        )
        pool = self._pool()
        if pool is not None and sp.versions is None:
            # warm the payload ahead of the query stream: chunk page-in,
            # CRC, and body parse run on the background thread while the
            # caller keeps loading/serving other payloads.  _get joins the
            # future before first use, so answers and the materialization
            # miss count are identical with prefetching off.
            sp.warm = pool.submit(self._warm_stream, name, sp)
        return self._info[name]

    def unload(self, name: str) -> None:
        self._drop_named_cache_entries(name)
        self._enc_counters_seen.pop(name, None)
        self._payloads.pop(name, None)
        sp = self._streams.pop(name, None)
        if sp is not None:
            sp.view.release()
        self._info.pop(name, None)

    def payloads(self) -> list[str]:
        return sorted(set(self._payloads) | set(self._streams))

    def info(self, name: str) -> PayloadInfo:
        return self._info[name]

    def shape_of(self, name: str) -> tuple[int, ...]:
        """Original-tensor shape of a payload.  Lazy payloads are
        materialized to read it (the fleet loader calls this exactly once,
        on the chunk-0 primary owner — an instance that keeps the body);
        the materialized body joins the LRU ledger just like a decode's
        would, so it stays accounted and evictable."""
        sp = self._streams.get(name)
        if sp is not None and sp.versions is not None:
            return self._ensure_version_geometry(name, sp)
        enc = self._get(name, count=False)
        self._account_decode_state(name, enc)
        return tuple(int(s) for s in enc.shape)

    def _get(self, name: str, count: bool = True) -> codecs.Encoded:
        """Resolve a payload, materializing lazy ones.  ``count=False``
        (validation-only paths like submit) skips the hit counter so one
        logical decode is not double-counted; a materialization is real
        work and is always counted as a miss."""
        if name in self._payloads:
            return self._payloads[name]
        sp = self._streams.get(name)
        if sp is None:
            raise KeyError(
                f"no payload {name!r}; loaded: {', '.join(self.payloads())}"
            )
        if sp.versions is not None:
            raise ValueError(
                f"payload {name!r} is versioned; query it through "
                "decode_at/submit (version=) instead"
            )
        if sp.enc is None and sp.warm is not None:
            warm, sp.warm = sp.warm, None
            with obs.span("prefetch_wait", payload=name):
                warm.result()  # propagate a failed background warm verbatim
        if sp.enc is None:
            if sp.ownership is not None and not sp.ownership.owns_payload():
                raise NotOwnedError(
                    f"payload {name!r} is not owned by this instance "
                    "(ownership filter excludes every chunk)"
                )
            self._materialize(name, sp)
        elif count:
            if sp.warm_credit:
                sp.warm_credit = False  # background warm's miss covered this
            else:
                self.cache_stats.hit(name)
                self._info[name].cache_hits += 1
        return sp.enc

    def _read_chunk_checked(
        self, name: str, sp: _StreamPayload, cid: int
    ) -> bytes:
        """Materialize one chunk's bytes with the quarantine discipline: a
        CRC/truncation failure (real, or injected via ``inject_fault``)
        marks the chunk quarantined — recorded once, surfaced through
        ``stats()['quarantine']``, fails fast on re-reads — and raises
        :class:`ChunkCorruptError` so callers (and the fleet frontend) can
        fail over to a replica instead of writing the payload off."""
        prior = sp.quarantine.get(cid)
        if prior is not None:
            raise ChunkCorruptError(name, cid, sp.path, prior)
        c = sp.chunks[cid]
        try:
            fault = self._faults.get(name)
            if fault is not None and cid in fault["corrupt_chunks"]:
                raise ValueError(
                    f"{sp.path}: corrupt payload: chunk checksum mismatch "
                    "(injected)"
                )
            return container.read_chunk(sp.view, c, ctx=f"{sp.path}: ")
        except ValueError as e:
            sp.quarantine[cid] = str(e)
            obs.emit_event(
                "chunk_quarantined",
                payload=name,
                chunk=cid,
                path=sp.path,
                entry_start=c.entry_start,
                entry_stop=c.entry_stop,
                error=str(e),
            )
            self.metrics.counter("chunks_quarantined", payload=name).inc()
            raise ChunkCorruptError(name, cid, sp.path, str(e)) from e

    def _materialize(
        self, name: str, sp: _StreamPayload, pipelined: bool = True
    ) -> None:
        """Read + parse a lazy payload body (counted as one miss, exactly
        like the pre-warm era).  Only BASE chunks form the body; TCDP patch
        overlays are materialized separately and wrapped around it, so
        every decode path sees repaired ranges automatically.  A chunk that
        fails its CRC is quarantined (see ``_read_chunk_checked``) instead
        of poisoning the payload.  ``pipelined=False`` reads chunks
        inline — required when already ON the single prefetch thread (the
        warm path), where submitting to the pool and waiting would
        deadlock."""
        self.cache_stats.miss(name)
        self._info[name].cache_misses += 1
        nb = _n_base(sp)
        with obs.span("materialize", payload=name, chunks=nb):
            with obs.span("chunk_read", payload=name, chunks=nb):
                reads = (
                    self._read_chunks(name, sp)
                    if pipelined
                    else [
                        self._read_chunk_checked(name, sp, i)
                        for i in range(nb)
                    ]
                )
                body = b"".join(reads)
            enc = codecs.get_codec(sp.codec).encoded_cls.from_bytes(body)
            if sp.patches:
                overlays = []
                for p in sp.patches:
                    pbody = b"".join(
                        self._read_chunk_checked(name, sp, i)
                        for i in range(p.chunk_start, p.chunk_stop)
                    )
                    overlays.append(
                        (p, codecs.get_codec(p.codec).encoded_cls.from_bytes(pbody))
                    )
                enc = container.PatchedEncoded(enc, overlays)
            fault = self._faults.get(name)
            if fault is not None and fault.get("noise") is not None:
                enc = _NoisyEncoded(enc, *fault["noise"])
            sp.enc = enc
        self._info[name].payload_bytes = sp.enc.payload_bytes()

    def _warm_stream(self, name: str, sp: _StreamPayload) -> None:
        """Background payload warm, scheduled by load_stream when prefetch
        is on.  Re-checks registration and ownership at RUN time (the fleet
        router may have installed a filter, or the name been reloaded,
        since scheduling) and silently skips when materializing would be
        wrong — the query path then does it synchronously as usual."""
        if self._streams.get(name) is not sp or sp.enc is not None:
            return
        if sp.ownership is not None and not sp.ownership.owns_payload():
            return
        self._materialize(name, sp, pipelined=False)
        sp.warm_credit = True

    # -------------------------------------------------------------- versions
    def _resolve_version(self, name: str, sp: _StreamPayload,
                         version: int | None) -> int:
        n = len(sp.versions)
        v = n - 1 if version is None else int(version)
        if not 0 <= v < n:
            raise ValueError(f"{name}: version {v} out of range [0, {n})")
        return v

    def _set_geometry(self, name: str, sp: _StreamPayload,
                      enc: codecs.Encoded) -> None:
        shape = tuple(int(s) for s in enc.shape)
        if sp.shape is None:
            sp.shape = shape
            if sp.tile_entries:
                sp.n_tiles = -(-int(np.prod(shape)) // sp.tile_entries)
        elif shape != sp.shape:
            raise ValueError(
                f"{name}: version component shape {shape} != {sp.shape}"
            )

    def _ensure_version_geometry(
        self, name: str, sp: _StreamPayload
    ) -> tuple[int, ...]:
        """Shape (and tile grid) of a versioned payload, learned from its
        version-0 component — materialized and LRU-accounted on demand."""
        if sp.shape is None:
            enc = self._get_component(name, sp, 0, count=False)
            self._account_version_state(name, sp, 0, enc)
        return sp.shape

    def _get_component(
        self, name: str, sp: _StreamPayload, v: int, count: bool = True
    ) -> codecs.Encoded:
        """Resolve ONE version's component payload (keyframe or delta),
        materializing it from the version's chunk range on a miss — the
        versioned analogue of ``_get``, with the same counting rules."""
        enc = sp.vencs.get(v)
        if enc is None:
            if sp.ownership is not None and not sp.ownership.owns_payload():
                raise NotOwnedError(
                    f"payload {name!r} is not owned by this instance "
                    "(ownership filter excludes every chunk)"
                )
            self.cache_stats.miss(name)
            self._info[name].cache_misses += 1
            ve = sp.versions[v]
            with obs.span("materialize", payload=name, version=v):
                with obs.span(
                    "chunk_read", payload=name,
                    chunks=ve.chunk_stop - ve.chunk_start,
                ):
                    body = b"".join(
                        self._read_chunk_checked(name, sp, i)
                        for i in range(ve.chunk_start, ve.chunk_stop)
                    )
                enc = codecs.get_codec(sp.codec).encoded_cls.from_bytes(body)
            sp.vencs[v] = enc
            self._set_geometry(name, sp, enc)
        elif count:
            self.cache_stats.hit(name)
            self._info[name].cache_hits += 1
        return enc

    def _account_version_state(
        self, name: str, sp: _StreamPayload, v: int, enc: codecs.Encoded
    ) -> None:
        """Post-decode accounting for one version component: its chunk
        bytes (+ droppable codec state) join the LRU as ("venc", name, v),
        evictable independently of every other version."""
        ve = sp.versions[v]
        vbytes = sum(
            c.length for c in sp.chunks[ve.chunk_start : ve.chunk_stop]
        )

        def drop(sp=sp, v=v):
            dropped = sp.vencs.pop(v, None)
            if dropped is not None:
                dropped.drop_caches()

        self._cache_put(
            ("venc", name, v),
            _CacheEntry(vbytes + enc.cache_nbytes(), None, drop),
        )

    def _decode_versioned(
        self, name: str, sp: _StreamPayload, idx: np.ndarray, version: int
    ) -> tuple[np.ndarray, int]:
        """Answer a query against version ``version``: float64 sum of the
        keyframe→delta chain's component answers (keyframe first) — the
        exact :class:`repro.temporal.ChainEncoded` convention, elementwise,
        so fleet batch-splitting cannot change a single bit."""
        chain = resolve_chain(sp.versions, version)
        if sp.tile_entries:
            return self._decode_versioned_tiled(name, sp, idx, chain, version)
        out = np.zeros((idx.shape[0],), dtype=np.float64)
        for v in chain:
            enc = self._get_component(name, sp, v)
            out += np.asarray(self._decode_batched(enc, idx), np.float64)
            self._account_version_state(name, sp, v, enc)
        calls = len(chain) * -(-idx.shape[0] // self.max_batch)
        return out, calls

    def _decode_versioned_tiled(
        self,
        name: str,
        sp: _StreamPayload,
        idx: np.ndarray,
        chain: list[int],
        version: int,
    ) -> tuple[np.ndarray, int]:
        """Tiled versioned decode.  Tiles cache under COMPOSITE ids
        ``v * n_tiles + tid`` so a base version's tiles are decoded once
        and shared by every version chaining through it; ownership is
        checked on the BASE tile id, keeping all versions of a tile on
        one owner (that is what makes the warm handoff and the fleet
        routing version-independent)."""
        flat = multi_to_flat(idx, sp.shape)
        if not len(flat):
            return np.zeros((0,), dtype=np.float64), 0
        tids = flat // sp.tile_entries
        uniq = [int(tid) for tid in np.unique(tids)]
        out = np.zeros((len(flat),), dtype=np.float64)
        with obs.span(
            "tile_decode", payload=name, version=version,
            chain=len(chain), tiles=len(uniq),
        ):
            decoded = self._decode_chain_tiles(
                name, sp, chain, uniq, flat, tids, out
            )
        return out, decoded

    def _decode_chain_tiles(
        self,
        name: str,
        sp: _StreamPayload,
        chain: list[int],
        uniq: list[int],
        flat: np.ndarray,
        tids: np.ndarray,
        out: np.ndarray,
    ) -> int:
        t = sp.tile_entries
        n_entries = int(np.prod(sp.shape))
        shape = sp.shape
        info = self._info[name]
        decoded = 0
        for v in chain:
            comp: codecs.Encoded | None = None
            for tid in uniq:
                ctid = v * sp.n_tiles + tid
                entry = self._cache_touch(("tile", name, ctid))
                if entry is None:
                    self.cache_stats.miss(name)
                    info.cache_misses += 1
                    if comp is None:
                        comp = self._get_component(name, sp, v, count=False)
                    start = tid * t
                    stop = min(start + t, n_entries)
                    tpos = flat_to_multi(
                        np.arange(start, stop, dtype=np.int64), shape
                    )
                    tile = self._decode_batched(comp, tpos)
                    decoded += 1
                    if sp.ownership is None or sp.ownership.owns_tile(tid):
                        self._cache_put(
                            ("tile", name, ctid),
                            _CacheEntry(int(tile.nbytes), tile),
                        )
                else:
                    self.cache_stats.hit(name)
                    info.cache_hits += 1
                    tile = entry.value
                mask = tids == tid
                out[mask] += np.asarray(tile[flat[mask] - tid * t], np.float64)
            if comp is not None:
                self._account_version_state(name, sp, v, comp)
        return decoded

    # -------------------------------------------------------------- prefetch
    def _pool(self) -> concurrent.futures.ThreadPoolExecutor | None:
        """Lazy single-worker pool: one background thread keeps the
        input-side pipeline strictly ordered (chunk i+1 never races ahead
        of chunk i+2), and nothing is spawned unless prefetch is on AND a
        pipelined path actually runs."""
        if not self.prefetch:
            return None
        if self._prefetch_pool is None:
            self._prefetch_pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="codec-prefetch"
            )
        return self._prefetch_pool

    def _read_chunks(self, name: str, sp: _StreamPayload) -> list[bytes]:
        """BASE-chunk bytes in index order.  With prefetch, reads run ahead
        on the background thread (page-in + CRC drop the GIL) while the
        main thread copies earlier chunks into the joined body."""
        nb = _n_base(sp)
        pool = self._pool()
        if pool is None or nb < 2:
            return [self._read_chunk_checked(name, sp, i) for i in range(nb)]
        futs = [
            pool.submit(self._read_chunk_checked, name, sp, i)
            for i in range(nb)
        ]
        return [f.result() for f in futs]

    # ------------------------------------------------------------- ownership
    def set_ownership(self, name: str, ownership: Ownership | None) -> None:
        """Install (or clear, with ``None``) the fleet ownership filter on
        a lazy payload's chunk-materialization and tile-cache paths.  The
        filter only gates FUTURE materialization/caching; state that just
        became unowned is dropped by :meth:`drop_unowned`, which the
        rebalancer calls after its drain barrier."""
        sp = self._streams.get(name)
        if sp is None:
            raise KeyError(f"no stream payload {name!r} (resident payloads "
                           "are not shardable)")
        sp.ownership = ownership

    def drop_unowned(self, name: str) -> int:
        """Evict cached state the current ownership filter excludes —
        unowned decode tiles, plus the materialized body when the payload
        itself is no longer owned.  Returns bytes freed (through the
        normal LRU eviction accounting)."""
        sp = self._streams.get(name)
        if sp is None or sp.ownership is None:
            return 0
        freed = 0
        for key in [k for k in self._cache if k[1] == name]:
            if key[0] == "tile":
                # composite versioned tile ids fold to their base tile: all
                # versions of a tile share one owner
                tid = key[2] % sp.n_tiles if sp.versions is not None else key[2]
                unowned = not sp.ownership.owns_tile(tid)
            else:
                unowned = not sp.ownership.owns_payload()
            if unowned:
                freed += self._cache[key].nbytes
                self._cache_evict(key)
        return freed

    def export_tiles(self, name: str) -> dict[int, np.ndarray]:
        """Cached decode tiles (tile id -> values) — the warm-handoff
        source a rebalance reads before this instance drops ownership."""
        return {
            key[2]: entry.value
            for key, entry in self._cache.items()
            if key[0] == "tile" and key[1] == name and entry.value is not None
        }

    def admit_tile(self, name: str, tid: int, values: np.ndarray) -> bool:
        """Warm handoff: admit a tile decoded by another instance, subject
        to the ownership filter and the byte budget.  Counts as neither
        hit nor miss — no query was answered.  Versioned payloads hand
        tiles off under their COMPOSITE ids (version * n_tiles + tile);
        ownership is judged on the base tile.  Returns True if admitted."""
        sp = self._streams.get(name)
        if sp is None or not sp.tile_entries:
            raise KeyError(f"no tiled stream payload {name!r}")
        tid = int(tid)
        base_tid = tid
        if sp.versions is not None:
            self._ensure_version_geometry(name, sp)
            v, base_tid = divmod(tid, sp.n_tiles)
            if not 0 <= v < len(sp.versions):
                return False
        if sp.ownership is not None and not sp.ownership.owns_tile(base_tid):
            return False
        values = np.asarray(values)
        self._cache_put(("tile", name, int(tid)),
                        _CacheEntry(int(values.nbytes), values))
        return True

    # ---------------------------------------------------------------- repair
    def inject_fault(self, name: str, fault: dict) -> None:
        """DEBUG-ONLY fault injection — the single surface behind the
        worker ``--debug-corrupt-chunk`` / ``--debug-fitness-noise`` flags
        and the pytest ``fault_injector`` fixture, so the CI drill and the
        unit tests exercise the exact failure path the repair controller
        fixes.

        ``fault["kind"]``:

        - ``"corrupt_chunk"`` (``chunk``): the named chunk's next read
          fails its CRC exactly as if the bytes rotted on disk — the chunk
          quarantines and queries needing the body raise
          :class:`ChunkCorruptError`;
        - ``"fitness_noise"`` (``entry_start``, ``entry_stop``, ``sigma``,
          optional ``seed``): served values inside the flat range pick up
          deterministic seeded noise, degrading canary fitness without
          touching the file.

        Cached bodies and tiles for the payload are dropped so the fault
        takes effect on the very next decode; :meth:`refresh` clears every
        installed fault."""
        sp = self._streams.get(name)
        if sp is None:
            raise KeyError(f"no stream payload {name!r}")
        kind = fault.get("kind")
        spec = self._faults.setdefault(
            name, {"corrupt_chunks": set(), "noise": None}
        )
        if kind == "corrupt_chunk":
            cid = int(fault["chunk"])
            if not 0 <= cid < len(sp.chunks):
                raise ValueError(f"{name}: chunk {cid} out of range")
            spec["corrupt_chunks"].add(cid)
        elif kind == "fitness_noise":
            spec["noise"] = (
                int(fault["entry_start"]),
                int(fault["entry_stop"]),
                float(fault["sigma"]),
                int(fault.get("seed", 0)),
            )
        else:
            raise ValueError(f"unknown fault kind {kind!r}")
        # join an in-flight background warm first: it may otherwise finish
        # AFTER the state drop below and resurrect a pre-fault body
        if sp.warm is not None:
            warm, sp.warm = sp.warm, None
            with contextlib.suppress(Exception):
                warm.result()
        sp.warm_credit = False
        self._drop_named_cache_entries(name)
        if sp.enc is not None:
            sp.enc.drop_caches()
            sp.enc = None
        sp.vencs.clear()

    def refresh(self, name: str) -> PayloadInfo:
        """Re-open a lazy payload's container file in place — the repair
        controller's epoch switch after it rewrote chunks or appended a
        patch.  Preserves the ownership filter and the cumulative
        ``PayloadInfo`` counters; clears quarantine marks, injected debug
        faults, per-payload canary state (the fitness gauge restarts clean
        for the repaired epoch), and every cached body/tile so the next
        decode re-reads the repaired bytes."""
        sp = self._streams.get(name)
        if sp is None:
            raise KeyError(f"no stream payload {name!r}")
        old = self._info[name]
        ownership, tile_entries, path = sp.ownership, sp.tile_entries, sp.path
        if sp.warm is not None:
            warm, sp.warm = sp.warm, None
            with contextlib.suppress(Exception):
                warm.result()
        self._faults.pop(name, None)
        self._canary.pop(name, None)
        self._canary_calls.pop(name, None)
        self._drop_named_cache_entries(name)
        self._streams.pop(name, None)
        sp.view.release()
        self.load_stream(name, path, tile_entries=tile_entries)
        nsp = self._streams[name]
        nsp.ownership = ownership
        info = self._info[name]
        info.requests = old.requests
        info.entries_decoded = old.entries_decoded
        info.decode_calls = old.decode_calls
        info.cache_hits = old.cache_hits
        info.cache_misses = old.cache_misses
        obs.emit_event("payload_refreshed", payload=name, path=path)
        return info

    def export_chunk(self, name: str, chunk: int) -> bytes | None:
        """Exact bytes of one chunk, reconstructed from this instance's
        MATERIALIZED body — never from the file, whose copy of the chunk
        may be the corrupt one under repair.  ``Encoded.to_bytes`` is a
        bit-exact round trip, so slicing the re-serialized body at the
        footer's chunk spans reproduces the originally written bytes.

        Returns ``None`` when this instance cannot vouch for the bytes:
        the chunk is quarantined here, the body is not materializable
        (ownership filter, or its own chunks are corrupt), or the slice
        fails the footer CRC.  A non-``None`` return IS CRC-verified
        against the footer entry, so the repair controller can splice it
        into a damaged replica's file sight unseen."""
        sp = self._streams.get(name)
        if sp is None:
            raise KeyError(f"no stream payload {name!r}")
        chunk = int(chunk)
        if not 0 <= chunk < len(sp.chunks):
            raise ValueError(f"{name}: chunk {chunk} out of range")
        if chunk in sp.quarantine:
            return None
        try:
            if sp.versions is not None:
                raw = self._export_version_chunk(name, sp, chunk)
            else:
                raw = self._export_single_chunk(name, sp, chunk)
        except (ChunkCorruptError, NotOwnedError):
            return None
        if raw is None:
            return None
        c = sp.chunks[chunk]
        if len(raw) != c.length or zlib.crc32(raw) & 0xFFFFFFFF != c.crc:
            return None
        return raw

    def _export_single_chunk(
        self, name: str, sp: _StreamPayload, chunk: int
    ) -> bytes | None:
        enc = self._get(name, count=False)
        self._account_decode_state(name, enc)
        while isinstance(enc, _NoisyEncoded):  # noise is decode-side only
            enc = enc.inner
        nb = _n_base(sp)
        if chunk < nb:
            base = enc.base if isinstance(enc, container.PatchedEncoded) else enc
            body = base.to_bytes()
            off = sum(sp.chunks[i].length for i in range(chunk))
            return body[off : off + sp.chunks[chunk].length]
        if not isinstance(enc, container.PatchedEncoded):
            return None
        for p, oenc in enc.overlays:
            if p.chunk_start <= chunk < p.chunk_stop:
                body = oenc.to_bytes()
                off = sum(
                    sp.chunks[i].length for i in range(p.chunk_start, chunk)
                )
                return body[off : off + sp.chunks[chunk].length]
        return None

    def _export_version_chunk(
        self, name: str, sp: _StreamPayload, chunk: int
    ) -> bytes | None:
        for v, ve in enumerate(sp.versions):
            if ve.chunk_start <= chunk < ve.chunk_stop:
                enc = self._get_component(name, sp, v, count=False)
                self._account_version_state(name, sp, v, enc)
                body = enc.to_bytes()
                off = sum(
                    sp.chunks[i].length for i in range(ve.chunk_start, chunk)
                )
                return body[off : off + sp.chunks[chunk].length]
        return None

    def quarantine_stats(self) -> dict:
        """Payload name -> {chunk id -> error} for every quarantined chunk;
        empty when healthy.  Rides ``stats()`` so the fleet repair
        controller discovers corruption through the same wire poll as
        canary breaches.  (JSON transports stringify the chunk-id keys —
        consumers normalize with ``int``.)"""
        return {
            name: {int(cid): err for cid, err in sorted(sp.quarantine.items())}
            for name, sp in self._streams.items()
            if sp.quarantine
        }

    # ----------------------------------------------------------------- cache
    def _drop_named_cache_entries(self, name: str) -> None:
        for key in [k for k in self._cache if k[1] == name]:
            self._cache_evict(key)

    def _cache_evict(self, key: tuple) -> None:
        entry = self._cache.pop(key)
        self.cache_stats.resident_bytes -= entry.nbytes
        self.cache_stats.evictions += 1
        per = self.cache_stats.for_payload(key[1])
        per.resident_bytes -= entry.nbytes
        per.evictions += 1
        if entry.on_evict is not None:
            entry.on_evict()

    def _cache_put(self, key: tuple, entry: _CacheEntry) -> None:
        old = self._cache.pop(key, None)
        if old is not None:
            self.cache_stats.resident_bytes -= old.nbytes
            self.cache_stats.for_payload(key[1]).resident_bytes -= old.nbytes
        self._cache[key] = entry
        self.cache_stats.resident_bytes += entry.nbytes
        self.cache_stats.for_payload(key[1]).resident_bytes += entry.nbytes
        if self.cache_bytes is None:
            return
        while self.cache_stats.resident_bytes > self.cache_bytes and self._cache:
            self._cache_evict(next(iter(self._cache)))

    def _cache_touch(self, key: tuple) -> _CacheEntry | None:
        entry = self._cache.get(key)
        if entry is not None:
            self._cache.move_to_end(key)
        return entry

    def _account_decode_state(self, name: str, enc: codecs.Encoded) -> None:
        """Post-decode accounting: droppable payload state (SZ-lite dense
        cache, materialized lazy bodies) joins the LRU ledger."""
        info = self._info[name]
        sp = self._streams.get(name)
        if sp is not None and sp.enc is not None:
            nbytes = sp.body_nbytes + enc.cache_nbytes()

            def drop(sp=sp, name=name):
                if sp.enc is not None:
                    sp.enc.drop_caches()
                    sp.enc = None
                # the rebuilt Encoded starts its counters at zero; reset the
                # mirror baseline with it or the next sync under-counts
                self._enc_counters_seen.pop(name, None)

            self._cache_put(("enc", name), _CacheEntry(nbytes, None, drop))
        elif enc.cache_nbytes():
            self._cache_put(
                ("deccache", name),
                _CacheEntry(enc.cache_nbytes(), None, enc.drop_caches),
            )
        # mirror per-payload counters kept by the Encoded itself (SZ-lite):
        # enc counters are cumulative, so fold in only the delta since the
        # last sync (re-registration under a new name resets the baseline)
        own = (getattr(enc, "cache_hits", 0), getattr(enc, "cache_misses", 0))
        if isinstance(own[0], int) and any(own):
            seen = self._enc_counters_seen.get(name, (0, 0))
            info.cache_hits += own[0] - seen[0]
            info.cache_misses += own[1] - seen[1]
            self._enc_counters_seen[name] = own

    # ----------------------------------------------------------------- tiles
    def _decode_tiled(
        self, name: str, sp: _StreamPayload, enc: codecs.Encoded, idx: np.ndarray
    ) -> tuple[np.ndarray, int]:
        """Answer a query from T-entry decode tiles; returns (values,
        number of tiles actually decoded)."""
        shape = enc.shape
        t = sp.tile_entries
        n_entries = int(np.prod(shape))
        flat = multi_to_flat(idx, shape)
        tids = flat // t
        if not len(flat):  # delegate so the dtype matches the untiled path
            return self._decode_batched(enc, idx), 0
        info = self._info[name]

        # pass 1: classify — cached tiles resolve immediately, misses queue
        # for the (possibly pipelined) decode pass.  Same structure with
        # prefetch on or off, so stats and answers match bit-for-bit.
        tiles: dict[int, np.ndarray] = {}
        misses: list[int] = []
        for tid in np.unique(tids):
            entry = self._cache_touch(("tile", name, int(tid)))
            if entry is None:
                self.cache_stats.miss(name)
                info.cache_misses += 1
                misses.append(int(tid))
            else:
                self.cache_stats.hit(name)
                info.cache_hits += 1
                tiles[int(tid)] = entry.value

        # pass 2: decode misses.  The per-tile input block (flat range ->
        # multi indices) is pure CPU work independent of the decode, so
        # with prefetch on, tile k+1's block is built on the background
        # thread while tile k decodes.
        def build(tid: int) -> np.ndarray:
            start = tid * t
            stop = min(start + t, n_entries)
            return flat_to_multi(np.arange(start, stop, dtype=np.int64), shape)

        pool = self._pool()
        with obs.span("tile_decode", payload=name, tiles=len(misses)) if misses \
                else contextlib.nullcontext():
            fut = None
            if pool is not None and len(misses) > 1:
                fut = pool.submit(build, misses[0])
            for j, tid in enumerate(misses):
                if fut is not None:
                    tpos = fut.result()
                    fut = pool.submit(build, misses[j + 1]) if j + 1 < len(misses) else None
                else:
                    tpos = build(tid)
                tile = self._decode_batched(enc, tpos)
                tiles[tid] = tile
                # unowned tiles decode through WITHOUT caching — correct
                # mid-rebalance, and resident tile bytes stay this
                # instance's shard of the fleet total
                if sp.ownership is None or sp.ownership.owns_tile(tid):
                    self._cache_put(
                        ("tile", name, tid), _CacheEntry(int(tile.nbytes), tile)
                    )

        out = np.empty(len(flat), dtype=next(iter(tiles.values())).dtype)
        for tid, tile in tiles.items():
            mask = tids == tid
            out[mask] = tile[flat[mask] - tid * t]
        return out, len(misses)

    # --------------------------------------------------------------- queries
    def _decode_batched(self, enc: codecs.Encoded, idx: np.ndarray) -> np.ndarray:
        """Decode at most ``max_batch`` indices per ``enc.decode_at`` call —
        EVERY decode (direct, coalesced, tile fill) funnels through here so
        no path can materialize one giant device batch."""
        if idx.shape[0] <= self.max_batch:
            return np.asarray(enc.decode_at(idx))
        return np.concatenate(
            [
                np.asarray(enc.decode_at(idx[s : s + self.max_batch]))
                for s in range(0, idx.shape[0], self.max_batch)
            ]
        )

    def _validate(self, name: str, enc: codecs.Encoded,
                  indices: np.ndarray) -> np.ndarray:
        return validate_indices(name, tuple(enc.shape), indices)

    def decode_at(
        self, name: str, indices: np.ndarray, version: int | None = None
    ) -> np.ndarray:
        """Chunked decode so arbitrarily large requests stream through
        fixed-size batches.  Indices are validated up front; stats count
        only work that actually decoded.  ``version`` selects a v4
        payload's version (default: latest); single-tensor payloads
        reject it."""
        with obs.span("decode_at", payload=name, entries=int(np.size(indices))):
            sp = self._streams.get(name)
            if sp is not None and sp.versions is not None:
                v = self._resolve_version(name, sp, version)
                shape = self._ensure_version_geometry(name, sp)
                idx = validate_indices(name, shape, indices)
                out, calls = self._decode_versioned(name, sp, idx, v)
            else:
                if version is not None:
                    raise ValueError(
                        f"payload {name!r} is not versioned (version={version})"
                    )
                enc = self._get(name)
                idx = self._validate(name, enc, indices)
                if sp is not None and sp.tile_entries:
                    out, calls = self._decode_tiled(name, sp, enc, idx)
                else:
                    out = self._decode_batched(enc, idx)
                    # ceil-div: 0 for an empty query, matching the tiled path
                    # (which reports 0 tiles decoded for an empty query)
                    calls = -(-idx.shape[0] // self.max_batch)
                self._account_decode_state(name, enc)
                if self.canary_fraction and sp is not None:
                    self._maybe_canary(name, sp, enc)
            info = self._info[name]
            info.requests += 1
            info.entries_decoded += idx.shape[0]
            info.decode_calls += calls
            return out

    # -------------------------------------------------------------- canaries
    def _maybe_canary(
        self, name: str, sp: _StreamPayload, enc: codecs.Encoded
    ) -> None:
        """Maybe run one online fitness check after a served decode.

        The sampling decision hashes (seed, payload, per-payload call
        number) so it is DETERMINISTIC in the request sequence — two
        instances serving the same stream canary the same calls, and a
        Local vs Socket transport cannot diverge.  The check decodes
        through :meth:`_decode_batched` (a pure read), so served answers
        are untouched; only stats move.
        """
        if sp.heldout is None:
            return
        k = self._canary_calls.get(name, 0)
        self._canary_calls[name] = k + 1
        h = zlib.crc32(f"{self.canary_seed}:{name}:{k}".encode())
        if h >= self.canary_fraction * 2**32:
            return
        idx, truth = sp.heldout.indices, sp.heldout.values
        if len(idx) > self.canary_max_entries:
            pick = np.random.default_rng((self.canary_seed, k)).choice(
                len(idx), size=self.canary_max_entries, replace=False
            )
            idx, truth = idx[pick], truth[pick]
        with obs.span("canary", payload=name, entries=len(idx)):
            pos = flat_to_multi(idx, tuple(int(s) for s in enc.shape))
            approx = np.asarray(self._decode_batched(enc, pos), np.float64)
        err = approx - truth
        fitness = float(
            1.0 - np.linalg.norm(err) / max(np.linalg.norm(truth), 1e-30)
        )
        st = self._canary.setdefault(name, _CanaryState())
        st.checks += 1
        st.last_fitness = fitness
        st.window.append(fitness)
        self.metrics.gauge("canary_fitness", payload=name).set(
            st.rolling_fitness()
        )
        self.metrics.counter("canary_checks", payload=name).inc()
        if (
            self.canary_min_fitness is not None
            and fitness < self.canary_min_fitness
        ):
            st.breaches += 1
            self.metrics.counter("canary_breaches", payload=name).inc()
            worst = int(idx[int(np.argmax(np.abs(err)))])
            chunk, lo, hi = self._chunk_of_entry(sp, worst)
            st.last_breach = {
                "fitness": fitness,
                "threshold": float(self.canary_min_fitness),
                "worst_index": worst,
                "chunk": chunk,
                "entry_start": lo,
                "entry_stop": hi,
            }
            obs.emit_event(
                "quality_breach",
                payload=name,
                fitness=fitness,
                threshold=float(self.canary_min_fitness),
                worst_index=worst,
                chunk=chunk,
                entry_start=lo,
                entry_stop=hi,
            )

    @staticmethod
    def _chunk_of_entry(
        sp: _StreamPayload, flat: int
    ) -> tuple[int | None, int | None, int | None]:
        """The BASE chunk whose footer entry range routes ``flat`` — names
        the repair target for a quality breach (patch chunks also carry
        ranges but base chunks are the stable repair address).  (None,
        None, None) when the file carries no entry ranges."""
        for i, c in enumerate(sp.chunks[: _n_base(sp)]):
            if (
                c.entry_start is not None
                and c.entry_start <= flat < c.entry_stop
            ):
                return i, int(c.entry_start), int(c.entry_stop)
        return None, None, None

    def canary_stats(self) -> dict:
        """Per-payload canary snapshot (checks/breaches/fitness); empty
        until a canary has actually run."""
        return {name: st.as_dict() for name, st in self._canary.items()}

    def stats(self) -> dict:
        """Full JSON-able instance snapshot: the cache-stats wire schema
        plus ``canary`` and ``quarantine`` sub-dicts.  Additive over
        ``cache_stats.as_dict`` so old consumers of the transport stats
        blob keep working."""
        out = self.cache_stats.as_dict()
        out["canary"] = self.canary_stats()
        out["quarantine"] = self.quarantine_stats()
        return out

    # --------------------------------------------------------------- batched
    def submit(
        self, name: str, indices: np.ndarray, version: int | None = None
    ) -> int:
        """Queue a request; returns a ticket resolved by the next flush().

        Validates eagerly — a malformed request raises HERE and never
        enters the queue, so it cannot sink the coalesced batch.
        ``version=None`` on a versioned payload resolves to the LATEST
        version at submit time, so the coalesced group is concrete."""
        sp = self._streams.get(name)
        if sp is not None and sp.versions is not None:
            v = self._resolve_version(name, sp, version)
            shape = self._ensure_version_geometry(name, sp)
            idx = validate_indices(name, shape, indices)
        else:
            if version is not None:
                raise ValueError(
                    f"payload {name!r} is not versioned (version={version})"
                )
            idx = self._validate(name, self._get(name, count=False), indices)
            v = None
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queue.append((ticket, name, idx, v))
        return ticket

    def flush(self) -> dict[int, np.ndarray]:
        """Decode all queued requests, one coalesced batch per (payload,
        version) group.

        A group that still fails is isolated: its tickets go to
        ``self.failed`` (ticket -> exception, reset each flush) and the
        other groups' results are returned normally."""
        self.failed = {}
        by_group: dict[tuple[str, int | None], list[tuple[int, np.ndarray]]] = {}
        for ticket, name, idx, version in self._queue:
            by_group.setdefault((name, version), []).append((ticket, idx))
        self._queue.clear()
        results: dict[int, np.ndarray] = {}
        with obs.span(
            "coalesce_flush",
            tickets=sum(len(reqs) for reqs in by_group.values()),
            groups=len(by_group),
        ):
            for (name, version), reqs in by_group.items():
                merged = np.concatenate([idx for _, idx in reqs], axis=0)
                try:
                    values = self.decode_at(name, merged, version=version)
                except Exception as e:  # noqa: BLE001 — isolate the bad group
                    for ticket, _ in reqs:
                        self.failed[ticket] = e
                    continue
                self._info[name].requests += len(reqs) - 1  # decode_at counted one
                off = 0
                for ticket, idx in reqs:
                    results[ticket] = values[off : off + idx.shape[0]]
                    off += idx.shape[0]
        return results
