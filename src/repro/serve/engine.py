"""Batched serving engine: fixed-slot continuous batching.

The engine owns a KV cache of B slots x max_len.  Requests queue up;
whenever a slot frees (sequence finished), the next request is prefilled
into that slot and decoding continues for the whole batch.  This is the
slot-based continuous batching used by production engines, minus paging
(slot granularity = full sequence; the dry-run's decode_32k cell is one
engine step at scale).

Greedy sampling by default; temperature optional.  All compute paths are
the pjit-able step functions from repro.train.step.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] token ids
    max_new_tokens: int = 32
    eos_id: int | None = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list[int]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.results: list[Result] = []
        # per-slot state
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_len = np.zeros(batch_slots, np.int32)
        self.slot_new = np.zeros(batch_slots, np.int32)
        self.slot_out: list[list[int]] = [[] for _ in range(batch_slots)]
        self.caches = [model.init_cache(cfg, 1, max_len) for _ in range(batch_slots)]
        self.last_tok = np.zeros(batch_slots, np.int32)

        self._decode = jax.jit(
            lambda p, c, t, ln: model.decode_step(
                p, cfg, token=t, cache=c, cache_len=ln
            )
        )
        self._prefill = jax.jit(
            lambda p, c, t: model.prefill(p, cfg, tokens=t, cache=c)
        )

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> list[Result]:
        """Run until queue and slots drain.  Returns completed results."""
        while self.queue or any(r is not None for r in self.slot_req):
            self._fill_slots()
            self._decode_tick()
        return self.results

    # ------------------------------------------------------------- internals
    def _fill_slots(self) -> None:
        for i in range(self.slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.popleft()
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache = self._prefill(self.params, self.caches[i], toks)
                self.caches[i] = cache
                self.slot_req[i] = req
                self.slot_len[i] = len(req.prompt)
                self.slot_new[i] = 0
                self.slot_out[i] = []
                self.last_tok[i] = self._sample(logits[0, -1])

    def _sample(self, logits: jax.Array) -> int:
        logits = np.asarray(logits, np.float32)[: self.cfg.vocab]
        if self.temperature <= 0:
            return int(logits.argmax())
        self.key, sub = jax.random.split(self.key)
        probs = jax.nn.softmax(jnp.asarray(logits) / self.temperature)
        return int(jax.random.choice(sub, logits.shape[0], p=probs))

    def _decode_tick(self) -> None:
        for i in range(self.slots):
            req = self.slot_req[i]
            if req is None:
                continue
            tok = self.last_tok[i]
            self.slot_out[i].append(int(tok))
            done = (
                len(self.slot_out[i]) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
                or self.slot_len[i] + 1 >= self.max_len
            )
            if done:
                self.results.append(Result(req.uid, self.slot_out[i]))
                self.slot_req[i] = None
                continue
            logits, cache = self._decode(
                self.params,
                self.caches[i],
                jnp.asarray([[tok]], jnp.int32),
                jnp.int32(self.slot_len[i]),
            )
            self.caches[i] = cache
            self.slot_len[i] += 1
            self.last_tok[i] = self._sample(logits[0, -1])
