"""TensorCodec as a checkpoint codec (the paper <-> framework integration).

Large weight tensors are lossily compressed with NTTD before hitting disk
or the network: embedding tables, MoE expert banks, and any matrix above
``min_elements``.  Each compressed leaf is fitness-gated — if the quick
NTTD fit cannot reach ``min_fitness`` within the epoch budget, the leaf is
stored raw instead (no silent quality cliffs).

This is the deployment story for the paper's technique at 1000-node
scale: checkpoint shipping and cold-start restore are bandwidth-bound, and
a 10-40x smaller payload directly cuts RPO/restore latency.  Exact-restore
training checkpoints should keep ``enabled=False``; the codec path is for
weight DISTRIBUTION (serving fleets, cross-DC sync, archival).
"""
from __future__ import annotations

import dataclasses
import io
from typing import Any

import jax
import numpy as np

from repro.core import codec as codec_lib
from repro.core import serialization


@dataclasses.dataclass
class CodecCheckpointConfig:
    min_elements: int = 1 << 16      # only compress leaves at least this big
    min_fitness: float = 0.95        # fitness gate; below -> store raw
    rank: int = 8
    hidden: int = 16
    epochs: int = 15
    batch_size: int = 65536
    lr: float = 1e-2
    reorder: bool = False            # reordering off for speed by default
    seed: int = 0


def compress_tree(tree, cfg: CodecCheckpointConfig | None = None):
    """Returns ({key: payload_bytes_or_raw}, stats).  Keys follow
    checkpoint._flatten naming."""
    from repro.train.checkpoint import _flatten

    cfg = cfg or CodecCheckpointConfig()
    out: dict[str, dict[str, Any]] = {}
    stats = {"raw_bytes": 0, "compressed_bytes": 0, "leaves_codec": 0, "leaves_raw": 0}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        raw_nbytes = arr.nbytes
        stats["raw_bytes"] += raw_nbytes
        if arr.size >= cfg.min_elements and arr.ndim >= 2:
            ct, _log = codec_lib.compress(
                arr.astype(np.float32),
                codec_lib.CodecConfig(
                    rank=cfg.rank,
                    hidden=cfg.hidden,
                    epochs=cfg.epochs,
                    batch_size=cfg.batch_size,
                    lr=cfg.lr,
                    init_reorder=cfg.reorder,
                    update_reorder=cfg.reorder,
                    seed=cfg.seed,
                    entries_per_epoch=min(arr.size, 2_000_000),
                ),
            )
            fit = ct.fitness(arr.astype(np.float32))
            if fit >= cfg.min_fitness:
                blob = serialization.save_bytes(ct, np.float32)
                out[key] = {
                    "kind": "nttd",
                    "data": blob,
                    "fitness": fit,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
                stats["compressed_bytes"] += len(blob)
                stats["leaves_codec"] += 1
                continue
        buf = io.BytesIO()
        np.save(buf, arr)
        out[key] = {"kind": "raw", "data": buf.getvalue()}
        stats["compressed_bytes"] += len(out[key]["data"])
        stats["leaves_raw"] += 1
    stats["ratio"] = stats["raw_bytes"] / max(stats["compressed_bytes"], 1)
    return out, stats


def decompress_tree(payload: dict, template):
    """Inverse of compress_tree (lossy for 'nttd' leaves)."""
    from repro.train.checkpoint import _unflatten_into

    values = {}
    for key, item in payload.items():
        if item["kind"] == "raw":
            values[key] = np.load(io.BytesIO(item["data"]))
        else:
            ct = serialization.load_bytes(item["data"])
            values[key] = ct.to_dense().astype(np.dtype(item["dtype"]))
    return _unflatten_into(template, values)
