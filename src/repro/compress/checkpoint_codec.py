"""Compressed checkpoints over the unified codec registry.

Large weight tensors are lossily compressed before hitting disk or the
network: embedding tables, MoE expert banks, and any matrix above
``min_elements``.  Any codec registered in ``repro.codecs`` can back the
compression (``CodecCheckpointConfig.codec``); the default is the paper's
NTTD.  Each compressed leaf is fitness-gated — if the fit cannot reach
``min_fitness`` within its budget, the leaf is stored raw instead (no
silent quality cliffs).  Payloads are the self-describing container
format, so a checkpoint written with one codec restores through the
registry without the reader knowing which codec produced it (legacy
headerless NTTD blobs from older checkpoints still load).

This is the deployment story for the paper's technique at 1000-node
scale: checkpoint shipping and cold-start restore are bandwidth-bound, and
a 10-40x smaller payload directly cuts RPO/restore latency.  Exact-restore
training checkpoints should keep ``enabled=False``; the codec path is for
weight DISTRIBUTION (serving fleets, cross-DC sync, archival).
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
from typing import Any

import numpy as np

from repro import codecs


@dataclasses.dataclass
class CodecCheckpointConfig:
    codec: str = "nttd"              # any name in repro.codecs.available()
    min_elements: int = 1 << 16      # only compress leaves at least this big
    min_fitness: float = 0.95        # fitness gate; below -> store raw
    # NTTD fit knobs (ignored by budget-driven codecs)
    rank: int = 8
    hidden: int = 16
    epochs: int = 15
    batch_size: int = 65536
    lr: float = 1e-2
    reorder: bool = False            # reordering off for speed by default
    seed: int = 0
    # budget for non-NTTD codecs: target payload as a fraction of raw bytes
    budget_ratio: float = 0.125
    fit_opts: dict[str, Any] | None = None  # explicit overrides, passed to fit


def _fit_leaf(arr32: np.ndarray, cfg: CodecCheckpointConfig) -> codecs.Encoded:
    codec = codecs.get_codec(cfg.codec)
    if cfg.fit_opts is not None:
        return codec.fit(arr32, **cfg.fit_opts)
    if cfg.codec == "nttd":
        return codec.fit(
            arr32,
            rank=cfg.rank,
            hidden=cfg.hidden,
            epochs=cfg.epochs,
            batch_size=cfg.batch_size,
            lr=cfg.lr,
            init_reorder=cfg.reorder,
            update_reorder=cfg.reorder,
            seed=cfg.seed,
            entries_per_epoch=min(arr32.size, 2_000_000),
        )
    budget = max(int(arr32.nbytes * cfg.budget_ratio), 1024)
    return codec.fit(arr32, budget)


def compress_tree(tree, cfg: CodecCheckpointConfig | None = None):
    """Returns ({key: payload_bytes_or_raw}, stats).  Keys follow
    checkpoint._flatten naming."""
    from repro.train.checkpoint import _flatten

    cfg = cfg or CodecCheckpointConfig()
    out: dict[str, dict[str, Any]] = {}
    stats = {"raw_bytes": 0, "compressed_bytes": 0, "leaves_codec": 0, "leaves_raw": 0}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        raw_nbytes = arr.nbytes
        stats["raw_bytes"] += raw_nbytes
        if arr.size >= cfg.min_elements and arr.ndim >= 2:
            arr32 = arr.astype(np.float32)
            try:
                enc = _fit_leaf(arr32, cfg)
            except ValueError:
                enc = None  # budget infeasible for this codec -> store raw
            fit = enc.fitness(arr32) if enc is not None else -np.inf
            if fit >= cfg.min_fitness:
                blob = codecs.save_bytes(enc)
                out[key] = {
                    "kind": cfg.codec,
                    "data": blob,
                    "fitness": fit,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
                stats["compressed_bytes"] += len(blob)
                stats["leaves_codec"] += 1
                continue
        buf = io.BytesIO()
        np.save(buf, arr)
        out[key] = {"kind": "raw", "data": buf.getvalue()}
        stats["compressed_bytes"] += len(out[key]["data"])
        stats["leaves_raw"] += 1
    stats["ratio"] = stats["raw_bytes"] / max(stats["compressed_bytes"], 1)
    return out, stats


@dataclasses.dataclass
class VersionedCheckpointConfig:
    """Knobs for :class:`VersionedCheckpointer` (delta-coded v4 stores)."""

    codec: str = "nttd"              # any name in repro.codecs.available()
    min_elements: int = 1 << 16      # only delta-code leaves at least this big
    min_fitness: float = 0.95        # chain gate; below -> fresh keyframe
    keyframe_interval: int = 8       # bound on decode-chain depth
    chunk_bytes: int = 1 << 20
    delta_passes: int = 2
    keyframe_opts: dict[str, Any] | None = None  # passed to Codec.fit
    delta_opts: dict[str, Any] | None = None     # passed to the stream fitter


class VersionedCheckpointer:
    """Checkpoint steps as versions of per-leaf delta stores.

    Step ``N+1`` of every large weight tensor is fitted as a residual
    against the reconstruction of step ``N`` (``repro.temporal``) — a
    training run's consecutive checkpoints differ by one optimizer step,
    so the residual is far cheaper to encode than the tensor.  Leaves
    below ``min_elements`` (or below the fitness gate on their very first
    step) are demoted to raw ``.npz`` per step, permanently: a leaf the
    codec cannot represent at step 0 will not start representing it later.

    Layout under ``directory``::

        manifest.json          key -> {kind, file, dtype, shape}; n_steps
        leaf<i>.tcdc           one v4 delta container per codec leaf
        raw_step<k>.npz        all raw leaves of step k

    Every ``save_step`` ends with the stores synced and the manifest
    rewritten, so the directory restores after a crash mid-run.  A
    reopened checkpointer is restore-only: resuming appends against
    existing stores is not supported (writers start fresh files).
    """

    def __init__(self, directory: str, cfg: VersionedCheckpointConfig | None = None):
        from repro.temporal import VersionedStore

        self.directory = directory
        self.cfg = cfg or VersionedCheckpointConfig()
        self._store_cls = VersionedStore
        os.makedirs(directory, exist_ok=True)
        self._stores: dict[str, Any] = {}   # key -> VersionedStore
        self._leaves: dict[str, dict] = {}  # key -> manifest entry
        self._n_steps = 0
        manifest = os.path.join(directory, "manifest.json")
        if os.path.exists(manifest):
            with open(manifest) as f:
                m = json.load(f)
            self._n_steps = m["n_steps"]
            self._leaves = m["leaves"]

    @property
    def n_steps(self) -> int:
        return self._n_steps

    def _open_store(self, key: str, fname: str):
        cfg = self.cfg
        self._stores[key] = self._store_cls(
            os.path.join(self.directory, fname),
            cfg.codec,
            keyframe_interval=cfg.keyframe_interval,
            chunk_bytes=cfg.chunk_bytes,
            keyframe_opts=cfg.keyframe_opts,
            delta_opts=cfg.delta_opts,
            delta_passes=cfg.delta_passes,
            rekey_below=cfg.min_fitness,
        )

    def save_step(self, tree) -> dict:
        """Append one checkpoint step; returns per-step stats."""
        from repro.train.checkpoint import _flatten

        cfg = self.cfg
        step = self._n_steps
        stats = {"step": step, "bytes": 0, "leaves_store": 0, "leaves_raw": 0,
                 "keyframes": 0, "fitness_min": 1.0}
        raw: dict[str, np.ndarray] = {}
        for i, (key, leaf) in enumerate(_flatten(tree)):
            arr = np.asarray(leaf)
            entry = self._leaves.get(key)
            if entry is None:
                if step != 0:
                    raise ValueError(f"leaf {key!r} appeared after step 0")
                eligible = arr.size >= cfg.min_elements and arr.ndim >= 2
                entry = {
                    "kind": "store" if eligible else "raw",
                    "file": f"leaf{i}.tcdc" if eligible else None,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
                self._leaves[key] = entry
            if entry["kind"] == "store":
                if key not in self._stores:
                    if step > 0:
                        raise ValueError(
                            "reopened VersionedCheckpointer is restore-only; "
                            "start a new directory to keep appending"
                        )
                    self._open_store(key, entry["file"])
                st = self._stores[key].append(arr.astype(np.float32))
                if step == 0 and st["fitness"] < cfg.min_fitness:
                    # below the gate on its FIRST step: the codec cannot
                    # represent this leaf — demote it to raw permanently
                    self._stores.pop(key).close()
                    os.remove(os.path.join(self.directory, entry["file"]))
                    entry.update(kind="raw", file=None)
                else:
                    stats["bytes"] += st["bytes"]
                    stats["leaves_store"] += 1
                    stats["keyframes"] += int(st["keyframe"])
                    stats["fitness_min"] = min(stats["fitness_min"], st["fitness"])
            if entry["kind"] == "raw":
                raw[key.replace("/", "__")] = arr
        if raw:
            path = os.path.join(self.directory, f"raw_step{step}.npz")
            np.savez(path, **raw)
            stats["bytes"] += os.path.getsize(path)
            stats["leaves_raw"] = len(raw)
        self._n_steps = step + 1
        self._write_manifest()
        return stats

    def _write_manifest(self) -> None:
        tmp = os.path.join(self.directory, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump({"n_steps": self._n_steps, "leaves": self._leaves}, f, indent=1)
        os.replace(tmp, os.path.join(self.directory, "manifest.json"))

    def restore_step(self, step: int, template):
        """Rebuild the tree at ``step`` (lossy for store-backed leaves)."""
        from repro.temporal import VersionedStore
        from repro.train.checkpoint import _unflatten_into

        if not 0 <= step < self._n_steps:
            raise ValueError(f"step {step} out of range [0, {self._n_steps})")
        values: dict[str, np.ndarray] = {}
        raw_path = os.path.join(self.directory, f"raw_step{step}.npz")
        raw = np.load(raw_path) if os.path.exists(raw_path) else {}
        for key, entry in self._leaves.items():
            dtype = np.dtype(entry["dtype"])
            if entry["kind"] == "raw":
                values[key] = np.asarray(raw[key.replace("/", "__")])
            else:
                with VersionedStore.open(
                    os.path.join(self.directory, entry["file"])
                ) as reader:
                    values[key] = reader.decode(version=step).astype(dtype)
        return _unflatten_into(template, values)

    def close(self) -> None:
        for store in self._stores.values():
            store.close()
        self._stores.clear()

    def __enter__(self) -> "VersionedCheckpointer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def decompress_tree(payload: dict, template):
    """Inverse of compress_tree (lossy for codec leaves).  The container's
    codec-id header drives decoding, so `kind` is informational only."""
    from repro.train.checkpoint import _unflatten_into

    values = {}
    for key, item in payload.items():
        if item["kind"] == "raw":
            values[key] = np.load(io.BytesIO(item["data"]))
        else:
            enc = codecs.load_bytes(item["data"])
            values[key] = enc.to_dense().astype(np.dtype(item["dtype"]))
    return _unflatten_into(template, values)
