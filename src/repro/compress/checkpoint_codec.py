"""Compressed checkpoints over the unified codec registry.

Large weight tensors are lossily compressed before hitting disk or the
network: embedding tables, MoE expert banks, and any matrix above
``min_elements``.  Any codec registered in ``repro.codecs`` can back the
compression (``CodecCheckpointConfig.codec``); the default is the paper's
NTTD.  Each compressed leaf is fitness-gated — if the fit cannot reach
``min_fitness`` within its budget, the leaf is stored raw instead (no
silent quality cliffs).  Payloads are the self-describing container
format, so a checkpoint written with one codec restores through the
registry without the reader knowing which codec produced it (legacy
headerless NTTD blobs from older checkpoints still load).

This is the deployment story for the paper's technique at 1000-node
scale: checkpoint shipping and cold-start restore are bandwidth-bound, and
a 10-40x smaller payload directly cuts RPO/restore latency.  Exact-restore
training checkpoints should keep ``enabled=False``; the codec path is for
weight DISTRIBUTION (serving fleets, cross-DC sync, archival).
"""
from __future__ import annotations

import dataclasses
import io
from typing import Any

import numpy as np

from repro import codecs


@dataclasses.dataclass
class CodecCheckpointConfig:
    codec: str = "nttd"              # any name in repro.codecs.available()
    min_elements: int = 1 << 16      # only compress leaves at least this big
    min_fitness: float = 0.95        # fitness gate; below -> store raw
    # NTTD fit knobs (ignored by budget-driven codecs)
    rank: int = 8
    hidden: int = 16
    epochs: int = 15
    batch_size: int = 65536
    lr: float = 1e-2
    reorder: bool = False            # reordering off for speed by default
    seed: int = 0
    # budget for non-NTTD codecs: target payload as a fraction of raw bytes
    budget_ratio: float = 0.125
    fit_opts: dict[str, Any] | None = None  # explicit overrides, passed to fit


def _fit_leaf(arr32: np.ndarray, cfg: CodecCheckpointConfig) -> codecs.Encoded:
    codec = codecs.get_codec(cfg.codec)
    if cfg.fit_opts is not None:
        return codec.fit(arr32, **cfg.fit_opts)
    if cfg.codec == "nttd":
        return codec.fit(
            arr32,
            rank=cfg.rank,
            hidden=cfg.hidden,
            epochs=cfg.epochs,
            batch_size=cfg.batch_size,
            lr=cfg.lr,
            init_reorder=cfg.reorder,
            update_reorder=cfg.reorder,
            seed=cfg.seed,
            entries_per_epoch=min(arr32.size, 2_000_000),
        )
    budget = max(int(arr32.nbytes * cfg.budget_ratio), 1024)
    return codec.fit(arr32, budget)


def compress_tree(tree, cfg: CodecCheckpointConfig | None = None):
    """Returns ({key: payload_bytes_or_raw}, stats).  Keys follow
    checkpoint._flatten naming."""
    from repro.train.checkpoint import _flatten

    cfg = cfg or CodecCheckpointConfig()
    out: dict[str, dict[str, Any]] = {}
    stats = {"raw_bytes": 0, "compressed_bytes": 0, "leaves_codec": 0, "leaves_raw": 0}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        raw_nbytes = arr.nbytes
        stats["raw_bytes"] += raw_nbytes
        if arr.size >= cfg.min_elements and arr.ndim >= 2:
            arr32 = arr.astype(np.float32)
            try:
                enc = _fit_leaf(arr32, cfg)
            except ValueError:
                enc = None  # budget infeasible for this codec -> store raw
            fit = enc.fitness(arr32) if enc is not None else -np.inf
            if fit >= cfg.min_fitness:
                blob = codecs.save_bytes(enc)
                out[key] = {
                    "kind": cfg.codec,
                    "data": blob,
                    "fitness": fit,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
                stats["compressed_bytes"] += len(blob)
                stats["leaves_codec"] += 1
                continue
        buf = io.BytesIO()
        np.save(buf, arr)
        out[key] = {"kind": "raw", "data": buf.getvalue()}
        stats["compressed_bytes"] += len(out[key]["data"])
        stats["leaves_raw"] += 1
    stats["ratio"] = stats["raw_bytes"] / max(stats["compressed_bytes"], 1)
    return out, stats


def decompress_tree(payload: dict, template):
    """Inverse of compress_tree (lossy for codec leaves).  The container's
    codec-id header drives decoding, so `kind` is informational only."""
    from repro.train.checkpoint import _unflatten_into

    values = {}
    for key, item in payload.items():
        if item["kind"] == "raw":
            values[key] = np.load(io.BytesIO(item["data"]))
        else:
            enc = codecs.load_bytes(item["data"])
            values[key] = enc.to_dense().astype(np.dtype(item["dtype"]))
    return _unflatten_into(template, values)
