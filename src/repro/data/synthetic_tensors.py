"""Synthetic replicas of the paper's 8 real-world tensors (Table II).

The container is offline, so the actual datasets (Uber, Air Quality, ...)
are unavailable.  Each generator below produces a tensor with the same
order and comparable density/smoothness profile; a ``mini`` variant scales
mode lengths down (~1/4 per mode) so CPU-budget experiments finish in
minutes.  ``stats`` computes the paper's density and smoothness metrics so
EXPERIMENTS.md can report how close the replicas are.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    shape: tuple[int, ...]        # paper's Table II shape
    mini_shape: tuple[int, ...]   # CPU-budget shape
    generator: Callable[[tuple[int, ...], np.random.Generator], np.ndarray]
    target_density: float
    target_smoothness: float


def _grid(shape, rng):
    axes = [np.linspace(0, 1, n) for n in shape]
    return np.meshgrid(*axes, indexing="ij")


def _match_density(x: np.ndarray, target: float) -> np.ndarray:
    """Zero the smallest-|value| entries so nnz/size == target."""
    if target >= 1.0:
        return x
    k = int(x.size * (1 - target))
    if k <= 0:
        return x
    thresh = np.partition(np.abs(x).reshape(-1), k)[k]
    out = x.copy()
    out[np.abs(out) < thresh] = 0.0
    return out


def _uber_like(shape, rng):
    """Sparse-ish counts with daily/hourly periodic structure (density .138)."""
    g = _grid(shape, rng)
    base = (
        np.sin(2 * np.pi * 3 * g[0])
        * np.exp(np.sin(2 * np.pi * g[1]) * 2)
        * (0.3 + np.cos(2 * np.pi * 2 * g[2]) ** 2)
    )
    intensity = np.exp(base * 1.5) * 0.08
    x = rng.poisson(intensity).astype(np.float64)
    return x


def _airquality_like(shape, rng):
    """Dense slow-varying sensor series + station offsets (density .917)."""
    g = _grid(shape, rng)
    x = (
        10
        + 6 * np.sin(2 * np.pi * 4 * g[0])
        + 4 * np.cos(2 * np.pi * 2 * g[1] + 1.0)
        + 2 * g[2]
        + rng.normal(size=shape) * 1.2
    )
    drop = rng.random(shape) > 0.92
    x[drop] = 0.0
    return x


def _action_like(shape, rng):
    """Motion-feature style: piecewise-smooth rows, moderate density."""
    x = rng.normal(size=shape) * 0.2
    t = np.linspace(0, 1, shape[-1])
    for _ in range(max(shape[0] * 2, 8)):
        i = rng.integers(0, shape[0])
        j = rng.integers(0, shape[1])
        f = rng.integers(1, 6)
        x[i, j:] += np.sin(2 * np.pi * f * t) * rng.normal() * 2
    return _match_density(x, 0.393)


def _pems_like(shape, rng):
    """Dense traffic occupancy: strong daily pattern per (station, lane)."""
    g = _grid(shape, rng)
    station = rng.normal(size=(shape[0], 1, 1))
    x = (
        0.1
        + 0.08 * np.exp(np.sin(2 * np.pi * g[1] - 1.2) * 1.5)
        + 0.03 * station
        + rng.normal(size=shape) * 0.01
    )
    return np.clip(x, 0, None)


def _activity_like(shape, rng):
    x = rng.normal(size=shape) * 0.2
    t = np.linspace(0, 1, shape[-1])
    for _ in range(max(shape[0] * 2, 8)):
        i = rng.integers(0, shape[0])
        j = rng.integers(0, shape[1])
        x[i, j:] += np.sin(2 * np.pi * rng.integers(1, 6) * t) * rng.normal() * 2
    return _match_density(x * 1.4 + 0.05, 0.569)


def _stock_like(shape, rng):
    """Random-walk price series per (ticker, feature): very smooth (.976).
    Neighboring tickers/features correlate (sector structure), so the 3^d
    window std stays far below the global std."""
    steps = rng.normal(size=shape) * 0.004
    common = rng.normal(size=(1, 1, shape[2])) * 0.01
    x = np.cumsum(steps + common, axis=-1) + 1.0
    # sorted per-ticker scales -> adjacent tickers have similar magnitude
    scale = np.sort(np.exp(rng.normal(size=shape[0]) * 0.8))[:, None, None]
    feat = np.sort(np.exp(rng.normal(size=shape[1]) * 0.3))[None, :, None]
    return _match_density(x * scale * feat, 0.816)


def _nyc_like(shape, rng):
    """4-order origin x dest x time x day taxi counts, sparse (.118)."""
    g = _grid(shape, rng)
    hub = np.exp(-((g[0] - 0.4) ** 2 + (g[1] - 0.4) ** 2) * 8)
    daily = np.exp(np.sin(2 * np.pi * g[2]) * 1.5)
    x = rng.poisson(hub * daily * 0.35).astype(np.float64)
    return x


def _absorb_like(shape, rng):
    """Climate-simulation style: fully dense, very smooth (.935)."""
    g = _grid(shape, rng)
    x = (
        np.sin(2 * np.pi * g[0])
        + np.cos(2 * np.pi * g[1] * 2)
        + 0.5 * g[2] ** 2
        + 0.3 * np.sin(2 * np.pi * g[3] * 3)
    )
    return x + rng.normal(size=shape) * 0.02


DATASETS: dict[str, DatasetSpec] = {
    s.name: s
    for s in [
        DatasetSpec("uber", (183, 24, 1140), (48, 24, 72), _uber_like, 0.138, 0.861),
        DatasetSpec("air_quality", (5600, 362, 6), (256, 92, 6), _airquality_like, 0.917, 0.513),
        DatasetSpec("action", (100, 570, 567), (50, 72, 72), _action_like, 0.393, 0.484),
        DatasetSpec("pems_sf", (963, 144, 440), (96, 48, 56), _pems_like, 0.999, 0.461),
        DatasetSpec("activity", (337, 570, 320), (64, 72, 48), _activity_like, 0.569, 0.553),
        DatasetSpec("stock", (1317, 88, 916), (128, 24, 96), _stock_like, 0.816, 0.976),
        DatasetSpec("nyc", (265, 265, 28, 35), (48, 48, 24, 12), _nyc_like, 0.118, 0.788),
        DatasetSpec("absorb", (192, 288, 30, 120), (48, 36, 12, 30), _absorb_like, 1.000, 0.935),
    ]
}


def load(name: str, mini: bool = True, seed: int = 0) -> np.ndarray:
    spec = DATASETS[name]
    rng = np.random.default_rng(seed + hash(name) % 2**31)
    shape = spec.mini_shape if mini else spec.shape
    return spec.generator(shape, rng).astype(np.float32)


def density(x: np.ndarray) -> float:
    return float(np.count_nonzero(x)) / x.size


def smoothness(x: np.ndarray, sample: int = 2000, seed: int = 0) -> float:
    """Paper's metric: 1 - E_i[sigma_3(i)] / sigma, where sigma_3(i) is the
    std of the 3^d window centered at i (sampled for speed)."""
    rng = np.random.default_rng(seed)
    d = x.ndim
    sigma = float(x.std())
    if sigma == 0:
        return 1.0
    centers = np.stack(
        [rng.integers(1, max(n - 1, 2), size=sample) for n in x.shape], axis=1
    )
    stds = np.empty(sample)
    for t in range(sample):
        sl = tuple(
            slice(max(c - 1, 0), min(c + 2, n))
            for c, n in zip(centers[t], x.shape)
        )
        stds[t] = x[sl].std()
    return 1.0 - float(stds.mean()) / sigma
