"""Deterministic, rank-sharded token pipeline.

Two sources:
  * ``SyntheticSource`` — seeded on-the-fly token streams (tests, smoke
    training, dry-runs); Zipfian unigram mix with injected n-gram structure
    so the loss actually decreases.
  * ``MMapSource`` — memory-mapped binary token file (production path;
    ``write_corpus`` builds one).

Determinism contract (straggler/elasticity story): ``batch_at(step)`` is a
pure function of (seed, rank, world, step) — a restarted or replacement
worker resumes mid-run by just asking for the right step, and a backup
worker can shadow a straggler without coordination.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    batch_size: int           # per-rank sequences per step
    seq_len: int
    vocab: int
    seed: int = 0
    rank: int = 0
    world: int = 1


class SyntheticSource:
    """Zipf unigrams + planted bigram transitions (learnable structure)."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks**1.2)
        self.unigram /= self.unigram.sum()
        # each token has a preferred successor (cyclic shift by a fixed map)
        self.successor = rng.permutation(v)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + cfg.rank * 7 + 13
        )
        b, s = cfg.batch_size, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(b, s), p=self.unigram).astype(np.int32)
        # 60% of positions follow the planted bigram map (structure to learn)
        follow = rng.random((b, s - 1)) < 0.6
        nxt = self.successor[toks[:, :-1]]
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = 0
        return {"tokens": toks, "labels": labels}


class MMapSource:
    """Flat binary int32 token file, rank-strided sampling."""

    def __init__(self, path: str, cfg: PipelineConfig):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        # all ranks draw from the same permutation stream, then take their
        # disjoint stripe — changing `world` reshuffles cleanly (elastic)
        idx = rng.integers(0, self.n_windows, size=cfg.batch_size * cfg.world)
        idx = idx[cfg.rank :: cfg.world][: cfg.batch_size]
        toks = np.stack(
            [self.data[i * cfg.seq_len : i * cfg.seq_len + cfg.seq_len] for i in idx]
        ).astype(np.int32)
        labels = np.stack(
            [
                self.data[i * cfg.seq_len + 1 : i * cfg.seq_len + cfg.seq_len + 1]
                for i in idx
            ]
        ).astype(np.int32)
        return {"tokens": toks, "labels": labels}


def write_corpus(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, dtype=np.int32).tofile(path)
