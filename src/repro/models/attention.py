"""GQA attention with RoPE: training, prefill (cache write), decode.

KV caches have logical axes (batch, long_kv/kv_seq, kv_heads, head_dim);
the long-context rules map the cache length onto the 'data' mesh axis when
the batch cannot fill it (long_500k), letting XLA partition the softmax
reduction across shards (flash-decode in SPMD form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, shard
from repro.kernels import ops
from repro.models import layers


def attn_specs(cfg: ModelConfig, stacked: tuple[int, ...] = ()) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    lead = tuple("layers" for _ in stacked)
    out = {
        "wq": ParamSpec(stacked + (d, h, hd), lead + ("ffn_in", "heads", "head_dim")),
        "wk": ParamSpec(stacked + (d, kv, hd), lead + ("ffn_in", "kv_heads", "head_dim")),
        "wv": ParamSpec(stacked + (d, kv, hd), lead + ("ffn_in", "kv_heads", "head_dim")),
        "wo": ParamSpec(stacked + (h, hd, d), lead + ("heads", "head_dim", "ffn_in")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamSpec(stacked + (h, hd), lead + ("heads", "head_dim"), init="zeros")
        out["bk"] = ParamSpec(stacked + (kv, hd), lead + ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = ParamSpec(stacked + (kv, hd), lead + ("kv_heads", "head_dim"), init="zeros")
    return out


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array, dt):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    # 'seq_attn' is None by default; rules map it to 'model' for archs
    # whose head count cannot take the TP axis (context-parallel attention)
    q = shard(q, "batch", "seq_attn", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def self_attention(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full causal self-attention (training / scoring)."""
    dt = x.dtype
    positions = jnp.arange(x.shape[1])
    q, k, v = _qkv(p, x, cfg, positions, dt)
    out = ops.attention(q, k, v, causal=True, impl=cfg.attn_impl)
    out = shard(out, "batch", "seq_attn", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def prefill_attention(
    p: dict, x: jax.Array, cfg: ModelConfig, cache: dict
) -> tuple[jax.Array, dict]:
    """Causal attention over the prompt; writes k/v into the cache at [0, S)."""
    dt = x.dtype
    s = x.shape[1]
    positions = jnp.arange(s)
    q, k, v = _qkv(p, x, cfg, positions, dt)
    out = ops.attention(q, k, v, causal=True, impl=cfg.attn_impl)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        ),
    }
    out = shard(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), new_cache


def decode_attention(
    p: dict,
    x: jax.Array,          # [B, 1, d]
    cfg: ModelConfig,
    cache: dict,           # k/v: [B, S_max, KV, hd]
    cache_len: jax.Array,  # scalar int32: tokens already in cache
) -> tuple[jax.Array, dict]:
    """Single-token decode against the KV cache."""
    dt = x.dtype
    positions = cache_len[None] if cache_len.ndim == 0 else cache_len
    q, k, v = _qkv(p, x, cfg, positions.reshape(1), dt)
    bsz = x.shape[0]
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, cache_len, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, cache_len, 0, 0)
    )
    kv_len = jnp.full((bsz,), cache_len + 1, jnp.int32)
    out = ops.attention(
        q,
        k_cache.astype(dt),
        v_cache.astype(dt),
        causal=False,
        kv_len=kv_len,
        impl="ref",  # single-query path: XLA partitions the length reduction
    )
    out = shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return y, {"k": k_cache, "v": v_cache}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool,
                stacked: tuple[int, ...] = ()) -> dict:
    """ParamSpec tree for the attention KV cache (used by serve dry-run)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    seq_axis = "long_kv" if long_ctx else "kv_seq"
    lead = tuple("layers" for _ in stacked)
    spec = ParamSpec(
        stacked + (batch, max_len, kv, hd),
        lead + ("batch", seq_axis, "kv_heads", "head_dim"),
        init="zeros",
        dtype=layers.dtype_of(cfg.compute_dtype),
    )
    return {"k": spec, "v": spec}
