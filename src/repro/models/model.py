"""Unified model API over the architecture zoo.

    specs   = param_specs(cfg)                    # ParamSpec tree
    params  = init_params(key, cfg)               # real weights (tests/training)
    ab      = abstract_params(cfg)                # ShapeDtypeStructs (dry-run)
    logits, aux = forward(params, cfg, tokens=...)      # teacher-forced
    loss, metrics = loss_fn(params, cfg, batch)
    logits, cache = prefill(params, cfg, tokens, cache)
    logits, cache = decode_step(params, cfg, token, cache, cache_len)

`[vlm]`/`[audio]` archs take precomputed frontend embeddings via
``embeds=`` (the assignment's stub frontend).
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.dist import sharding
from repro.dist.sharding import shard
from repro.models import layers, transformer


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def param_specs(cfg: ModelConfig) -> dict:
    return transformer.param_specs(cfg)


def init_params(key: jax.Array, cfg: ModelConfig):
    return sharding.materialize(
        key, param_specs(cfg), layers.dtype_of(cfg.param_dtype)
    )


def abstract_params(cfg: ModelConfig):
    return sharding.tree_abstract(param_specs(cfg), layers.dtype_of(cfg.param_dtype))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool = False):
    return transformer.cache_specs(cfg, batch, max_len, long_ctx)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool = False):
    return sharding.tree_abstract(
        cache_specs(cfg, batch, max_len, long_ctx), layers.dtype_of(cfg.compute_dtype)
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool = False):
    # all cache specs are zeros-init
    return sharding.materialize(
        jax.random.PRNGKey(0),
        cache_specs(cfg, batch, max_len, long_ctx),
        layers.dtype_of(cfg.compute_dtype),
    )


# ---------------------------------------------------------------------------
# forward paths
# ---------------------------------------------------------------------------
def _embed_in(params, cfg: ModelConfig, tokens, embeds):
    dt = layers.dtype_of(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(dt)
    else:
        x = layers.embed_lookup(params["tok"], tokens, dt)
    return shard(x, "batch", "seq", "act_embed")


def forward(params, cfg: ModelConfig, tokens=None, embeds=None):
    """Teacher-forced full-sequence forward.  Returns (logits, aux)."""
    x = _embed_in(params, cfg, tokens, embeds)
    x, _, aux = transformer.run_stack(params, x, cfg, mode="full")
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return layers.unembed(params["tok"], x, layers.dtype_of(cfg.compute_dtype)), aux


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """batch: {'tokens' or 'embeds', 'labels', optional 'mask'}."""
    logits, aux = forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds")
    )
    xent = layers.softmax_xent(logits, batch["labels"], valid_vocab=cfg.vocab)
    loss = xent + cfg.moe_aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


def prefill(params, cfg: ModelConfig, tokens=None, cache=None, embeds=None):
    """Process the prompt, fill the cache.  Returns (last-position logits, cache)."""
    x = _embed_in(params, cfg, tokens, embeds)
    x, new_cache, _ = transformer.run_stack(params, x, cfg, cache=cache, mode="prefill")
    x = layers.rmsnorm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["tok"], x, layers.dtype_of(cfg.compute_dtype))
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, token=None, cache=None, cache_len=None,
                embeds=None):
    """One decode step.  token: [B, 1] ids (or embeds [B, 1, d]);
    cache_len: scalar int32 tokens already in cache.  Returns (logits, cache)."""
    x = _embed_in(params, cfg, token, embeds)
    x, new_cache, _ = transformer.run_stack(
        params, x, cfg, cache=cache, cache_len=cache_len, mode="decode"
    )
    x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(params["tok"], x, layers.dtype_of(cfg.compute_dtype))
    return logits, new_cache


# ---------------------------------------------------------------------------
# analytic parameter counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------
def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    import numpy as np

    specs = jax.tree.leaves(
        param_specs(cfg), is_leaf=lambda s: isinstance(s, sharding.ParamSpec)
    )
    total = sum(int(np.prod(s.shape)) for s in specs)
    if not active_only or not cfg.moe_experts:
        return total
    # active = total - (inactive experts' weights)
    layout = transformer.block_layout(cfg)
    n_moe = sum(1 for _, f in layout if f == "moe") * cfg.n_blocks
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = n_moe * (cfg.moe_experts - cfg.moe_top_k) * per_expert
    return total - inactive
