"""Mamba2 block (state-space duality, arXiv:2405.21060) — TPU-native.

Training/prefill uses the chunked SSD parallel form: the sequence is split
into chunks of Q tokens; within a chunk the output is a masked quadratic
"attention" with cumulative decay weights (MXU-friendly einsums), and the
inter-chunk recurrence runs a short ``lax.scan`` over chunk states
(S/Q steps, e.g. 16 at seq 4096).  Decode is the exact recurrence on the
[B, H, P, N] state.

Block structure (in_proj -> causal conv -> SSD -> gated RMSNorm ->
out_proj) follows the Mamba2 reference; the conv state carries the last
(k-1) inputs for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, shard
from repro.models import layers


def dims(cfg: ModelConfig) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "d_in": d_in,
        "n_heads": n_heads,
        "conv_dim": conv_dim,
        "proj_out": 2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + n_heads,
    }


def mamba_specs(cfg: ModelConfig, stacked: tuple[int, ...] = ()) -> dict:
    d = dims(cfg)
    lead = tuple("layers" for _ in stacked)
    return {
        # in_proj packs [z (d_in), x (d_in), B (G*N), C (G*N), dt (H)]
        "in_proj": ParamSpec(
            stacked + (cfg.d_model, d["proj_out"]), lead + ("ffn_in", "ssm_inner")
        ),
        "conv_w": ParamSpec(
            stacked + (cfg.ssm_conv, d["conv_dim"]), lead + ("conv_k", "ssm_inner")
        ),
        "conv_b": ParamSpec(stacked + (d["conv_dim"],), lead + ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec(stacked + (d["n_heads"],), lead + ("ssm_heads",), init="zeros"),
        "d_skip": ParamSpec(stacked + (d["n_heads"],), lead + ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec(stacked + (d["n_heads"],), lead + ("ssm_heads",), init="zeros"),
        "norm_w": ParamSpec(stacked + (d["d_in"],), lead + ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec(
            stacked + (d["d_in"], cfg.d_model), lead + ("ssm_inner", "ffn_in")
        ),
    }


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    d = dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    z, xbc_dt = jnp.split(proj, [d["d_in"]], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d["d_in"] + 2 * gn], axis=-1)
    return z, xbc, dt  # xbc = [x, B, C] goes through the conv


def _split_xbc(xbc: jax.Array, cfg: ModelConfig):
    d = dims(cfg)
    gn = cfg.ssm_groups * cfg.ssm_state
    x, b, c = jnp.split(xbc, [d["d_in"], d["d_in"] + gn], axis=-1)
    return x, b, c


def _ssd_chunked(
    x: jax.Array,   # [B, S, H, P]
    dt: jax.Array,  # [B, S, H] (post-softplus)
    a: jax.Array,   # [H] negative decay rates
    b: jax.Array,   # [B, S, G, N]
    c: jax.Array,   # [B, S, G, N]
    cfg: ModelConfig,
    h0: jax.Array | None = None,  # [B, H, P, N] initial state
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.  Returns (y [B,S,H,P], final state [B,H,P,N])."""
    bs, s_in, nh, hp = x.shape
    g = cfg.ssm_groups
    q = min(cfg.ssm_chunk, s_in)
    pad = (-s_in) % q
    if pad:
        # dt=0 on padding: zero state contribution AND unit decay, so the
        # final state is exact; padded outputs are sliced off below.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s_in + pad
    nc = s // q
    rep = nh // g

    # chunk views
    xc = x.reshape(bs, nc, q, nh, hp)
    dtc = dt.reshape(bs, nc, q, nh)
    bc = jnp.repeat(b.reshape(bs, nc, q, g, -1), rep, axis=3)   # [B,NC,Q,H,N]
    cc = jnp.repeat(c.reshape(bs, nc, q, g, -1), rep, axis=3)

    da = dtc * a[None, None, None, :]                  # [B,NC,Q,H] log-decay
    cums = jnp.cumsum(da, axis=2)                      # within-chunk cumulative

    # ---- intra-chunk (quadratic with decay mask) ----------------------------
    # L[i,j] = exp(cums_i - cums_j) for i >= j else 0.
    # The mask must be applied INSIDE the exp (double-where): for i < j the
    # difference is positive and can overflow, and grad-of-where still
    # differentiates the overflowed branch (NaN gradients otherwise).
    rel = cums[:, :, :, None, :] - cums[:, :, None, :, :]      # [B,NC,Qi,Qj,H]
    causal = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    l_mask = jnp.where(causal, jnp.exp(jnp.where(causal, rel, 0.0)), 0.0)
    scores = jnp.einsum("bnihd,bnjhd->bnijh", cc, bc)          # C_i . B_j
    w = scores * l_mask * dtc[:, :, None, :, :]                # weight x_j by dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", w.astype(x.dtype), xc)

    # ---- chunk summary states -------------------------------------------------
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)          # [B,NC,Q,H]
    state_contrib = jnp.einsum(
        "bnqhd,bnqhp,bnqh->bnhpd",
        bc,
        xc.astype(jnp.float32),
        (decay_to_end * dtc).astype(jnp.float32),
    )  # [B,NC,H,P,N]
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                  # [B,NC,H]

    # ---- inter-chunk recurrence (scan over chunks) -----------------------------
    def step(h_prev, inp):
        contrib, decay = inp  # [B,H,P,N], [B,H]
        h_new = h_prev * decay[:, :, None, None] + contrib
        return h_new, h_prev  # emit state *entering* the chunk

    init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((bs, nh, hp, b.shape[-1]), jnp.float32)
    )
    h_final, h_enter = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(state_contrib, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_enter = jnp.moveaxis(h_enter, 0, 1)                      # [B,NC,H,P,N]

    # ---- inter-chunk output ------------------------------------------------------
    decay_from_start = jnp.exp(cums)                           # [B,NC,Q,H]
    y_inter = jnp.einsum(
        "bnqhd,bnhpd,bnqh->bnqhp",
        cc.astype(jnp.float32),
        h_enter,
        decay_from_start.astype(jnp.float32),
    )
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(bs, s, nh, hp)
    if pad:
        y = y[:, :s_in]
    return y.astype(x.dtype), h_final


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def mamba_forward(
    p: dict,
    xin: jax.Array,  # [B, S, d_model]
    cfg: ModelConfig,
    state: dict | None = None,  # decode: {'conv': [B,K-1,convdim], 'ssm': [B,H,P,N]}
) -> tuple[jax.Array, dict | None]:
    """Full-sequence forward (train/prefill: state=None -> chunked SSD) or
    single-step decode (state given, S must be 1)."""
    dt_c = xin.dtype
    d = dims(cfg)
    proj = jnp.einsum("bsd,de->bse", xin, p["in_proj"].astype(dt_c))
    z, xbc, dt_raw = _split_proj(proj, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if state is None:
        conv_out = _causal_conv(xbc, p["conv_w"].astype(dt_c), p["conv_b"].astype(dt_c))
        x, b, c = _split_xbc(conv_out, cfg)
        bs, s = xin.shape[0], xin.shape[1]
        x = x.reshape(bs, s, d["n_heads"], cfg.ssm_head_dim)
        b = b.reshape(bs, s, cfg.ssm_groups, cfg.ssm_state)
        c = c.reshape(bs, s, cfg.ssm_groups, cfg.ssm_state)
        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )
        x = shard(x, "batch", "seq", "ssm_heads", "ssm_head_dim")
        y, h_final = _ssd_chunked(x, dt, a, b, c, cfg)
        y = y + x * p["d_skip"].astype(dt_c)[None, None, :, None]
        y = y.reshape(bs, s, d["d_in"])
        new_state = {
            "conv": xbc[:, -(cfg.ssm_conv - 1) :, :].astype(dt_c),
            "ssm": h_final.astype(jnp.float32),
        }
    else:
        # ---- exact recurrence, one token ------------------------------------
        bs = xin.shape[0]
        conv_in = jnp.concatenate([state["conv"].astype(dt_c), xbc], axis=1)
        k = cfg.ssm_conv
        w = p["conv_w"].astype(dt_c)
        conv_out = sum(conv_in[:, i : i + 1, :] * w[i][None, None, :] for i in range(k))
        conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dt_c)[None, None, :])
        x, b, c = _split_xbc(conv_out, cfg)
        x = x.reshape(bs, d["n_heads"], cfg.ssm_head_dim)
        b = b.reshape(bs, cfg.ssm_groups, cfg.ssm_state)
        c = c.reshape(bs, cfg.ssm_groups, cfg.ssm_state)
        rep = d["n_heads"] // cfg.ssm_groups
        bh = jnp.repeat(b, rep, axis=1)  # [B,H,N]
        ch = jnp.repeat(c, rep, axis=1)
        dt = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )  # [B,H]
        decay = jnp.exp(dt * a[None, :])  # [B,H]
        h = state["ssm"] * decay[:, :, None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", x.astype(jnp.float32), bh.astype(jnp.float32), dt
        )
        y = jnp.einsum("bhpn,bhn->bhp", h, ch.astype(jnp.float32))
        y = y + x.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
        y = y.reshape(bs, 1, d["d_in"]).astype(dt_c)
        new_state = {"conv": conv_in[:, 1:, :].astype(dt_c), "ssm": h}

    # gated RMSNorm + out_proj
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_c))
    return out, new_state


def state_specs(cfg: ModelConfig, batch: int, stacked: tuple[int, ...] = ()) -> dict:
    d = dims(cfg)
    lead = tuple("layers" for _ in stacked)
    return {
        "conv": ParamSpec(
            stacked + (batch, cfg.ssm_conv - 1, d["conv_dim"]),
            lead + ("batch", None, "ssm_inner"),
            init="zeros",
            dtype=layers.dtype_of(cfg.compute_dtype),
        ),
        "ssm": ParamSpec(
            stacked + (batch, d["n_heads"], cfg.ssm_head_dim, cfg.ssm_state),
            lead + ("batch", "ssm_heads", "ssm_head_dim", "ssm_state"),
            init="zeros",
            dtype=jax.numpy.float32,
        ),
    }
