"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, embedding/unembedding.

All functions are pure; weights come in as pytree leaves annotated with
logical axes via ParamSpec (see models/*.py `*_specs` builders).  Compute
runs in ``compute_dtype`` (bf16 on TPU); norms and softmax accumulate f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import ParamSpec, shard


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_spec(d: int, stacked: tuple[int, ...] = ()) -> ParamSpec:
    lead = tuple("layers" for _ in stacked)
    return ParamSpec(stacked + (d,), lead + ("act_embed",), init="ones")


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [S] or [B, S] absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [S, half] or [B,S,half]
    if ang.ndim == 2:  # [S, half] -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]  # [B_or_1, S, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_specs(d: int, f: int, stacked: tuple[int, ...] = ()) -> dict:
    lead = tuple("layers" for _ in stacked)
    return {
        "w_gate": ParamSpec(stacked + (d, f), lead + ("ffn_in", "mlp")),
        "w_up": ParamSpec(stacked + (d, f), lead + ("ffn_in", "mlp")),
        "w_down": ParamSpec(stacked + (f, d), lead + ("mlp", "ffn_in")),
    }


def mlp(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(h) * u
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(compute_dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
VOCAB_PAD = 128  # Megatron-style: pad vocab so TP always divides


def padded_vocab(vocab: int) -> int:
    return ((vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def embed_specs(vocab: int, d: int, tie: bool) -> dict:
    pv = padded_vocab(vocab)
    out = {"embed": ParamSpec((pv, d), ("vocab", "embed"), init="embed")}
    if not tie:
        out["unembed"] = ParamSpec((d, pv), ("embed", "vocab"))
    return out


def embed_lookup(p: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["embed"].astype(compute_dtype)[tokens]


def unembed(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    if "unembed" in p:
        w = p["unembed"].astype(compute_dtype)
    else:
        w = p["embed"].astype(compute_dtype).T
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(
    logits: jax.Array, labels: jax.Array, valid_vocab: int | None = None
) -> jax.Array:
    """Mean token cross-entropy; logits promoted to f32.  ``valid_vocab``
    masks padded vocabulary columns out of the partition function."""
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < valid_vocab
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
