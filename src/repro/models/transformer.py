"""Decoder stack assembly for all assigned families.

A *block* is the scan unit; each family defines a block layout — a list of
(mixer, ffn) sublayers:

  dense    : [(attn, mlp)]                                x n_layers
  moe e1   : [(attn, moe)]                                x n_layers   (grok)
  moe e2   : [(attn, mlp), (attn, moe)]                   x n_layers/2 (llama4)
  hybrid   : [(attn, mlp|moe), (mamba, ...) x 7]          x n_layers/8 (jamba,
             1 attention per 8 sublayers, MoE on odd global layer indices)
  ssm      : [(mamba, None)]                              x n_layers   (mamba2)

Within a block, params of each sublayer type are stacked on a 'sublayers'
dim and applied by a short unrolled loop; blocks themselves are stacked on
a 'layers' dim and driven by ``lax.scan`` (keeps HLO size and compile time
independent of depth).  ``cfg.remat`` wraps the scan body in
``jax.checkpoint`` with the selected policy.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, shard
from repro.models import attention, layers, mamba, moe


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------
def block_layout(cfg: ModelConfig) -> list[tuple[str, str | None]]:
    if cfg.family == "dense":
        return [("attn", "mlp")]
    if cfg.family == "moe":
        if cfg.moe_every == 1:
            return [("attn", "moe")]
        out = []
        for i in range(cfg.moe_every):
            out.append(("attn", "moe" if i % 2 == 1 else "mlp"))
        return out
    if cfg.family == "hybrid":
        out = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (cfg.moe_experts and i % 2 == 1) else "mlp"
            out.append((mixer, ffn))
        return out
    if cfg.family == "ssm":
        return [("mamba", None)]
    raise ValueError(cfg.family)


def _counts(cfg: ModelConfig) -> dict[str, int]:
    layout = block_layout(cfg)
    return {
        "attn": sum(1 for m, _ in layout if m == "attn"),
        "mamba": sum(1 for m, _ in layout if m == "mamba"),
        "mlp": sum(1 for _, f in layout if f == "mlp"),
        "moe": sum(1 for _, f in layout if f == "moe"),
        "sub": len(layout),
        "ffn": sum(1 for _, f in layout if f),
    }


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def block_specs(cfg: ModelConfig) -> dict:
    c = _counts(cfg)
    nb = cfg.n_blocks
    d = cfg.d_model
    specs: dict[str, Any] = {
        "mixer_norm": ParamSpec(
            (nb, c["sub"], d), ("layers", "layers", "act_embed"), init="ones"
        ),
    }
    if c["ffn"]:
        specs["ffn_norm"] = ParamSpec(
            (nb, c["ffn"], d), ("layers", "layers", "act_embed"), init="ones"
        )
    if c["attn"]:
        specs["attn"] = attention.attn_specs(cfg, stacked=(nb, c["attn"]))
    if c["mamba"]:
        specs["mamba"] = mamba.mamba_specs(cfg, stacked=(nb, c["mamba"]))
    if c["mlp"]:
        specs["mlp"] = layers.mlp_specs(d, cfg.d_ff, stacked=(nb, c["mlp"]))
    if c["moe"]:
        specs["moe"] = moe.moe_specs(cfg, stacked=(nb, c["moe"]))
    return specs


def param_specs(cfg: ModelConfig) -> dict:
    specs = {
        "tok": layers.embed_specs(cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "blocks": block_specs(cfg),
        "final_norm": layers.rmsnorm_spec(cfg.d_model),
    }
    return specs


# ---------------------------------------------------------------------------
# cache specs (serving)
# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int, long_ctx: bool) -> dict:
    c = _counts(cfg)
    nb = cfg.n_blocks
    out: dict[str, Any] = {}
    if c["attn"]:
        out["attn"] = attention.cache_specs(
            cfg, batch, max_len, long_ctx, stacked=(nb, c["attn"])
        )
    if c["mamba"]:
        out["mamba"] = mamba.state_specs(cfg, batch, stacked=(nb, c["mamba"]))
    return out


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _tree_index(tree, i: int):
    return jax.tree.map(lambda a: a[i], tree)


def apply_block(
    bp: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache: dict | None,
    cache_len: jax.Array | None,
    mode: str,  # full | prefill | decode
):
    """Returns (x, new_cache_or_None, aux_loss)."""
    layout = block_layout(cfg)
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    idx = {"attn": 0, "mamba": 0, "mlp": 0, "moe": 0}
    attn_caches, mamba_caches = [], []

    for sub, (mixer, ffn) in enumerate(layout):
        h = layers.rmsnorm(x, bp["mixer_norm"][sub], eps)
        if mixer == "attn":
            ap = _tree_index(bp["attn"], idx["attn"])
            if mode == "full":
                y = attention.self_attention(ap, h, cfg)
            elif mode == "prefill":
                cslice = _tree_index(cache["attn"], idx["attn"])
                y, nc = attention.prefill_attention(ap, h, cfg, cslice)
                attn_caches.append(nc)
            else:
                cslice = _tree_index(cache["attn"], idx["attn"])
                y, nc = attention.decode_attention(ap, h, cfg, cslice, cache_len)
                attn_caches.append(nc)
        else:
            mp = _tree_index(bp["mamba"], idx["mamba"])
            st = _tree_index(cache["mamba"], idx["mamba"]) if mode == "decode" else None
            y, nst = mamba.mamba_forward(mp, h, cfg, st)
            if mode in ("prefill", "decode"):
                mamba_caches.append(nst)
        idx[mixer] += 1
        x = x + y
        x = shard(x, "batch", "seq", "act_embed")

        if ffn:
            fi = idx["mlp"] + idx["moe"]
            h = layers.rmsnorm(x, bp["ffn_norm"][fi], eps)
            if ffn == "mlp":
                y = layers.mlp(
                    _tree_index(bp["mlp"], idx["mlp"]),
                    h,
                    layers.dtype_of(cfg.compute_dtype),
                )
            else:
                y, a = moe.moe_ffn(_tree_index(bp["moe"], idx["moe"]), h, cfg)
                aux = aux + a
            idx[ffn] += 1
            x = x + y
            x = shard(x, "batch", "seq", "act_embed")

    if mode == "full":
        return x, None, aux
    if attn_caches:
        new_cache["attn"] = jax.tree.map(lambda *a: jnp.stack(a), *attn_caches)
    if mamba_caches:
        new_cache["mamba"] = jax.tree.map(lambda *a: jnp.stack(a), *mamba_caches)
    return x, new_cache, aux


def cache_max_len(cache) -> int:
    """Static max length from an (abstract or real) attn cache tree."""
    return cache["attn"]["k"].shape[-3]


# ---------------------------------------------------------------------------
# stack (scan over blocks)
# ---------------------------------------------------------------------------
def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    elif cfg.remat == "full":
        policy = jax.checkpoint_policies.nothing_saveable
    else:
        raise ValueError(cfg.remat)
    return jax.checkpoint(fn, policy=policy)


def run_stack(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache: dict | None = None,
    cache_len: jax.Array | None = None,
    mode: str = "full",
):
    """x: [B, S, d] hidden states -> (x, new_cache_or_None, aux)."""

    if mode == "full":

        def body(carry, bp):
            h, aux = carry
            h, _, a = apply_block(bp, h, cfg, None, None, "full")
            return (h, aux + a), None

        body = _remat_wrap(body, cfg)
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
            )
        else:
            aux = jnp.zeros((), jnp.float32)
            for i in range(cfg.n_blocks):
                (x, aux), _ = body((x, aux), _tree_index(params["blocks"], i))
        return x, None, aux

    # prefill and decode both stream the cache through scan xs/ys
    def body(carry, xs):
        h, aux = carry
        bp, cslice = xs
        h, nc, a = apply_block(bp, h, cfg, cslice, cache_len, mode)
        return (h, aux + a), nc

    if cfg.scan_layers:
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], cache)
        )
    else:
        aux = jnp.zeros((), jnp.float32)
        caches = []
        for i in range(cfg.n_blocks):
            (x, aux), nc = body(
                (x, aux), (_tree_index(params["blocks"], i), _tree_index(cache, i))
            )
            caches.append(nc)
        new_cache = jax.tree.map(lambda *a: jnp.stack(a), *caches)
    return x, new_cache, aux
