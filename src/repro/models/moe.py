"""Mixture-of-Experts FFN: top-k router + grouped sort-based dispatch.

Dispatch follows the GShard/MaxText *grouped* discipline: tokens are
processed in G = batch groups (one per sequence), each with its own
capacity C = ceil(S*k/E * factor).  Every dispatch step (stable sort by
expert id, intra-expert ranking, capacity scatter) carries the leading G
dim, which is sharded over the DP axes — so the SPMD partitioner keeps the
whole dispatch LOCAL to each data shard and the only cross-shard traffic
is the expert einsum against model-sharded weights.  (A global sort/scatter
formulation compiles to a full-buffer all-reduce across the mesh —
~276 GB/device/layer for grok — which is why groups matter.)

Within a group the dispatch is the modern sort/gather (megablocks-style)
form rather than GShard's one-hot einsums: a [T, E, C] one-hot at 1M
tokens x 128 experts is ~10^12 elements, while the sort route is O(T*k*d).
Out-of-capacity slots scatter out of bounds and are dropped
(capacity-factor policy, as in Switch).

Decode (S == 1): each group is a single token whose k routed experts are
distinct, so C = k guarantees zero drops and decode stays bit-consistent
with teacher forcing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, shard


def moe_specs(cfg: ModelConfig, stacked: tuple[int, ...] = ()) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    lead = tuple("layers" for _ in stacked)
    return {
        "router": ParamSpec(stacked + (d, e), lead + ("ffn_in", "experts")),
        "w_gate": ParamSpec(
            stacked + (e, d, f), lead + ("experts", "expert_in", "expert_mlp")
        ),
        "w_up": ParamSpec(
            stacked + (e, d, f), lead + ("experts", "expert_in", "expert_mlp")
        ),
        "w_down": ParamSpec(
            stacked + (e, f, d), lead + ("experts", "expert_mlp", "expert_in")
        ),
    }


def group_capacity(group_tokens: int, cfg: ModelConfig) -> int:
    if group_tokens == 1:
        return cfg.moe_top_k  # decode: exact, zero drops
    cap = int(
        group_tokens * cfg.moe_top_k / cfg.moe_experts * cfg.moe_capacity_factor
    )
    return max(cap, cfg.moe_top_k)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y: [B, S, d], aux_loss scalar)."""
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    g = b                       # one group per sequence (sharded over DP)
    tg = s * k                  # routed slots per group
    cap = group_capacity(s, cfg)

    # ---- router (f32 numerics) ----------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)           # [B,S,E]
    gate_vals, gate_idx = jax.lax.top_k(probs, k)      # [B,S,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balance auxiliary loss (Switch) ----------------------------------
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # ---- grouped sort-based dispatch (everything keeps the leading G dim) ----
    eids = gate_idx.reshape(g, tg).astype(jnp.int32)          # [G, Tg]
    gates = gate_vals.reshape(g, tg)
    tok = jnp.broadcast_to(jnp.arange(tg, dtype=jnp.int32) // k, (g, tg))
    order = jnp.argsort(eids, axis=1, stable=True)
    eids_s = jnp.take_along_axis(eids, order, axis=1)
    tok_s = jnp.take_along_axis(tok, order, axis=1)
    gates_s = jnp.take_along_axis(gates, order, axis=1)
    counts = jnp.sum(
        (eids[:, :, None] == jnp.arange(e)[None, None, :]), axis=1
    )                                                          # [G, E]
    seg_start = jnp.cumsum(counts, axis=1) - counts
    rank = jnp.arange(tg, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        seg_start, eids_s, axis=1
    ).astype(jnp.int32)
    in_cap = rank < cap
    # out-of-capacity -> out-of-bounds -> scatter mode="drop"
    slot = jnp.where(in_cap, eids_s * cap + rank, e * cap)

    xg = x.reshape(g, s, d)
    xs = jnp.take_along_axis(
        xg, tok_s[:, :, None].astype(jnp.int32), axis=1
    )                                                          # [G, Tg, d]
    gidx = jnp.arange(g, dtype=jnp.int32)[:, None]
    buf = jnp.zeros((g, e * cap, d), dt).at[gidx, slot].set(xs, mode="drop")
    xe = buf.reshape(g, e, cap, d)
    # under EP rules this constraint IS the token all-to-all: xe leaves the
    # moe_group sharding and lands expert-sharded
    xe = shard(xe, "moe_group", "experts", "capacity", "expert_in")

    # ---- expert SwiGLU --------------------------------------------------------------
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dt))
    h = jax.nn.silu(h) * u
    h = shard(h, "moe_group", "experts", "capacity", "expert_mlp")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    ye = shard(ye, "moe_group", "experts", "capacity", "expert_in")

    # ---- combine (un-sort + gate-weighted sum over the k slots) ----------------
    ye_flat = ye.reshape(g, e * cap, d)
    y_s = jnp.take_along_axis(
        ye_flat, jnp.minimum(slot, e * cap - 1)[:, :, None], axis=1
    )
    y_s = y_s * (gates_s * in_cap)[:, :, None].astype(dt)
    y = jnp.zeros((g, s, d), dt).at[gidx, tok_s].add(y_s)
    y = y.reshape(b, s, d)
    return shard(y, "batch", "seq", "act_embed"), aux
