"""NTTD-compressed embedding layer (paper <-> LM integration #2).

Stores NTTD parameters instead of the full [vocab, d] table and
reconstructs only the looked-up rows on the fly — the TT-Rec idea with the
paper's neural generator.  For qwen1.5-4b (152k x 2560 = 389M entries,
1.5GB in f32) an R=8/h=16 NTTD payload is ~1000x smaller; quality is
whatever fitness the offline fit achieved (lossy; measured in
examples/compressed_embedding.py).

The row reconstruction is a batched NTTD decode: token id i -> original
row index -> folded indices of all d columns -> chain products.  Lookup
cost is O(S * d * d' * (h^2 + hR^2)) — serving-practical for prompt
encoding; decode looks up one row per step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as codec_lib
from repro.core import nttd


@dataclasses.dataclass
class NTTDEmbedding:
    """Frozen compressed embedding (built offline from a trained table)."""

    ct: codec_lib.CompressedTensor
    vocab: int
    d_model: int

    @classmethod
    def fit(cls, table: np.ndarray, rank: int = 8, hidden: int = 16,
            epochs: int = 150, seed: int = 0, lr: float = 2e-2,
            batch_size: int = 2048, reorder: bool = True) -> "NTTDEmbedding":
        # reordering matters here: embedding rows have cluster structure but
        # arbitrary ids — exactly the paper's argument for pi (token-id
        # remapping costs one permutation, stored in the payload)
        ct, _ = codec_lib.compress(
            table.astype(np.float32),
            codec_lib.CodecConfig(
                rank=rank, hidden=hidden, epochs=epochs, seed=seed, lr=lr,
                batch_size=min(batch_size, table.size),
                entries_per_epoch=min(table.size, 4_000_000),
                init_reorder=reorder, update_reorder=reorder,
                # space out pi sweeps: each one reinitializes Adam (paper
                # Alg. 1), so theta needs room to converge in between
                reorder_every=10, reorder_warmup=30,
                patience=40,
            ),
        )
        return cls(ct=ct, vocab=table.shape[0], d_model=table.shape[1])

    def lookup(self, token_ids: jax.Array) -> jax.Array:
        """token_ids [B, S] -> embeddings [B, S, d] (reconstructed)."""
        b, s = token_ids.shape
        flat = token_ids.reshape(-1)
        # positions in the reordered tensor
        inv_rows = jnp.asarray(np.argsort(self.ct.pi[0]))
        inv_cols = jnp.asarray(np.argsort(self.ct.pi[1]))
        rows = inv_rows[flat]                                   # [B*S]
        cols = inv_cols[jnp.arange(self.d_model)]               # [d]
        pos = jnp.stack(
            [
                jnp.repeat(rows, self.d_model),
                jnp.tile(cols, flat.shape[0]),
            ],
            axis=1,
        )
        vals = nttd.apply_at_positions(
            self.ct.params, pos.astype(jnp.int32), self.ct.spec, self.ct.cfg
        )
        vals = vals * self.ct.norm_std + self.ct.norm_mean
        return vals.reshape(b, s, self.d_model)

    def payload_bytes(self) -> int:
        return self.ct.payload_bytes(4)

    def raw_bytes(self) -> int:
        return self.vocab * self.d_model * 4
