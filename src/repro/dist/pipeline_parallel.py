"""GPipe-style pipeline parallelism over one mesh axis.

The stack's layers are split into S = mesh.shape[axis] contiguous stages
(``split_stages``); each device owns one stage's weights and the M
microbatches stream through the ring (``pipeline_forward``).  The
schedule is the classic GPipe fill-drain: M + S - 1 ticks, every tick
each device applies its stage and ``ppermute``s the activation to the
next stage.  Device i holds microbatch (t - i) at tick t, so the bubble
fraction is (S - 1) / (M + S - 1).

Devices do run their stage on ring-garbage during fill/drain ticks — the
standard trick that keeps the loop body collective-uniform (every device
executes the same ppermute each tick, which is what SPMD requires); the
garbage lineages are never written to the output buffer.

``fn(stage_params, x) -> y`` must preserve the activation shape (true
for residual stacks), since the same buffer carries every stage's
activation around the ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P


def split_stages(params, n_stages: int):
    """Split each leaf's leading (layer) dim into [n_stages, L/n_stages, ...]."""

    def split(a):
        if a.shape[0] % n_stages:
            raise ValueError(
                f"layer dim {a.shape[0]} not divisible by {n_stages} stages"
            )
        return a.reshape((n_stages, a.shape[0] // n_stages) + a.shape[1:])

    return jax.tree.map(split, params)


def pipeline_forward(fn, stage_params, microbatches, mesh: Mesh, axis: str = "pod"):
    """Run ``fn`` as an S-stage pipeline over ``mesh.shape[axis]``.

    stage_params: tree of [S, ...] leaves (see ``split_stages``), sharded
    so device i holds stage i.  microbatches: [M, mb, ...].  Returns the
    [M, mb, ...] outputs of the final stage, replicated over ``axis``.
    """
    n_stages = mesh.shape[axis]
    m = microbatches.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def run(params, x):
        params = jax.tree.map(lambda a: jnp.squeeze(a, 0), params)  # [1,...] local
        stage = jax.lax.axis_index(axis)

        def tick(t, carry):
            state, out = carry
            # stage 0 injects microbatch t (clamped past M: drain garbage,
            # its lineage exits the loop before reaching the last stage)
            inject = jax.lax.dynamic_index_in_dim(
                x, jnp.minimum(t, m - 1), 0, keepdims=False
            )
            y = fn(params, jnp.where(stage == 0, inject, state))
            done = t - (n_stages - 1)  # microbatch finishing this tick
            write = jnp.logical_and(stage == n_stages - 1, done >= 0)
            out = jnp.where(write, out.at[jnp.maximum(done, 0)].set(y), out)
            return jax.lax.ppermute(y, axis, perm), out

        carry = (jnp.zeros_like(x[0]), jnp.zeros_like(x))
        _, out = jax.lax.fori_loop(0, m + n_stages - 1, tick, carry)
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(
            jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out)), axis
        )

    return shard_map(
        run,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, microbatches)
