"""Logical-axis sharding: ParamSpec trees, rules tables, late mesh binding.

Weights are declared once as ``ParamSpec(shape, logical_axes, init)``
trees; activations are constrained in-model with ``shard(x, *axes)``.
Nothing in the model code names a mesh axis — the rules tables below bind
logical axes to mesh axes at jit/lower time, so the same model definition
runs replicated on one CPU device or 3D-sharded on a multi-pod mesh.

Resolution semantics (``logical_pspec``):
  * rules map a logical axis to a mesh axis name, a tuple of names, or
    ``None`` (replicate); axes missing from the table replicate too;
  * mesh axes not present in the target mesh are dropped (e.g. 'pod' on a
    single-pod mesh);
  * a mesh axis consumed by an earlier dim of the same tensor is skipped
    (PartitionSpecs must not repeat a mesh axis);
  * when the tensor shape is known, a dim that the mapped axis product
    does not divide evenly falls back to replication (smoke shapes on
    production meshes).

``shard`` only constrains inside a ``sharding_ctx`` — outside it is an
identity, which is what keeps single-device tests oblivious to SPMD.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
import zlib
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative leaf: shape + logical axis names + init kind.

    init: 'fan_in' (scaled normal), 'embed', 'ones', 'zeros'.
    dtype: overrides the tree-level default (KV caches, SSM states).
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "fan_in"
    dtype: Any = None

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec rank mismatch: shape {self.shape} vs axes {self.axes}"
            )


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# ---------------------------------------------------------------------------
# rules tables (logical axis -> mesh axis | tuple of mesh axes | None)
# ---------------------------------------------------------------------------
# Megatron-style tensor parallelism on 'model', data parallelism on
# ('pod', 'data').  Weights stay unsharded on their input dims (pure TP);
# FSDP_RULES below adds the ZeRO-3 weight sharding over the DP axes.
BASE_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,          # -> 'model' (Megatron SP) via effective_rules
    "seq_attn": None,     # -> 'model' for context-parallel attention cells
    "act_embed": None,
    # embedding / unembedding
    "vocab": "model",
    "embed": None,
    # stacked-layer and generic weight dims
    "layers": None,
    "ffn_in": None,
    "mlp": "model",
    # attention
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    # KV cache; kv_seq flips to 'data'/'model' per-cell (flash-decode)
    "kv_seq": None,
    "long_kv": "data",
    # MoE: dispatch groups ride the DP axes (keeps the sort/scatter local),
    # expert weights are TP-sharded on their hidden dim like dense MLPs
    "moe_group": ("pod", "data"),
    "experts": None,
    "expert_in": None,
    "expert_mlp": "model",
    "capacity": None,
    # Mamba / SSD
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_head_dim": None,
    "ssm_state": None,
    "conv_k": None,
}

# ZeRO-3/FSDP: additionally shard every weight's input dim over the DP
# axes (gathered bf16 per use; see train.step loss_with_cast).  Experts
# move to 'model' (expert parallelism); 'expert_mlp' then loses 'model'
# via the first-dim-wins fallback, so expert weights gather only over
# 'data' on their d_model dim.
FSDP_RULES: dict[str, Any] = dict(
    BASE_RULES,
    ffn_in=("pod", "data"),
    embed=("pod", "data"),
    experts="model",
    expert_in=("pod", "data"),
)


# ---------------------------------------------------------------------------
# rule resolution
# ---------------------------------------------------------------------------
def _rule_axes(logical: str | None, rules: dict) -> tuple[str, ...]:
    if logical is None:
        return ()
    r = rules.get(logical)
    if r is None:
        return ()
    if isinstance(r, str):
        return (r,)
    return tuple(r)


def logical_pspec(
    axes: tuple[str | None, ...],
    rules: dict,
    mesh: Mesh,
    shape: tuple[int, ...] | None = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec on ``mesh``.

    With ``shape`` given, dims the mapped mesh-axis product does not
    divide evenly are replicated instead (all-or-nothing per dim).
    """
    used: set[str] = set()
    parts: list[Any] = []
    for i, logical in enumerate(axes):
        cand = [
            m
            for m in _rule_axes(logical, rules)
            if m in mesh.axis_names and m not in used
        ]
        if cand and shape is not None:
            if shape[i] % math.prod(mesh.shape[m] for m in cand) != 0:
                cand = []
        used.update(cand)
        if not cand:
            parts.append(None)
        elif len(cand) == 1:
            parts.append(cand[0])
        else:
            parts.append(tuple(cand))
    return P(*parts)


# ---------------------------------------------------------------------------
# sharding context + activation constraints
# ---------------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: dict):
    """Bind (mesh, rules) for ``shard`` constraints traced inside."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, dict(rules))
    try:
        yield
    finally:
        _CTX.val = prev


def current_ctx() -> tuple[Mesh, dict] | None:
    return getattr(_CTX, "val", None)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain ``x`` to its logical axes under the active sharding_ctx.

    Identity when no context is active (single-device tests, benches).
    """
    if x.ndim != len(axes):
        # validate even on the no-context identity path, so single-device
        # tests catch a bad annotation before it first lowers under a mesh
        raise ValueError(f"shard: rank {x.ndim} tensor with axes {axes}")
    ctx = current_ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_pspec(axes, rules, mesh, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# spec-tree operations
# ---------------------------------------------------------------------------
def tree_shardings(mesh: Mesh, specs, rules: dict):
    """ParamSpec tree -> NamedSharding tree (divisibility-checked)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_pspec(s.axes, rules, mesh, s.shape)),
        specs,
        is_leaf=_is_spec,
    )


def tree_abstract(specs, dtype):
    """ParamSpec tree -> ShapeDtypeStruct tree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs,
        is_leaf=_is_spec,
    )


def _stacked_fan_in(spec: ParamSpec) -> int:
    # fan-in = every non-output dim that is not a stacked-layer or a
    # vmapped expert dim; the last dim is the output by convention
    # (matches 2D weights exactly; depthwise convs get fan_in = k).
    # q/k/v projections fuse two output dims (heads, head_dim): a heads
    # dim right before a final head_dim is output, not fan-in — while in
    # wo-style (heads, head_dim, d) weights the heads dim IS fan-in.
    fan = 1
    n = len(spec.axes)
    for i, (dim, ax) in enumerate(zip(spec.shape[:-1], spec.axes[:-1])):
        if ax in ("layers", "experts"):
            continue
        if ax in ("heads", "kv_heads") and i == n - 2 and spec.axes[-1] == "head_dim":
            continue
        fan *= dim
    return fan


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        # unit-variance logits under tied unembedding (x is rmsnormed)
        std = spec.shape[-1] ** -0.5
    elif spec.init == "fan_in":
        std = _stacked_fan_in(spec) ** -0.5
    else:
        raise ValueError(f"unknown init kind: {spec.init!r}")
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def materialize(key: jax.Array, specs, dtype):
    """ParamSpec tree -> real weights.  Per-leaf keys are derived from the
    tree path, so adding a parameter never reshuffles the others."""

    def init_at(path, spec):
        leaf_key = jax.random.fold_in(
            key, zlib.crc32(jax.tree_util.keystr(path).encode())
        )
        return _init_leaf(leaf_key, spec, spec.dtype or dtype)

    return jax.tree_util.tree_map_with_path(init_at, specs, is_leaf=_is_spec)
