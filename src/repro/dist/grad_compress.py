"""Gradient compressors with persistent error feedback.

Both compressors follow the EF-SGD discipline (Seide et al. 2014;
Karimireddy et al. 2019): the quantization/sparsification residual is
kept per-leaf and added back to the next step's gradient, so compression
error accumulates into later updates instead of being lost — unbiased in
the long run, which is what lets Adam converge through a lossy channel.

Contract (matches the optimizer hook in ``train.step.make_train_step``
and the trainer in ``launch.train``):

    comp = ErrorFeedbackInt8()          # or TopK(0.05)
    state = comp.init(params)           # f32 residual tree, shards like params
    grads, state = comp.transform(grads, state)   # inside jit, per step

``transform`` returns *decompressed* gradients: the wire format (int8
values + per-leaf scale, or a thresholded sparse leaf) only exists inside
the per-leaf kernels, since on a real mesh the cheap representation is
what crosses the DP all-reduce and both endpoints are in the same jit.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _zeros_like_f32(tree):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), tree)


def _map_unzip(fn, grads, state):
    """Apply ``fn(g, e) -> (g', e')`` per leaf; return the two trees."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state)
    pairs = [fn(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([p[0] for p in pairs]),
        treedef.unflatten([p[1] for p in pairs]),
    )


class ErrorFeedbackInt8:
    """Symmetric per-leaf int8 quantization with error feedback.

    Each leaf is scaled by max|g|/127 and rounded to int8; the rounding
    error goes into the residual.  8x smaller DP all-reduce payload than
    f32 gradients at <1% relative error per step.
    """

    def init(self, params):
        return _zeros_like_f32(params)

    @staticmethod
    def _leaf(g, e):
        acc = g.astype(jnp.float32) + e
        scale = jnp.max(jnp.abs(acc)) / 127.0
        q = jnp.round(acc / jnp.where(scale > 0, scale, 1.0))
        q = jnp.clip(q, -127, 127).astype(jnp.int8)
        deq = (q.astype(jnp.float32) * scale).astype(g.dtype)
        # residual measured against the dtype the optimizer actually sees,
        # so low-precision cast error feeds back too instead of drifting
        return deq, acc - deq.astype(jnp.float32)

    def transform(self, grads, state):
        return _map_unzip(self._leaf, grads, state)


class TopK:
    """Keep the top ``fraction`` of entries per leaf (by magnitude); the
    rest accumulate in the residual and re-surface on later steps."""

    def __init__(self, fraction: float):
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1]: {fraction}")
        self.fraction = fraction

    def init(self, params):
        return _zeros_like_f32(params)

    def _leaf(self, g, e):
        acc = g.astype(jnp.float32) + e
        k = max(1, math.ceil(acc.size * self.fraction))  # python int: static
        thresh = jax.lax.top_k(jnp.abs(acc).reshape(-1), k)[0][-1]
        kept = jnp.where(jnp.abs(acc) >= thresh, acc, 0.0).astype(g.dtype)
        return kept, acc - kept.astype(jnp.float32)

    def transform(self, grads, state):
        return _map_unzip(self._leaf, grads, state)
