"""Distribution layer: logical-axis sharding, gradient compression,
pipeline parallelism.

Every weight and activation in the model zoo is annotated with *logical*
axis names (``ParamSpec`` for weights, ``shard(x, *axes)`` for
activations) rather than mesh axes.  A rules table (``BASE_RULES`` /
``FSDP_RULES``, or a per-cell variant from ``train.step.effective_rules``)
maps each logical axis to zero or more mesh axes; resolution happens late,
against a concrete ``jax.sharding.Mesh``:

* a logical axis whose mesh axes are absent from the mesh (e.g. 'pod' on
  a single-pod mesh) silently falls back to replication,
* a mesh axis already consumed by an earlier dimension of the same tensor
  is skipped (first dimension wins),
* a dimension whose size does not divide the mapped mesh-axis product is
  replicated (smoke configs on big meshes just lose that sharding).

This keeps one model definition valid on every mesh from a single CPU
device (rules resolve to fully-replicated, ``shard`` is a no-op outside
``sharding_ctx``) up to the multi-pod production mesh in ``launch.mesh``.

Submodules:
    sharding          ParamSpec, rules tables, tree materialize/abstract
    grad_compress     error-feedback int8 / top-k gradient compressors
    pipeline_parallel GPipe-style microbatched pipeline over a mesh axis
"""
from repro.dist import sharding  # noqa: F401  (the load-bearing module)
