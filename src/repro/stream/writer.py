"""Chunked container-v3 writer: append payload bytes as fit progresses.

``ChunkedWriter`` writes the v3 header up front, appends chunks as the
producer emits them (a finalized TT core, an accumulating fitter's
partial body, a periodic snapshot), and seals the file with the footer
chunk index on ``close`` — append-only, no seeking back to patch a
length field, so a crash leaves a file that is cleanly rejected rather
than silently half-read.

The concatenated chunks are the codec's ``Encoded.to_bytes()`` body;
``write_chunked`` is the convenience that splits a finished payload into
fixed-size chunks, which keeps the serve layer's lazy loader
(``CodecService.load_stream``) from ever needing one giant read.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.codecs import container
from repro.codecs.base import Encoded


class ChunkedWriter:
    def __init__(self, path: str, codec_name: str):
        self.path = path
        self.codec_name = codec_name
        self._chunks: list[container.ChunkEntry] = []
        self._f = open(path, "wb")
        self._offset = self._f.write(container.pack_header(codec_name,
                                                          container.FLAG_CHUNKED))
        self._closed = False

    def append(
        self, chunk: bytes, entry_range: tuple[int, int] | None = None
    ) -> int:
        """Append one chunk; returns its index in the footer.

        ``entry_range=(start, stop)`` records the flat-entry span this
        chunk ROUTES for (footer ``TCDR`` block) — the partition of the
        index space the fleet router shards ownership by.  Ranges are
        all-or-nothing across chunks: the footer drops them unless every
        chunk has one.
        """
        if self._closed:
            raise ValueError(f"{self.path}: writer already closed")
        if not chunk:
            raise ValueError("empty chunk")
        start, stop = (None, None) if entry_range is None else map(int, entry_range)
        if start is not None and not 0 <= start < stop:
            raise ValueError(f"bad entry_range ({start}, {stop})")
        self._f.write(chunk)
        self._chunks.append(
            container.ChunkEntry(
                self._offset, len(chunk), zlib.crc32(chunk) & 0xFFFFFFFF,
                start, stop,
            )
        )
        self._offset += len(chunk)
        return len(self._chunks) - 1

    @property
    def chunks_written(self) -> int:
        return len(self._chunks)

    def close(self) -> int:
        """Seal the file with the footer index; returns total file bytes."""
        if self._closed:
            return self._offset
        self._f.write(container.pack_footer(self._chunks))
        self._offset = self._f.tell()
        self._f.close()
        self._closed = True
        return self._offset

    def __enter__(self) -> "ChunkedWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # don't seal a half-written file as valid
            self._f.close()
            self._closed = True


def write_chunked(path: str, enc: Encoded, chunk_bytes: int = 1 << 20) -> int:
    """Write a finished payload as a chunked v3 file; returns file bytes.

    Each byte chunk is stamped with an equal slice of the tensor's flat
    entry space (chunk i of n routes entries ``[i*E/n, (i+1)*E/n)``) so a
    fleet router can shard query ownership chunk-by-chunk without any
    knowledge of the codec's body layout.
    """
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    body = enc.to_bytes()
    if not body:
        raise ValueError("empty payload body")
    n_entries = int(np.prod(enc.shape))
    n_chunks = -(-len(body) // chunk_bytes)
    with ChunkedWriter(path, enc.codec_name) as w:
        for i, off in enumerate(range(0, len(body), chunk_bytes)):
            lo = i * n_entries // n_chunks
            hi = (i + 1) * n_entries // n_chunks
            w.append(
                body[off : off + chunk_bytes],
                entry_range=(lo, hi) if hi > lo else None,
            )
        return w.close()
