"""Chunked container writer: append payload bytes as fit progresses.

``ChunkedWriter`` writes the header up front, appends chunks as the
producer emits them (a finalized TT core, an accumulating fitter's
partial body, a periodic snapshot), and seals the file with the footer
chunk index on ``close`` — append-only, no seeking back to patch a
length field, so a crash leaves a file that is cleanly rejected rather
than silently half-read.

Two modes:

* default (container v3): the concatenated chunks are one codec's
  ``Encoded.to_bytes()`` body; ``write_chunked`` is the convenience that
  splits a finished payload into fixed-size chunks, which keeps the serve
  layer's lazy loader (``CodecService.load_stream``) from ever needing
  one giant read.
* ``delta=True`` (container v4): the file holds a SEQUENCE of bodies.
  ``begin_version(base)`` opens a version (``base=-1`` keyframe, else a
  residual against version ``base``); subsequent ``append`` calls belong
  to it; the footer's ``TCDV`` block records the per-version chunk
  ranges.  ``sync()`` is an opt-in durability point: it ends the open
  version and writes a footer NOW, leaving a valid readable file while
  the writer stays open — the next ``append`` truncates that footer and
  keeps going, so a crash mid-version loses only the unsynced tail.
  ``repro.temporal.VersionedStore`` builds on this.

Either mode can additionally record HELD-OUT ground truth for the serve
layer's online fitness canaries: ``record_heldout(flat_indices, values)``
accumulates exact original-tensor entries that every sync/close folds
into the footer's optional ``TCDQ`` block.  ``write_chunked`` takes the
same sample via ``heldout=``; files written without one parse exactly as
before (the block is optional), so old readers and old files both keep
working.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from repro.codecs import container
from repro.codecs.base import Encoded


class ChunkedWriter:
    def __init__(self, path: str, codec_name: str, *, delta: bool = False):
        self.path = path
        self.codec_name = codec_name
        self.delta = delta
        self._chunks: list[container.ChunkEntry] = []
        self._versions: list[container.VersionEntry] | None = [] if delta else None
        self._heldout_idx: list[np.ndarray] = []
        self._heldout_vals: list[np.ndarray] = []
        self._open_base: int | None = None
        self._open_start = 0
        flags = container.FLAG_CHUNKED | (container.FLAG_DELTA if delta else 0)
        version = container.DELTA_VERSION if delta else container.VERSION
        self._f = open(path, "w+b")
        self._offset = self._f.write(
            container.pack_header(codec_name, flags, version)
        )
        self._sealed = False  # a valid footer currently trails the data
        self._closed = False

    # -- delta versions ----------------------------------------------------
    def begin_version(self, base: int = -1) -> int:
        """Open version ``len(versions)``; returns its id.

        ``base=-1`` marks a keyframe; ``base=k`` a residual whose decode
        adds onto version ``k``'s.  Closes the previously open version
        (which must have received at least one chunk).
        """
        if not self.delta:
            raise ValueError(f"{self.path}: begin_version needs delta=True")
        if self._closed:
            raise ValueError(f"{self.path}: writer already closed")
        self._end_version()
        vid = len(self._versions)
        base = int(base)
        if vid == 0 and base != -1:
            raise ValueError(f"{self.path}: version 0 must be a keyframe (base=-1)")
        if not -1 <= base < vid:
            raise ValueError(f"{self.path}: bad base {base} for version {vid}")
        self._open_base = base
        self._open_start = len(self._chunks)
        return vid

    def _end_version(self) -> None:
        if self._open_base is None:
            return
        if len(self._chunks) == self._open_start:
            raise ValueError(
                f"{self.path}: version {len(self._versions)} has no chunks"
            )
        self._versions.append(
            container.VersionEntry(
                self._open_base, self._open_start, len(self._chunks)
            )
        )
        self._open_base = None

    # -- chunk appends -----------------------------------------------------
    def append(
        self, chunk: bytes, entry_range: tuple[int, int] | None = None
    ) -> int:
        """Append one chunk; returns its index in the footer.

        ``entry_range=(start, stop)`` records the flat-entry span this
        chunk ROUTES for (footer ``TCDR`` block) — the partition of the
        index space the fleet router shards ownership by (per version, in
        delta mode).  Ranges are all-or-nothing across chunks: the footer
        drops them unless every chunk has one.
        """
        if self._closed:
            raise ValueError(f"{self.path}: writer already closed")
        if self.delta and self._open_base is None:
            raise ValueError(
                f"{self.path}: append outside begin_version in delta mode"
            )
        if not chunk:
            raise ValueError("empty chunk")
        start, stop = (None, None) if entry_range is None else map(int, entry_range)
        if start is not None and not 0 <= start < stop:
            raise ValueError(f"bad entry_range ({start}, {stop})")
        self._unseal()
        self._f.write(chunk)
        self._chunks.append(
            container.ChunkEntry(
                self._offset, len(chunk), zlib.crc32(chunk) & 0xFFFFFFFF,
                start, stop,
            )
        )
        self._offset += len(chunk)
        return len(self._chunks) - 1

    def record_heldout(
        self, flat_indices: np.ndarray, values: np.ndarray
    ) -> int:
        """Accumulate held-out ground-truth entries (flat index + exact
        original value) for the footer's ``TCDQ`` block; returns the total
        recorded so far.  Call any time before close — typically at fit
        time, when the original values are still in hand.  Re-sealing
        (``sync``) folds everything recorded so far into the footer."""
        if self._closed:
            raise ValueError(f"{self.path}: writer already closed")
        idx = np.asarray(flat_indices, dtype=np.int64).reshape(-1)
        vals = np.asarray(values, dtype=np.float64).reshape(-1)
        if len(idx) != len(vals):
            raise ValueError(
                f"held-out indices/values length mismatch: {len(idx)} != {len(vals)}"
            )
        if len(idx):
            if int(idx.min()) < 0:
                raise ValueError("held-out flat indices must be non-negative")
            self._heldout_idx.append(idx)
            self._heldout_vals.append(vals)
            self._unseal()  # a synced footer no longer reflects the sample
        return self.heldout_recorded

    @property
    def heldout_recorded(self) -> int:
        return sum(len(a) for a in self._heldout_idx)

    def _heldout(self) -> container.HeldoutEntries | None:
        if not self._heldout_idx:
            return None
        return container.HeldoutEntries(
            np.concatenate(self._heldout_idx), np.concatenate(self._heldout_vals)
        )

    def _unseal(self) -> None:
        """Drop a footer written by an earlier ``sync`` so appends resume
        at the data end; the next sync/close writes a fresh footer."""
        if self._sealed:
            self._f.seek(self._offset)
            self._f.truncate()
            self._sealed = False

    @property
    def chunks_written(self) -> int:
        return len(self._chunks)

    @property
    def versions_written(self) -> int:
        return len(self._versions or ())

    # -- sealing -----------------------------------------------------------
    def sync(self) -> int:
        """Write a footer NOW without closing; returns current file bytes.

        Ends the open version first (delta mode).  The file is valid and
        readable from this moment even if the process dies — appends made
        after the last ``sync`` are the only thing a crash can lose.
        """
        if self._closed:
            raise ValueError(f"{self.path}: writer already closed")
        if self.delta:
            self._end_version()
            if not self._versions:
                raise ValueError(f"{self.path}: no versions to sync")
        if not self._sealed:
            self._f.write(
                container.pack_footer(self._chunks, self._versions, self._heldout())
            )
            self._f.flush()
            self._sealed = True
        return self._f.tell()

    def close(self) -> int:
        """Seal the file with the footer index; returns total file bytes."""
        if self._closed:
            return self._offset
        if self.delta:
            self._end_version()
            if not self._versions:
                raise ValueError(
                    f"{self.path}: delta file needs at least one version"
                )
        if not self._sealed:
            self._f.write(
                container.pack_footer(self._chunks, self._versions, self._heldout())
            )
        self._offset = self._f.tell()
        self._f.close()
        self._closed = True
        return self._offset

    def __enter__(self) -> "ChunkedWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # don't seal a half-written file as valid
            self._f.close()
            self._closed = True


def write_chunked(
    path: str,
    enc: Encoded,
    chunk_bytes: int = 1 << 20,
    heldout: tuple[np.ndarray, np.ndarray] | None = None,
) -> int:
    """Write a finished payload as a chunked v3 file; returns file bytes.

    Each byte chunk is stamped with an equal slice of the tensor's flat
    entry space (chunk i of n routes entries ``[i*E/n, (i+1)*E/n)``) so a
    fleet router can shard query ownership chunk-by-chunk without any
    knowledge of the codec's body layout.

    ``heldout=(flat_indices, values)`` records ground-truth ORIGINAL
    tensor entries into the footer's ``TCDQ`` block so the serve layer
    can run online fitness canaries against this file.  The values must
    come from the source tensor, not the codec's own decode — comparing
    a codec against itself would report perfect fitness forever.
    """
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    body = enc.to_bytes()
    if not body:
        raise ValueError("empty payload body")
    n_entries = int(np.prod(enc.shape))
    n_chunks = -(-len(body) // chunk_bytes)
    with ChunkedWriter(path, enc.codec_name) as w:
        if heldout is not None:
            idx = np.asarray(heldout[0], dtype=np.int64).reshape(-1)
            if len(idx) and int(idx.max()) >= n_entries:
                raise ValueError(
                    f"held-out flat index {int(idx.max())} out of range "
                    f"[0, {n_entries})"
                )
            w.record_heldout(idx, heldout[1])
        for i, off in enumerate(range(0, len(body), chunk_bytes)):
            lo = i * n_entries // n_chunks
            hi = (i + 1) * n_entries // n_chunks
            w.append(
                body[off : off + chunk_bytes],
                entry_range=(lo, hi) if hi > lo else None,
            )
        return w.close()


def _sealed_state(path: str):
    """Parse a sealed chunked file for mutation: footer contents plus the
    data end (where the footer starts) so a rewrite can truncate-and-reseal
    exactly the way ``ChunkedWriter._unseal``/``sync`` do."""
    oc = container.open_container(path)
    try:
        if not (oc.flags & container.FLAG_CHUNKED):
            raise ValueError(f"{path}: monolithic container cannot be rewritten")
        state = (oc.codec, list(oc.chunks), oc.versions, oc.heldout,
                 list(oc.patches))
    finally:
        oc.close()
    with open(path, "rb") as f:
        f.seek(-container._TRAILER_LEN, 2)
        trailer_at = f.tell()
        (footer_len,) = struct.unpack("<Q", f.read(8))
    return (*state, trailer_at - footer_len)


def rewrite_chunks(path: str, replacements: dict[int, bytes]) -> None:
    """Replace named chunks' BYTES in a sealed chunked file, in place.

    The read-repair swap primitive: a same-length replacement (the exact
    restore of a corrupt chunk from a replica's materialized body) is
    written at the chunk's original offset — every other byte of the file,
    footer included, is preserved verbatim.  A different-length replacement
    is appended at the data end and the chunk's index entry re-pointed
    (its id, entry range, and position in the footer never change, so
    routing tables stay valid); the old bytes become an unreferenced hole.
    Either way the footer is truncated and resealed, so a crash mid-rewrite
    leaves a file that is cleanly rejected, never silently half-patched.
    Live mmap readers keep their parsed index: same-length rewrites become
    visible to them byte-for-byte, relocations stay invisible until they
    re-open — both consistent states, which is what lets a fleet swap a
    repaired chunk under traffic (``repro.fleet.repair``).
    """
    if not replacements:
        return
    codec, chunks, versions, heldout, patches, data_end = _sealed_state(path)
    for cid in replacements:
        if not 0 <= cid < len(chunks):
            raise ValueError(f"{path}: no chunk {cid} to rewrite")
        if not replacements[cid]:
            raise ValueError(f"{path}: empty replacement for chunk {cid}")
    with open(path, "r+b") as f:
        f.seek(data_end)
        f.truncate()  # unseal: drop the footer before mutating the index
        end = data_end
        for cid in sorted(replacements):
            raw = replacements[cid]
            c = chunks[cid]
            crc = zlib.crc32(raw) & 0xFFFFFFFF
            if len(raw) == c.length:
                f.seek(c.offset)
                f.write(raw)
                chunks[cid] = dataclasses.replace(c, crc=crc)
            else:
                f.seek(end)
                f.write(raw)
                chunks[cid] = container.ChunkEntry(
                    end, len(raw), crc, c.entry_start, c.entry_stop
                )
                end += len(raw)
        f.seek(end)
        f.write(container.pack_footer(chunks, versions, heldout, patches))
        f.flush()


def append_patch(
    path: str,
    body: bytes,
    entry_range: tuple[int, int],
    codec_name: str,
    chunk_bytes: int = 1 << 20,
) -> int:
    """Append a read-repair overlay to a sealed v3 file; returns its patch
    index in the ``TCDP`` block.

    ``body`` is the overlay payload's ``Encoded.to_bytes()`` — a
    stand-alone tensor holding exactly ``entry_stop - entry_start``
    entries whose decode REPLACES the base payload over ``entry_range``
    (see ``container.PatchEntry``).  The overlay's chunks join the chunk
    index as a suffix; base chunks are not touched, which is the whole
    point: untouched entry ranges keep decoding bit-identically after the
    repair.  Delta (v4) containers are rejected — repairing a version
    chain goes through exact chunk restore (``rewrite_chunks``), never an
    overlay.
    """
    lo, hi = int(entry_range[0]), int(entry_range[1])
    if not 0 <= lo < hi:
        raise ValueError(f"{path}: bad patch entry_range ({lo}, {hi})")
    if not body:
        raise ValueError(f"{path}: empty patch body")
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    codec, chunks, versions, heldout, patches, data_end = _sealed_state(path)
    if versions is not None:
        raise ValueError(f"{path}: cannot patch a delta container")
    n_base = container.patch_base_count(len(chunks), patches)
    stops = [c.entry_stop for c in chunks[:n_base] if c.entry_stop is not None]
    if stops and hi > max(stops):
        raise ValueError(
            f"{path}: patch entry_range ({lo}, {hi}) exceeds the payload's "
            f"{max(stops)} entries"
        )
    with open(path, "r+b") as f:
        f.seek(data_end)
        f.truncate()
        cstart = len(chunks)
        off = data_end
        for at in range(0, len(body), chunk_bytes):
            raw = body[at : at + chunk_bytes]
            f.write(raw)
            chunks.append(container.ChunkEntry(
                off, len(raw), zlib.crc32(raw) & 0xFFFFFFFF, lo, hi
            ))
            off += len(raw)
        patches.append(container.PatchEntry(
            lo, hi, cstart, len(chunks), codec_name
        ))
        f.write(container.pack_footer(chunks, versions, heldout, patches))
        f.flush()
    return len(patches) - 1


def sample_heldout(
    x: np.ndarray, n: int = 256, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic held-out sample of a dense source tensor: ``n``
    distinct flat indices (sorted) and their exact values, ready for
    ``write_chunked(..., heldout=...)`` / ``record_heldout``."""
    flat = np.asarray(x).reshape(-1)
    n = min(int(n), flat.size)
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.choice(flat.size, size=n, replace=False)).astype(np.int64)
    return idx, flat[idx].astype(np.float64)
