"""Incremental fitters behind the ``Codec.fit_stream`` hook.

``fit_stream(name, source, budget)`` is the one entry point; it
dispatches to the named codec's ``stream_fitter``:

  * NTTD — warm-started minibatch SGD (paper §IV-B Alg. 2) over arriving
    slabs.  Each slab trains a few scan-jitted Adam steps whose batches
    mix fresh slab entries with a seeded reservoir replay buffer, so early
    slabs are not forgotten once they leave memory.  Mode orderings start
    identity (the TSP init needs the full tensor); ``refine_orders``
    optionally recomputes them mid-stream from the reservoir sample (or a
    caller-provided dense estimate) — the read-repair refit path uses
    this.  Normalization constants are frozen from the first slab.
  * TT — a TT-ICE-style update (Aksoy et al., *An Incremental Tensor
    Train Decomposition Algorithm*): an orthonormal row-space basis is
    expanded by each slab's residual directions (rank-capped), and
    ``finalize`` TT-SVDs the small basis tensor back into cores.
  * everything else — the default accumulate-then-``fit`` fallback in
    ``codecs/base.py``.

Every fitter is deterministic in the slab sequence: per-slab RNG is
seeded from ``(seed, slab_index)`` exactly like ``data/pipeline.py``
seeds ``batch_at(step)``, so resuming from a source cursor reproduces an
uninterrupted run bit-for-bit.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.codecs.base import Encoded, StreamFitter, get_codec
from repro.core import codec as codec_lib
from repro.core import nttd, reorder, ttd
from repro.core.folding import make_folding_spec
from repro.optim import optimizers


def fit_stream(codec_name: str, source, budget: int | None = None, **opts) -> Encoded:
    """Fit the named codec over a :class:`repro.stream.SlabSource`."""
    return get_codec(codec_name).fit_stream(source, budget, **opts)


# ---------------------------------------------------------------------------
# NTTD: warm-started minibatch SGD + reservoir replay
# ---------------------------------------------------------------------------
class NTTDStreamFitter(StreamFitter):
    def __init__(
        self,
        shape: tuple[int, ...],
        rank: int = 8,
        hidden: int | None = None,
        d_prime: int | None = None,
        *,
        lr: float = 5e-3,
        batch_size: int = 8192,
        steps_per_slab: int = 4,
        replay_capacity: int = 1 << 16,
        replay_fraction: float = 0.5,
        seed: int = 0,
        kernel_impl: str = "ref",
        normalize: bool = True,
    ):
        self.shape = tuple(int(s) for s in shape)
        self.spec = make_folding_spec(self.shape, d_prime)
        self.cfg = nttd.NTTDConfig(
            rank=rank, hidden=hidden or 2 * rank, kernel_impl=kernel_impl
        )
        self.seed = int(seed)
        self.batch_size = int(batch_size)
        self.steps_per_slab = int(steps_per_slab)
        self.replay_fraction = float(replay_fraction)
        self.normalize = normalize
        self.params = nttd.init_params(jax.random.PRNGKey(self.seed), self.spec, self.cfg)
        self._opt = optimizers.adam(lr)
        self._opt_state = self._opt.init(self.params)
        self._epoch = codec_lib._make_train_epoch(self.spec, self.cfg, self._opt)
        d = len(self.shape)
        cap = int(replay_capacity)
        self._rpos = np.zeros((cap, d), dtype=np.int64)
        self._rval = np.zeros((cap,), dtype=np.float32)
        self._rfill = 0
        self.entries_seen = 0
        self.slabs_seen = 0
        self._mean: float | None = None
        self._std = 1.0
        #: per-mode orders (pi[k][pos] = original index); identity until a
        #: refine_orders call installs TSP-derived ones.  _inv is the lazy
        #: original->position map, None while orders are still identity so
        #: the common path pays no gather.
        self.orders = reorder.identity_orders(self.shape)
        self._inv: list[np.ndarray] | None = None

    def update(self, indices: np.ndarray, values: np.ndarray) -> None:
        idx = np.asarray(indices, dtype=np.int64)
        vals = np.asarray(values, dtype=np.float32).ravel()
        if idx.ndim != 2 or idx.shape[1] != len(self.shape) or idx.shape[0] != len(vals):
            raise ValueError(
                f"slab must be indices [B, {len(self.shape)}] + values [B], "
                f"got {idx.shape} / {vals.shape}"
            )
        if self._inv is not None:
            # train in POSITION space (X_pi(pos) = X(pi(pos)), the same
            # convention core/codec.py uses); decode maps back via inv_pi
            pos_idx = np.empty_like(idx)
            for j in range(idx.shape[1]):
                pos_idx[:, j] = self._inv[j][idx[:, j]]
            idx = pos_idx
        if self._mean is None:
            # frozen first-slab estimate: a streaming fit cannot see global
            # stats up front, and re-normalizing mid-stream would shift the
            # regression targets under the optimizer
            self._mean = float(vals.mean()) if self.normalize else 0.0
            self._std = (float(vals.std()) or 1.0) if self.normalize else 1.0
        vn = (vals - self._mean) / self._std
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.slabs_seen) * 131 + 29
        )

        # ---- train: fixed-shape [steps, bsz] batches mixing fresh + replay
        steps, bsz = self.steps_per_slab, self.batch_size
        n_replay = int(bsz * self.replay_fraction) if self._rfill else 0
        n_fresh = bsz - n_replay
        fresh = rng.integers(0, len(vn), size=(steps, n_fresh))
        pos = idx[fresh]                       # [steps, n_fresh, d]
        val = vn[fresh]
        if n_replay:
            rep = rng.integers(0, self._rfill, size=(steps, n_replay))
            pos = np.concatenate([pos, self._rpos[rep]], axis=1)
            val = np.concatenate([val, self._rval[rep]], axis=1)
        t0 = time.perf_counter()
        self.params, self._opt_state, loss = self._epoch(
            self.params,
            self._opt_state,
            jnp.asarray(pos, jnp.int32),
            jnp.asarray(val, jnp.float32),
        )
        train_elapsed = time.perf_counter() - t0

        # ---- reservoir insert (Algorithm R, vectorized per slab) ----------
        cap = self._rval.shape[0]
        take = min(cap - self._rfill, len(vn))
        if take:
            self._rpos[self._rfill : self._rfill + take] = idx[:take]
            self._rval[self._rfill : self._rfill + take] = vn[:take]
            self._rfill += take
        if take < len(vn):
            t = self.entries_seen + 1 + np.arange(take, len(vn), dtype=np.int64)
            slots = (rng.random(len(t)) * t).astype(np.int64)
            keep = slots < cap
            self._rpos[slots[keep]] = idx[take:][keep]
            self._rval[slots[keep]] = vn[take:][keep]

        self.entries_seen += len(vn)
        self.slabs_seen += 1
        if obs.fit_telemetry_enabled():
            # float(loss) forces a device sync — only pay it when logging
            obs.fit_event(
                "fit_slab",
                codec="nttd",
                step=self.slabs_seen - 1,
                loss=float(loss),
                entries=len(vn),
                entries_per_sec=(
                    len(vn) / train_elapsed if train_elapsed > 0 else None
                ),
                reservoir_fill=self._rfill,
                reservoir_capacity=int(self._rval.shape[0]),
            )

    def _reservoir_orig(self) -> np.ndarray:
        """Reservoir positions mapped back to ORIGINAL indices [fill, d]."""
        rpos = self._rpos[: self._rfill]
        if self._inv is None:
            return rpos
        return np.stack(
            [self.orders[j][rpos[:, j]] for j in range(len(self.shape))], axis=1
        )

    def refine_orders(self, x: np.ndarray | None = None) -> list[np.ndarray]:
        """Mid-stream TSP mode-order refinement (paper §IV-D, made
        streaming-feasible): recompute per-mode orders from a dense
        estimate — the caller's tensor when given, else a zero-filled
        densification of the reservoir sample — remap the reservoir into
        the new position space, and reinitialize the optimizer (the paper
        reinits Adam after every reorder).  Parameters are KEPT: training
        continues warm against the re-permuted targets, which is the
        read-repair refit's whole point."""
        if x is None:
            if not self._rfill:
                raise ValueError("empty reservoir: nothing to refine orders from")
            est = np.zeros(self.shape, dtype=np.float32)
            est[tuple(self._reservoir_orig().T)] = self._rval[: self._rfill]
        else:
            est = np.asarray(x, dtype=np.float32)
            if est.shape != self.shape:
                raise ValueError(
                    f"order-refinement tensor shape {est.shape} != {self.shape}"
                )
            # normalization is affine: slice distances (hence TSP orders)
            # are unchanged, but stay consistent with the reservoir path
            est = (est - (self._mean or 0.0)) / self._std
        orig = self._reservoir_orig() if self._rfill else None
        new = [reorder.tsp_order_mode(est, k) for k in range(est.ndim)]
        new_inv = [np.argsort(p) for p in new]
        if orig is not None:
            for j in range(len(self.shape)):
                self._rpos[: self._rfill, j] = new_inv[j][orig[:, j]]
        self.orders, self._inv = new, new_inv
        self._opt_state = self._opt.init(self.params)
        return new

    def finalize(self) -> Encoded:
        from repro.codecs.adapters import NTTDEncoded

        ct = codec_lib.CompressedTensor(
            jax.tree.map(np.asarray, self.params),
            [np.asarray(p) for p in self.orders],
            self.spec,
            self.cfg,
            self._mean or 0.0,
            self._std,
        )
        return NTTDEncoded(ct)


# ---------------------------------------------------------------------------
# TT: TT-ICE-style incremental row-space basis expansion
# ---------------------------------------------------------------------------
class TTICEStreamFitter(StreamFitter):
    """Incremental TT over slices arriving along mode 0.

    State is an orthonormal basis ``U`` [M, r] for the row space of the
    mode-0 unfolding (M = prod of trailing mode lengths) plus per-slice
    coefficients.  A new block of slices is projected onto ``U``; if the
    residual energy exceeds ``rel_eps`` and the rank cap allows, the
    residual's leading singular directions join the basis — existing
    coefficients are untouched (zero on new directions), which is exactly
    TT-ICE's update.  ``finalize`` TT-SVDs the [r, N_2, ..., N_d] basis
    tensor into trailing cores and absorbs the coefficients into core 1.

    Requires row-major slab delivery (the ``_FlatSlabSource`` layout);
    partial rows are buffered until the next slab completes them.
    """

    def __init__(
        self,
        shape: tuple[int, ...],
        max_rank: int,
        *,
        rel_eps: float = 0.02,
    ):
        if len(shape) < 2:
            raise ValueError("TT streaming needs an order >= 2 tensor")
        self.shape = tuple(int(s) for s in shape)
        self.max_rank = int(max_rank)
        self.rel_eps = float(rel_eps)
        self.row = int(np.prod(self.shape[1:]))
        self._U: np.ndarray | None = None       # [M, r] orthonormal columns
        self._coeffs: list[np.ndarray] = []     # blocks [b_i, r_at_block_i]
        self._pending = np.zeros((0,), dtype=np.float64)
        self.entries_seen = 0
        self.rows_seen = 0

    def update(self, indices: np.ndarray, values: np.ndarray) -> None:
        idx = np.asarray(indices)
        strides = np.cumprod((self.shape[1:] + (1,))[::-1])[::-1]
        flat0 = int((idx[0] * strides).sum())
        if flat0 < self.entries_seen:
            return  # re-read of an already-consumed prefix (extra pass): no-op
        if flat0 != self.entries_seen:
            raise ValueError(
                f"TT streaming needs contiguous row-major slabs: expected "
                f"flat offset {self.entries_seen}, got {flat0}"
            )
        vals = np.asarray(values, dtype=np.float64).ravel()
        self.entries_seen += len(vals)
        buf = np.concatenate([self._pending, vals])
        n_rows = len(buf) // self.row
        self._pending = buf[n_rows * self.row :]
        if not n_rows:
            return
        v = buf[: n_rows * self.row].reshape(n_rows, self.row)
        self.rows_seen += n_rows
        vnorm = float(np.linalg.norm(v))
        if self._U is None:
            u, s, _ = np.linalg.svd(v.T, full_matrices=False)
            r = max(int((s > self.rel_eps * max(vnorm, 1e-30)).sum()), 1)
            self._U = u[:, : min(r, self.max_rank)]
            self._coeffs.append(v @ self._U)
            if obs.fit_telemetry_enabled():
                obs.fit_event(
                    "fit_slab",
                    codec="tt_ice",
                    step=len(self._coeffs),
                    entries=n_rows * self.row,
                    rank=int(self._U.shape[1]),
                    rows_seen=self.rows_seen,
                )
            return
        c = v @ self._U
        res = v - c @ self._U.T
        headroom = self.max_rank - self._U.shape[1]
        if headroom > 0 and np.linalg.norm(res) > self.rel_eps * max(vnorm, 1e-30):
            u, s, _ = np.linalg.svd(res.T, full_matrices=False)
            k = max(int((s > self.rel_eps * max(vnorm, 1e-30)).sum()), 1)
            u_new = u[:, : min(k, headroom)]
            # re-orthogonalize against U (rounding leaves tiny overlaps)
            u_new -= self._U @ (self._U.T @ u_new)
            u_new /= np.maximum(np.linalg.norm(u_new, axis=0, keepdims=True), 1e-30)
            self._U = np.concatenate([self._U, u_new], axis=1)
            c = np.concatenate([c, v @ u_new], axis=1)
        self._coeffs.append(c)
        if obs.fit_telemetry_enabled():
            obs.fit_event(
                "fit_slab",
                codec="tt_ice",
                step=len(self._coeffs),
                entries=n_rows * self.row,
                rank=int(self._U.shape[1]),
                rows_seen=self.rows_seen,
            )

    def finalize(self) -> Encoded:
        from repro.codecs.adapters import TTEncoded

        if self._U is None:
            raise ValueError("no complete mode-0 rows seen yet")
        r = self._U.shape[1]
        n1 = self.shape[0]
        a = np.zeros((n1, r))
        off = 0
        for block in self._coeffs:      # older blocks are zero on newer dirs
            a[off : off + block.shape[0], : block.shape[1]] = block
            off += block.shape[0]
        tail = ttd.tt_svd(
            self._U.T.reshape((r,) + self.shape[1:]), max_rank=self.max_rank
        )
        first = a @ tail.cores[0][0]    # absorb basis coefficients into core 1
        cores = [first.reshape(1, n1, first.shape[1])] + tail.cores[1:]
        return TTEncoded(ttd.TTDecomposition(cores))
