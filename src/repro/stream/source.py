"""Slab sources: deterministic, resumable suppliers of tensor entries.

A *slab* is a contiguous row-major block of the tensor delivered as
``(indices, values)`` — original multi-indices ``[B, d]`` plus the entry
values ``[B]``.  Sources follow the ``data/pipeline.py`` batch-at-step
contract: ``slab_at(cursor)`` is a pure function of ``(source config,
cursor)``, so a restarted fit resumes mid-stream by just asking for the
right cursor, and two fits over the same cursor range see bit-identical
data.

Three sources:
  * ``DenseSource``      — wraps an in-memory array (tests, parity checks);
  * ``MMapTensorSource`` — flat binary file via ``np.memmap`` (out-of-core
    production path; ``write_tensor_file`` builds one);
  * ``SyntheticTensorSource`` — seeded separable-harmonic generator that
    computes values entrywise from indices, so a 2^24-entry tensor can be
    streamed without EVER materializing it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Protocol, runtime_checkable

import numpy as np

from repro.codecs.indexing import flat_to_multi


@dataclasses.dataclass(frozen=True)
class Slab:
    cursor: int
    indices: np.ndarray  # [B, d] int64, ORIGINAL multi-indices
    values: np.ndarray   # [B] float32


@runtime_checkable
class SlabSource(Protocol):
    """The protocol ``fit_stream`` consumes.  Implementations must make
    ``slab_at`` deterministic and side-effect free (resumable cursor)."""

    shape: tuple[int, ...]
    slab_entries: int

    @property
    def n_slabs(self) -> int: ...

    def slab_at(self, cursor: int) -> Slab: ...


class _FlatSlabSource:
    """Shared base: row-major flat ranges ``[c * slab_entries, ...)``.

    Subclasses implement ``_values_flat(start, stop)``; everything else —
    cursor arithmetic, index synthesis, iteration — lives here so all
    sources agree on which entries slab ``c`` contains.
    """

    def __init__(self, shape: tuple[int, ...], slab_entries: int):
        self.shape = tuple(int(s) for s in shape)
        if slab_entries <= 0:
            raise ValueError(f"slab_entries must be positive, got {slab_entries}")
        self.slab_entries = int(slab_entries)
        self.n_entries = int(np.prod(self.shape))
        #: peak bytes one slab occupies resident (indices int64 + values f32)
        self.slab_nbytes = self.slab_entries * (8 * len(self.shape) + 4)

    @property
    def n_slabs(self) -> int:
        return -(-self.n_entries // self.slab_entries)

    def slab_at(self, cursor: int) -> Slab:
        if not 0 <= cursor < self.n_slabs:
            raise IndexError(f"cursor {cursor} out of range [0, {self.n_slabs})")
        start = cursor * self.slab_entries
        stop = min(start + self.slab_entries, self.n_entries)
        flat = np.arange(start, stop, dtype=np.int64)
        indices = flat_to_multi(flat, self.shape)
        values = np.asarray(
            self._values_slab(start, stop, indices), np.float32
        ).ravel()
        return Slab(cursor, indices, values)

    def _values_slab(
        self, start: int, stop: int, indices: np.ndarray
    ) -> np.ndarray:
        """Values for the flat range [start, stop); ``indices`` is its
        already-computed multi-index block for sources that synthesize
        values from coordinates."""
        raise NotImplementedError

    def iter_slabs(self, start: int = 0, stop: int | None = None) -> Iterator[Slab]:
        for c in range(start, self.n_slabs if stop is None else stop):
            yield self.slab_at(c)


class DenseSource(_FlatSlabSource):
    """Slabs over an in-memory array (control path for parity tests)."""

    def __init__(self, x: np.ndarray, slab_entries: int = 1 << 16):
        super().__init__(x.shape, slab_entries)
        self._flat = np.ascontiguousarray(x, dtype=np.float32).reshape(-1)

    def _values_slab(self, start, stop, indices) -> np.ndarray:
        return self._flat[start:stop]


class MMapTensorSource(_FlatSlabSource):
    """Flat binary file of row-major entries, read slab-by-slab via mmap —
    the resident set is one slab, never the tensor."""

    def __init__(
        self,
        path: str,
        shape: tuple[int, ...],
        dtype: str | np.dtype = np.float32,
        slab_entries: int = 1 << 16,
    ):
        super().__init__(shape, slab_entries)
        self._data = np.memmap(path, dtype=np.dtype(dtype), mode="r")
        if len(self._data) < self.n_entries:
            raise ValueError(
                f"{path}: {len(self._data)} entries on disk < shape "
                f"{self.shape} ({self.n_entries} entries)"
            )

    def _values_slab(self, start, stop, indices) -> np.ndarray:
        return np.asarray(self._data[start:stop], dtype=np.float32)


def write_tensor_file(path: str, x: np.ndarray) -> None:
    """Row-major flat dump, the on-disk layout MMapTensorSource reads."""
    np.ascontiguousarray(x).tofile(path)


class SyntheticTensorSource(_FlatSlabSource):
    """Seeded separable-harmonic tensor, computed entrywise from indices.

    value(i) = A * prod_k sin(2 pi f_k i_k / N_k + phi_k) + bias + noise-free
    second harmonic — smooth, learnable structure (NTTD reaches high
    fitness on it) that a generator can emit for ANY flat range without
    materializing the tensor.  Frequencies/phases are drawn once from
    ``seed``, so slab c is a pure function of (shape, slab_entries, seed, c).
    """

    def __init__(
        self,
        shape: tuple[int, ...],
        slab_entries: int = 1 << 16,
        seed: int = 0,
    ):
        super().__init__(shape, slab_entries)
        self.seed = int(seed)
        rng = np.random.default_rng(self.seed)
        d = len(self.shape)
        self._freq = rng.integers(1, 4, size=(2, d)).astype(np.float64)
        self._phase = rng.uniform(0.0, 2 * np.pi, size=(2, d))
        self._amp = np.array([1.0, 0.35])
        self._bias = float(rng.normal() * 0.1)

    def _values_slab(self, start, stop, indices) -> np.ndarray:
        return self.values_at(indices)

    def values_at(self, indices: np.ndarray) -> np.ndarray:
        """Ground truth at arbitrary multi-indices [B, d] — the whole point
        of this source: any entry is computable without the tensor."""
        dims = np.asarray(self.shape, dtype=np.float64)
        out = np.full(indices.shape[0], self._bias)
        for h in range(2):
            theta = 2 * np.pi * self._freq[h] * indices / dims + self._phase[h]
            out += self._amp[h] * np.prod(np.sin(theta), axis=1)
        return out.astype(np.float32)
