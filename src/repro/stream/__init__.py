"""Out-of-core streaming compression: fit and serve tensors that never
fit in memory at once.

The paper's scalability claim (§V-D) is that compression time is linear
in the number of entries — this package removes the remaining obstacle
to exercising that claim at scale, the fully materialized ``np.ndarray``
every ``Codec.fit`` call required.  Tensors arrive as ``(indices,
values)`` slabs from a :class:`SlabSource` (dense array, memory-mapped
file, or seeded synthetic generator), and ``fit_stream`` drives a
codec's incremental fitter over them:

    from repro.stream import SyntheticTensorSource, fit_stream

    src = SyntheticTensorSource((4096, 64, 64), slab_entries=1 << 18)
    enc = fit_stream("nttd", src, rank=6, hidden=12)   # never densifies
    repro.stream.write_chunked("payload.tcdc", enc)    # chunked container

NTTD warm-starts its minibatched SGD (paper §IV-B Alg. 2) over arriving
slabs with a reservoir replay buffer; TT gets a TT-ICE-style incremental
basis expansion (Aksoy et al., PAPERS.md); every other codec falls back
to accumulate-then-``fit`` via the default ``Codec.fit_stream`` hook.
Modules: ``source`` (slab protocol + sources), ``fit`` (incremental
fitters), ``writer`` (chunked container-v3 writer).
"""
from repro.stream.fit import NTTDStreamFitter, TTICEStreamFitter, fit_stream
from repro.stream.source import (
    DenseSource,
    MMapTensorSource,
    Slab,
    SlabSource,
    SyntheticTensorSource,
    write_tensor_file,
)
from repro.stream.writer import (
    ChunkedWriter,
    append_patch,
    rewrite_chunks,
    sample_heldout,
    write_chunked,
)

__all__ = [
    "ChunkedWriter",
    "append_patch",
    "rewrite_chunks",
    "DenseSource",
    "MMapTensorSource",
    "NTTDStreamFitter",
    "Slab",
    "SlabSource",
    "SyntheticTensorSource",
    "TTICEStreamFitter",
    "fit_stream",
    "sample_heldout",
    "write_chunked",
    "write_tensor_file",
]
