"""Counters, gauges, and fixed-bucket histograms behind one registry.

The fleet layer's ad-hoc latency deques gave windowed percentiles only —
an instance whose deque wrapped silently forgot its history.  A
:class:`Histogram` here keeps BOTH views under bounded memory:

- fixed log-spaced buckets accumulate every observation forever, so
  all-time p50/p99 are available at any fleet age (bucket-interpolated,
  clamped to the observed min/max);
- a ``maxlen``-bounded window deque keeps the most recent raw samples,
  so the recent-window percentiles stay EXACT — the semantics the old
  ``FleetFrontend._latency`` deques had.

Percentile calls on an empty histogram return ``None`` (never raise):
an instance with zero flushes is a reportable fact, not a crash.

:class:`MetricsRegistry` get-or-creates instruments by (name, labels)
and renders the lot JSON-able via ``as_dict`` — the shape the fleet
metrics roll-up extends its wire schema with.
"""
from __future__ import annotations

import collections
import math
import threading


def default_latency_buckets() -> tuple[float, ...]:
    """Log-spaced seconds, 10us .. ~84s (1-2-5 decades): fine enough for
    sub-millisecond decode spans, wide enough for cold jit compiles."""
    out = []
    for exp in range(-5, 2):
        for mant in (1.0, 2.0, 5.0):
            out.append(mant * 10.0**exp)
    return tuple(out)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value, with a running max (peak-tracking gauges are the
    fleet's in-flight byte high-water marks)."""

    __slots__ = ("name", "labels", "value", "max")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self.max = 0.0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.max:
            self.max = v

    def set_max(self, v: float) -> None:
        """Peak semantics: keep the high-water mark in ``value`` itself."""
        if v > self.value:
            self.value = v
            self.max = v


class Histogram:
    """Fixed-bucket histogram + bounded exact-sample window."""

    __slots__ = (
        "name", "labels", "bounds", "bucket_counts", "count", "total",
        "min", "max", "window",
    )

    def __init__(
        self,
        name: str,
        labels: tuple,
        buckets: tuple[float, ...] | None = None,
        window: int = 2048,
    ):
        self.name = name
        self.labels = labels
        self.bounds = tuple(buckets) if buckets else default_latency_buckets()
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram buckets must ascend: {self.bounds}")
        # one count per bound plus the overflow bucket
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.window: collections.deque[float] = collections.deque(maxlen=window)

    def observe(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # first bound >= v (bisect, inlined to stay import-light)
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.bucket_counts[lo] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.window.append(v)

    @property
    def mean(self) -> float | None:
        return self.total / self.count if self.count else None

    def percentile(self, q: float) -> float | None:
        """All-time percentile estimate from the buckets (linear within the
        target bucket, clamped to observed min/max).  ``None`` when empty."""
        if not self.count:
            return None
        target = q / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.bucket_counts):
            if not c:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (target - cum) / c
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def window_percentile(self, q: float) -> float | None:
        """EXACT percentile over the recent-sample window; ``None`` when
        empty.  Same nearest-rank-with-interpolation convention as
        ``numpy.percentile(..., q)`` (linear)."""
        if not self.window:
            return None
        vals = sorted(self.window)
        if len(vals) == 1:
            return vals[0]
        pos = q / 100.0 * (len(vals) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(vals) - 1)
        return vals[lo] + (vals[hi] - vals[lo]) * (pos - lo)

    def window_values(self) -> list[float]:
        return list(self.window)


class MetricsRegistry:
    """Get-or-create instrument registry, keyed by (name, sorted labels)."""

    def __init__(self) -> None:
        self._instruments: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kw):
        key = (cls.__name__, name, tuple(sorted(labels.items())))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, tuple(sorted(labels.items())), **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):  # pragma: no cover — registry bug
                raise TypeError(f"{name}{labels} already registered as "
                                f"{type(inst).__name__}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(
        self,
        name: str,
        buckets: tuple[float, ...] | None = None,
        window: int = 2048,
        **labels,
    ) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets, window=window)

    def instruments(self) -> list:
        with self._lock:
            return list(self._instruments.values())

    def remove(self, name: str, **labels) -> None:
        """Drop every instrument kind registered under (name, labels) —
        what the fleet does when an instance retires."""
        key_labels = tuple(sorted(labels.items()))
        with self._lock:
            for key in [
                k for k in self._instruments
                if k[1] == name and k[2] == key_labels
            ]:
                del self._instruments[key]

    def as_dict(self) -> dict:
        """JSON-able snapshot of every instrument."""
        out: dict[str, list] = {"counters": [], "gauges": [], "histograms": []}
        for inst in self.instruments():
            labels = dict(inst.labels)
            if isinstance(inst, Counter):
                out["counters"].append(
                    {"name": inst.name, "labels": labels, "value": inst.value}
                )
            elif isinstance(inst, Gauge):
                out["gauges"].append(
                    {"name": inst.name, "labels": labels,
                     "value": inst.value, "max": inst.max}
                )
            elif isinstance(inst, Histogram):
                out["histograms"].append({
                    "name": inst.name,
                    "labels": labels,
                    "count": inst.count,
                    "sum": inst.total,
                    "p50": inst.percentile(50),
                    "p99": inst.percentile(99),
                    "window_p50": inst.window_percentile(50),
                    "window_p99": inst.window_percentile(99),
                })
        return out
